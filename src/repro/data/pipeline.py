"""Deterministic synthetic token pipeline.

Emits next-token-prediction batches from a fixed-seed Markov-ish stream:
tokens follow a Zipf marginal with a learnable-in-principle bigram
structure (``x_{t+1} = (a·x_t + b) mod V`` on a subset of steps), so tiny
models show a real, monotonically-decreasing loss — enough signal to
validate trainers and the STRADS block scheduler end-to-end without
shipping a corpus.

Everything is derived from ``(seed, step)`` so any worker can regenerate
any batch (the same property STRADS push workers rely on for their data
shards); no filesystem or host state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2          # marginal skew
    structure: float = 0.75      # fraction of deterministic bigram steps


def _zipf_logits(v: int, a: float) -> np.ndarray:
    ranks = np.arange(1, v + 1, dtype=np.float64)
    return np.log(ranks ** -a)


def make_batch(cfg: SyntheticLMConfig, step: int,
               d_model: Optional[int] = None,
               frontend_tokens: int = 0,
               frames: bool = False) -> Dict[str, jax.Array]:
    """Batch for one step.  ``frames=True`` → audio-style frame embeddings
    instead of tokens; ``frontend_tokens`` → prepend VLM patch embeddings."""
    key = jax.random.PRNGKey(cfg.seed * 1_000_003 + step)
    kz, ks, kf, kv = jax.random.split(key, 4)
    B, S, V = cfg.batch_size, cfg.seq_len + 1, cfg.vocab_size
    logits = jnp.asarray(_zipf_logits(V, cfg.zipf_a), jnp.float32)
    draws = jax.random.categorical(kz, logits, shape=(B, S))
    structured = jax.random.bernoulli(ks, cfg.structure, (B, S))

    def step_fn(prev, xs):
        draw, use_bigram = xs
        nxt = jnp.where(use_bigram, (prev + 1) % V, draw)
        return nxt, nxt
    _, seq = jax.lax.scan(step_fn, draws[:, 0],
                          (draws.T, structured.T))
    seq = seq.T.astype(jnp.int32)                       # (B, S)

    out: Dict[str, jax.Array] = {"labels": seq[:, 1:]}
    if frames:
        assert d_model is not None
        out["frames"] = jax.random.normal(kf, (B, cfg.seq_len, d_model),
                                          jnp.float32) * 0.02
    else:
        out["tokens"] = seq[:, :-1]
    if frontend_tokens:
        assert d_model is not None
        out["frontend"] = jax.random.normal(kv, (B, frontend_tokens,
                                                 d_model),
                                            jnp.float32) * 0.02
    return out


def synthetic_batches(cfg: SyntheticLMConfig, **kw
                      ) -> Iterator[Dict[str, jax.Array]]:
    """The trainer-facing batch iterator — a thin walk over
    :class:`repro.stream.source.SyntheticLMSource`, so the streaming
    subsystem's DataSource and this generator share one batch-derivation
    path (same ``(seed, step)`` schedule, same deltas)."""
    from ..stream.source import SyntheticLMSource
    src = SyntheticLMSource(cfg, kwargs=kw or None)
    step = 0
    while True:
        for delta in src.take(step):
            yield delta["data"]
        step += 1
