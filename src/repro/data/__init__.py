from .pipeline import SyntheticLMConfig, synthetic_batches, make_batch  # noqa: F401
