"""Host-side structured events: the :class:`Recorder`.

The device counters (:mod:`repro.obs.counters`) answer *what the
compiled program did*; the Recorder answers *what the host runtime did
around it*: compiled-program cache misses on (SchedulerSpec, Assignment,
KernelSpec) keys, partition rebalances with before/after load spreads,
checkpoint writes, and wall-clock spans around every execution phase
(resolve → chunk → executor dispatch, per-round under the host loop).

Events are typed dicts with a microsecond timestamp relative to the
Recorder's start:

* **instants** — ``{"name", "ph": "i", "ts", "args"}``;
* **spans** — ``{"name", "ph": "X", "ts", "dur", "args"}``, produced by
  the ``span()`` context manager.  The context-manager discipline makes
  nesting *structural*: a span closes only after everything it opened,
  so exported spans are strictly nested with non-negative durations
  (``tests/test_obs.py`` validates the export against exactly that).

Exports: ``to_json_events()`` (the portable list that rides
:class:`~repro.obs.report.RunReport`), JSONL (one event per line), and
the Chrome trace-event format (``chrome://tracing`` / Perfetto — see
:func:`chrome_trace`).  ``profiler=True`` additionally opens a
``jax.profiler.TraceAnnotation`` around every span, so host phases line
up inside an XLA device profile.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import List, Optional


class Recorder:
    """Collects typed instants and strictly nested wall-clock spans."""

    def __init__(self, profiler: bool = False):
        self.profiler = profiler
        self._t0 = time.perf_counter_ns()
        self._events: List[dict] = []
        self._stack: List[dict] = []   # open spans (strict nesting)

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    # -- recording -----------------------------------------------------------

    def instant(self, name: str, **args) -> dict:
        """Record a point event (cache miss, rebalance, checkpoint …)."""
        ev = {"name": name, "ph": "i", "ts": self._now_us(),
              "args": args}
        self._events.append(ev)
        return ev

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Record a wall-clock phase span.  Spans opened inside close
        first (context-manager discipline), so the export is strictly
        nested by construction."""
        ev = {"name": name, "ph": "X", "ts": self._now_us(),
              "dur": 0.0, "args": args}
        self._stack.append(ev)
        ann = contextlib.nullcontext()
        if self.profiler:
            import jax.profiler
            ann = jax.profiler.TraceAnnotation(name)
        try:
            with ann:
                yield ev
        finally:
            ev["dur"] = max(0.0, self._now_us() - ev["ts"])
            self._stack.pop()
            self._events.append(ev)

    # -- export --------------------------------------------------------------

    def to_json_events(self) -> List[dict]:
        """The portable event list (instants + completed spans), sorted
        by start time — what :class:`~repro.obs.report.RunReport`
        carries and the JSONL/Chrome exports derive from."""
        return sorted((dict(ev) for ev in self._events),
                      key=lambda e: (e["ts"], -e.get("dur", 0.0)))

    def write_jsonl(self, path: str) -> str:
        return write_jsonl(self.to_json_events(), path)

    def write_chrome_trace(self, path: str) -> str:
        return write_chrome_trace(self.to_json_events(), path)


# ---------------------------------------------------------------------------
# Format helpers (usable on saved event lists too — launch/trace CLI)
# ---------------------------------------------------------------------------

def write_jsonl(events: List[dict], path: str) -> str:
    """One event dict per line — greppable, streamable."""
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")
    return path


def chrome_trace(events: List[dict], pid: int = 0, tid: int = 0) -> dict:
    """The Chrome trace-event JSON (``chrome://tracing`` / Perfetto):
    spans become complete ("X") events, instants stay instant ("i")
    events, timestamps/durations in microseconds."""
    out = []
    for ev in events:
        rec = {"name": ev["name"], "ph": ev.get("ph", "i"),
               "ts": ev["ts"], "pid": pid, "tid": tid,
               "cat": "strads", "args": ev.get("args", {})}
        if rec["ph"] == "X":
            rec["dur"] = ev.get("dur", 0.0)
        else:
            rec["s"] = "t"
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(events: List[dict], path: str,
                       pid: int = 0, tid: int = 0) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(events, pid=pid, tid=tid), f, indent=1)
    return path


def validate_spans(events: List[dict]) -> Optional[str]:
    """``None`` when every span has a non-negative duration and the span
    set is strictly nested (any two spans are disjoint or one contains
    the other); else a human-readable reason — the ``launch/trace
    --check`` predicate."""
    spans = [ev for ev in events if ev.get("ph") == "X"]
    for ev in spans:
        if ev.get("dur", 0.0) < 0.0:
            return f"span {ev['name']!r} has negative duration {ev['dur']}"
        if ev.get("ts", 0.0) < 0.0:
            return f"span {ev['name']!r} starts before the run ({ev['ts']})"
    spans = sorted(spans, key=lambda e: (e["ts"], -e["dur"]))
    stack: List[dict] = []
    for ev in spans:
        while stack and ev["ts"] >= stack[-1]["ts"] + stack[-1]["dur"]:
            stack.pop()
        if stack:
            parent = stack[-1]
            if ev["ts"] + ev["dur"] > parent["ts"] + parent["dur"]:
                return (f"span {ev['name']!r} overlaps its enclosing "
                        f"{parent['name']!r} without nesting inside it")
        stack.append(ev)
    return None
