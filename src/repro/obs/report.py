"""The uniform per-run metrics surface: :class:`RunReport`.

``ExecutionReport.telemetry`` is one of these for **every** executor
whenever the plan carries a :class:`~repro.obs.spec.TelemetrySpec`:
the resolved spec, the device counters summarized to host ints, the
host event log (``kind="trace"``), and — for SSP runs — the
:class:`~repro.ps.telemetry.SSPTelemetry` staleness/byte section that
used to be the whole telemetry story.

A RunReport is JSON-first: ``to_json()`` is what dryrun/train/benchmark
artifacts embed and what ``python -m repro.launch.trace`` summarizes,
checks and re-exports (JSONL / Chrome trace) offline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

from .counters import summarize_counters
from .events import write_chrome_trace, write_jsonl
from .spec import TelemetrySpec


@dataclasses.dataclass
class RunReport:
    """One instrumented run, summarized uniformly across executors.

    spec:      the resolved :class:`TelemetrySpec` that instrumented
               the run.
    executor:  the plan's executor name.
    rounds:    rounds the plan executed.
    counters:  host-int summary of the device counter pytree (see
               :func:`repro.obs.counters.summarize_counters`); ``{}``
               only for runs that executed zero rounds.
    events:    the host event log (instants + strictly nested spans,
               microsecond timestamps) — ``[]`` under
               ``kind="counters"``.
    ssp:       the :class:`repro.ps.telemetry.SSPTelemetry` section
               (staleness histogram + byte accounting); ``None`` for
               the BSP executors.
    """
    spec: TelemetrySpec
    executor: str
    rounds: int
    counters: dict = dataclasses.field(default_factory=dict)
    events: List[dict] = dataclasses.field(default_factory=list)
    ssp: Any = None

    @classmethod
    def build(cls, spec: TelemetrySpec, executor: str, rounds: int,
              device_counters: Any = None, recorder: Any = None,
              ssp: Any = None) -> "RunReport":
        """Assemble from the run's raw pieces: the device counter pytree
        off the final carry, the live Recorder (or None), and the SSP
        summary (or None)."""
        return cls(spec=spec, executor=executor, rounds=rounds,
                   counters=summarize_counters(device_counters),
                   events=(recorder.to_json_events()
                           if recorder is not None else []),
                   ssp=ssp)

    # -- export --------------------------------------------------------------

    def to_json(self) -> dict:
        return {"spec": self.spec.to_json(),
                "executor": self.executor,
                "rounds": self.rounds,
                "counters": dict(self.counters),
                "events": [dict(ev) for ev in self.events],
                "ssp": self.ssp.to_json() if self.ssp is not None
                else None}

    def summary(self) -> str:
        """One line per layer — what the trace CLI prints."""
        lines = [f"{self.executor}: {self.rounds} rounds "
                 f"(telemetry kind={self.spec.kind!r})"]
        c = self.counters
        if c:
            lines.append(
                f"  counters: rounds/phase {c['rounds_per_phase']}  "
                f"sched_size {c['sched_size']}  rho-filter "
                f"{c['accepted']}/{c['proposed']} kept "
                f"({c['killed']} killed)")
        if self.events:
            spans = [e for e in self.events if e.get("ph") == "X"]
            inst = len(self.events) - len(spans)
            lines.append(f"  events: {len(spans)} spans, {inst} "
                         f"instants")
            for e in spans:
                if not _enclosed(e, spans):
                    lines.append(f"    {e['name']}: "
                                 f"{e['dur'] / 1e3:.2f} ms")
        if self.ssp is not None:
            s = self.ssp
            lines.append(
                f"  ssp: staleness<= {s.max_staleness}/"
                f"{s.staleness_bound}  hist {list(map(int, s.hist))}  "
                f"flushes {s.flushes}  pushed {s.bytes_pushed}B")
        return "\n".join(lines)

    def write_jsonl(self, path: str) -> str:
        return write_jsonl(self.events, path)

    def write_chrome_trace(self, path: str) -> str:
        return write_chrome_trace(self.events, path)


def _enclosed(ev: dict, spans: List[dict]) -> bool:
    return any(o is not ev and o["ts"] <= ev["ts"]
               and ev["ts"] + ev["dur"] <= o["ts"] + o["dur"]
               for o in spans)


def report_from_json(obj: dict) -> RunReport:
    """Rebuild a RunReport (sans the live SSPTelemetry object — its
    section stays a plain dict) from ``to_json()`` output; the trace CLI
    uses this to summarize/check/re-export saved artifacts."""
    spec = TelemetrySpec.from_json(obj["spec"])
    rep = RunReport(spec=spec, executor=obj["executor"],
                    rounds=int(obj["rounds"]),
                    counters=dict(obj.get("counters") or {}),
                    events=list(obj.get("events") or []),
                    ssp=_DictSection(obj["ssp"]) if obj.get("ssp")
                    else None)
    return rep


class _DictSection:
    """A saved SSP section, re-animated just enough for summary()."""

    def __init__(self, d: dict):
        self._d = dict(d)

    def __getattr__(self, name):
        try:
            return self._d[name]
        except KeyError:
            raise AttributeError(name) from None

    def to_json(self) -> dict:
        return dict(self._d)
