"""The declarative observability surface: :class:`TelemetrySpec`.

The paper's dynamic-parallelism argument rests on *measured* system
behavior — staleness actually observed, bytes actually moved, load
actually imbalanced — so observability is a first-class policy on the
:class:`~repro.core.plan.ExecutionPlan`, declared exactly like the
scheduler/partitioner/kernel policies already are:

* **frozen + hashable** — a spec is a value, usable as a sweep key;
* **validated at construction** — every invalid kind/parameter
  combination raises here, at spec-build time, never at trace time;
* **JSON-round-trippable** — ``to_json``/``from_json`` are exact
  (defaults included), so specs live inside checked-in plan files
  (``examples/plans/``), benchmark records (``BENCH_obs.json``) and CLI
  flags (``launch/dryrun.py --telemetry``).

Two kinds, by cost:

* ``"counters"`` — device-side int32 counters threaded through every
  executor's scan carry (per-phase round counts, schedule sizes, the
  ρ-filter's proposed/accepted/killed tallies, plus SSP's staleness
  histogram).  Bit-neutral to model state and within noise on the hot
  path (``benchmarks/bench_obs.py`` keeps that claim measured).
* ``"trace"`` — counters **plus** host-side structured events: a
  :class:`~repro.obs.events.Recorder` collecting typed instants
  (compiled-program cache misses, rebalances with before/after load
  spreads, checkpoint writes) and wall-clock phase spans, exportable as
  JSONL and as a Chrome-trace (``chrome://tracing``/Perfetto) file.
  ``profiler=True`` additionally opens a ``jax.profiler``
  TraceAnnotation around every span so the host phases line up inside
  an XLA profile.
"""
from __future__ import annotations

import dataclasses
import json

TELEMETRY_KINDS = ("counters", "trace")

_KIND_MSG = "telemetry kind must be 'counters' or 'trace'; got {!r}"

# Which fields each kind consumes; everything else must stay at its zero
# default (a spec never carries silently-ignored knobs).
_FIELDS_BY_KIND = {
    "counters": (),
    "trace": ("profiler",),
}


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Everything the engine needs to know about *what* to observe.

    Fields
    ------
    kind:     ``"counters"`` (device-side per-phase/schedule/ρ-filter
              counters in the executor carry — the hot-path-safe floor)
              or ``"trace"`` (counters + the host-side event
              :class:`~repro.obs.events.Recorder` with phase spans and
              Chrome-trace export).
    profiler: with ``kind="trace"``: open a ``jax.profiler``
              TraceAnnotation around every recorded span, so host
              phases appear inside an XLA device profile.
    """

    kind: str
    profiler: bool = False

    def __post_init__(self):
        if self.kind not in TELEMETRY_KINDS:
            raise ValueError(_KIND_MSG.format(self.kind))
        if not isinstance(self.profiler, bool):
            raise ValueError(f"profiler must be a bool; got "
                             f"{self.profiler!r}")
        used = _FIELDS_BY_KIND[self.kind]
        if "profiler" not in used and self.profiler:
            raise ValueError(
                f"profiler={self.profiler!r} does not apply to "
                f"kind={self.kind!r} (leave it at its default)")

    @property
    def events(self) -> bool:
        """True when this spec asks for the host-side event Recorder."""
        return self.kind == "trace"

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        """A plain JSON-safe dict (every field, defaults included) —
        ``from_json(to_json(s)) == s`` exactly."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj) -> "TelemetrySpec":
        """Rebuild from ``to_json`` output, a JSON string, or a partial
        dict (missing fields take their defaults; unknown keys raise)."""
        if isinstance(obj, (str, bytes)):
            obj = json.loads(obj)
        if not isinstance(obj, dict):
            raise TypeError(f"TelemetrySpec.from_json wants a dict or "
                            f"JSON string; got {type(obj).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - known
        if unknown:
            raise ValueError(f"unknown TelemetrySpec field(s): "
                             f"{sorted(unknown)}")
        return cls(**obj)

    @classmethod
    def default_for(cls, kind: str, **overrides) -> "TelemetrySpec":
        """The conventional spec for a kind — the ONE defaults table the
        CLI surfaces (``dryrun --telemetry``) resolve flag-built specs
        from, so per-site copies cannot drift.  ``overrides`` replace
        individual fields on the conventional base."""
        if kind not in TELEMETRY_KINDS:
            raise ValueError(_KIND_MSG.format(kind))
        base = dict(kind=kind)
        base.update(overrides)
        return cls(**base)
