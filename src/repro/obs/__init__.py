"""repro.obs — the unified observability subsystem.

Three layers behind one declarative :class:`TelemetrySpec` on the
:class:`~repro.core.plan.ExecutionPlan` (the telemetry-injection
contract, :mod:`repro.core.primitives`):

* :mod:`repro.obs.counters` — device-side int32 counters threaded
  through every executor's scan carry (per-phase rounds, schedule
  sizes, the ρ-filter ledger, SSP staleness histograms), bit-neutral to
  model state;
* :mod:`repro.obs.events` — the host-side :class:`Recorder` of typed
  instants and strictly nested wall-clock spans, exportable as JSONL
  and Chrome-trace files;
* :mod:`repro.obs.report` — :class:`RunReport`, the uniform
  ``ExecutionReport.telemetry`` object every executor returns
  (``python -m repro.launch.trace`` summarizes/checks saved ones).
"""
from .counters import (init_counters, observe_read, observe_round,
                       staleness_init, summarize_counters)
from .events import (Recorder, chrome_trace, validate_spans,
                     write_chrome_trace, write_jsonl)
from .report import RunReport, report_from_json
from .spec import TELEMETRY_KINDS, TelemetrySpec

__all__ = [
    "TELEMETRY_KINDS", "TelemetrySpec", "Recorder", "RunReport",
    "chrome_trace", "init_counters", "observe_read", "observe_round",
    "report_from_json", "staleness_init", "summarize_counters",
    "validate_spans", "write_chrome_trace", "write_jsonl",
]
