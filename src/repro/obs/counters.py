"""Device-side telemetry counters, carried through every executor's scan.

One small int32 pytree rides the executor carry (``EngineCarry.obs`` /
``SSPCarry.obs``) and is folded forward once per round, entirely from the
round's *schedule* — never from model state or the PRNG stream, so an
instrumented run is **bit-identical** to an uninstrumented one (the
telemetry-on ≡ telemetry-off property ``tests/test_obs.py`` asserts on
every executor × app).

Counters
--------
``rounds``     (phase_period,) — rounds executed per static phase; the
               total must equal the rounds the plan ran (the hypothesis
               invariant: ``Σ rounds == R``).
``sched_size`` scheduled entries actually admitted across the run (for
               masked schedules the mask popcount; for dense schedules
               the static schedule width).
``proposed``/``accepted``/``killed``
               the ρ-dependency-filter ledger (paper §3.3): candidates
               the scheduler proposed (U′ per round for the dynamic
               kinds), survivors of the dependency filter, and filtered
               casualties — ``accepted + killed == proposed`` by
               construction, and the property test keeps it that way.

The SSP staleness histogram (``staleness_init``/``observe_read``) lives
here too — it is the same pattern (an int32 pytree in the scan carry,
asserted over what the compiled program actually did), generalized from
the original ``repro/ps/telemetry.py`` device half, which now re-exports
these for its summaries.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def init_counters(phase_period: int) -> Dict[str, jnp.ndarray]:
    """A fresh counter pytree for an app whose phases cycle with period
    ``phase_period`` (1 = phaseless)."""
    return {"rounds": jnp.zeros((phase_period,), jnp.int32),
            "sched_size": jnp.int32(0),
            "proposed": jnp.int32(0),
            "accepted": jnp.int32(0),
            "killed": jnp.int32(0)}


def observe_round(counters: Dict[str, jnp.ndarray], sched: Any,
                  phase: int,
                  num_candidates: int = 0) -> Dict[str, jnp.ndarray]:
    """Fold one executed round's schedule into the counters (traced —
    runs inside the executor's scan).

    Boolean leaves of the schedule pytree are keep-masks (the
    ρ-dependency filter's survivors): their popcount is the round's
    accepted count, ``num_candidates`` (the scheduler's static U′; 0 for
    policies without a proposal pool) the proposed count, and the
    difference the killed count.  Schedules without masks (rotation,
    dense MF rank blocks) contribute their static width to
    ``sched_size`` and keep the filter ledger balanced with
    ``proposed == accepted``.
    """
    c = dict(counters)
    c["rounds"] = c["rounds"].at[phase].add(1)
    leaves = jax.tree_util.tree_leaves(sched)
    masks = [x for x in leaves
             if jnp.asarray(x).dtype == jnp.bool_]
    if masks:
        acc = sum(jnp.sum(m.astype(jnp.int32)) for m in masks)
        prop = (jnp.int32(num_candidates) if num_candidates else acc)
        c["sched_size"] = c["sched_size"] + acc
        c["accepted"] = c["accepted"] + acc
        c["proposed"] = c["proposed"] + prop
        c["killed"] = c["killed"] + (prop - acc)
    else:
        width = int(sum(np.prod(jnp.shape(x), dtype=int)
                        for x in leaves))
        c["sched_size"] = c["sched_size"] + jnp.int32(width)
        # no filter ran: the ledger stays balanced at proposed==accepted
        c["proposed"] = c["proposed"] + jnp.int32(width)
        c["accepted"] = c["accepted"] + jnp.int32(width)
    return c


def summarize_counters(counters: Optional[Dict[str, Any]]) -> dict:
    """Host ints out of the device counter pytree (empty dict for an
    uninstrumented run)."""
    if counters is None:
        return {}
    per_phase = [int(v) for v in np.asarray(counters["rounds"])]
    return {"rounds": int(sum(per_phase)),
            "rounds_per_phase": per_phase,
            "sched_size": int(counters["sched_size"]),
            "proposed": int(counters["proposed"]),
            "accepted": int(counters["accepted"]),
            "killed": int(counters["killed"])}


# ---------------------------------------------------------------------------
# SSP staleness histogram (relocated device half of repro/ps/telemetry.py)
# ---------------------------------------------------------------------------

def staleness_init(staleness: int) -> Dict[str, jnp.ndarray]:
    """Scan-carried staleness telemetry: histogram over observed read
    staleness (bins 0..s) and the running max."""
    return {"hist": jnp.zeros((staleness + 1,), jnp.int32),
            "max_staleness": jnp.int32(0)}


def observe_read(telem: Dict[str, jnp.ndarray], clock,
                 cache_clock) -> Dict[str, jnp.ndarray]:
    """Record one SSP round's read: how stale was the cache it was
    served from?  (``clock`` and ``cache_clock`` are device scalars.)"""
    st = jnp.asarray(clock, jnp.int32) - jnp.asarray(cache_clock,
                                                     jnp.int32)
    return {"hist": telem["hist"].at[st].add(1),
            "max_staleness": jnp.maximum(telem["max_staleness"], st)}
