"""The declarative streaming surface: :class:`StreamSpec`.

The paper's STRADS workers assume a fixed data shard per worker — the
engine places the data pytree once (``StradsEngine.shard_data``) and
every round reads it.  A :class:`StreamSpec` makes the *write* half of
that story declarative, exactly like :class:`~repro.serve.spec.ServeSpec`
made the read half declarative:

* **frozen + hashable** — a spec is a value, usable as a sweep key;
* **validated at construction** — every invalid kind/parameter
  combination raises here, at spec-build time, never mid-ingest;
* **JSON-round-trippable** — ``to_json``/``from_json`` are exact
  (defaults included), so specs live inside benchmark records
  (``BENCH_stream.json``) and CLI flags (``launch/serve.py --stream``).

The spec is policy only — it never names an app.  *What* an ingested
delta means (which leaves, how derived state catches up) comes from the
app's ``ingest()``/``ingest_specs()`` primitives; *where* deltas come
from is a :class:`~repro.stream.source.DataSource` bound alongside the
spec at the entry points (``execute(..., stream=, source=)``); *when*
they land is this spec's cadence — always at host-synced chunk
boundaries, the same places the partitioner rebalances and the serve
loop publishes.
"""
from __future__ import annotations

import dataclasses
import json

STREAM_KINDS = ("replace", "extend")

_KIND_MSG = "stream kind must be 'replace' or 'extend'; got {!r}"

# Which fields each kind consumes; everything else must stay at its zero
# default (a spec never carries silently-ignored knobs — the same rule
# SchedulerSpec/PartitionerSpec/ServeSpec enforce).
_FIELDS_BY_KIND = {
    "replace": ("ingest_every",),
    "extend": ("ingest_every", "capacity"),
}


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Everything the ingest path needs to know about *how* new data may
    flow into a running job.

    Fields
    ------
    kind:         ``"replace"`` (each delta names the row slots it
                  overwrites — corrected labels, refreshed ratings; the
                  data shapes and the row→worker placement never
                  change), ``"extend"`` (each delta appends rows into a
                  capacity-padded ring buffer with a validity mask —
                  new observations land in padding slots first, then
                  wrap around and overwrite the oldest rows, so data
                  shapes stay static and the compiled round programs
                  are reused, never recompiled).
    ingest_every: the ingest cadence in rounds (≥ 1).  Deltas land at
                  host-synced boundaries ``t % ingest_every == 0``; the
                  engine requires it to be a multiple of the executor's
                  step length, the same alignment rule
                  ``checkpoint_every`` obeys.
    capacity:     ring-buffer size in rows (``extend`` only; 0 = the
                  data's whole row axis).  Appends beyond it overwrite
                  the oldest rows; delta rows that can never land
                  (a single delta larger than the ring) are counted as
                  dropped.
    """

    kind: str
    ingest_every: int = 1
    capacity: int = 0

    def __post_init__(self):
        if self.kind not in STREAM_KINDS:
            raise ValueError(_KIND_MSG.format(self.kind))
        v = self.ingest_every
        if not isinstance(v, int) or isinstance(v, bool) or v < 1:
            raise ValueError(f"ingest_every must be an int >= 1; "
                             f"got {v!r}")
        v = self.capacity
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            raise ValueError(f"capacity must be an int >= 0; got {v!r}")
        used = _FIELDS_BY_KIND[self.kind]
        for field in ("capacity",):
            if field not in used and getattr(self, field):
                raise ValueError(
                    f"{field}={getattr(self, field)!r} does not apply to "
                    f"kind={self.kind!r} (leave it at its default)")

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        """A plain JSON-safe dict (every field, defaults included) —
        ``from_json(to_json(s)) == s`` exactly."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj) -> "StreamSpec":
        """Rebuild from ``to_json`` output, a JSON string, or a partial
        dict (missing fields take their defaults; unknown keys raise)."""
        if isinstance(obj, (str, bytes)):
            obj = json.loads(obj)
        if not isinstance(obj, dict):
            raise TypeError(f"StreamSpec.from_json wants a dict or JSON "
                            f"string; got {type(obj).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - known
        if unknown:
            raise ValueError(f"unknown StreamSpec field(s): "
                             f"{sorted(unknown)}")
        return cls(**obj)

    @classmethod
    def default_for(cls, kind: str, **overrides) -> "StreamSpec":
        """The conventional spec for a kind — the ONE defaults table the
        CLI surfaces (``launch/serve.py --stream-kind``) resolve
        flag-built specs from, so per-site copies cannot drift.
        ``overrides`` replace individual fields on the conventional
        base."""
        if kind == "replace":
            base = dict(kind=kind, ingest_every=1)
        elif kind == "extend":
            base = dict(kind=kind, ingest_every=1)
        else:
            raise ValueError(_KIND_MSG.format(kind))
        base.update(overrides)
        return cls(**base)
