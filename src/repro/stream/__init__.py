"""Streaming data ingest: the sixth declarative subsystem.

The paper's workers assume a fixed data shard; this package is the
"continuous operation" half the ROADMAP north star needs — new
observations flowing into a *running* job without rebuilding or
recompiling anything:

* :class:`StreamSpec` — frozen, JSON-round-trippable policy
  (``"replace"`` swaps named row slots in place, ``"extend"`` appends
  into a capacity-padded ring buffer behind a validity mask);
* :class:`DataSource` — the host-side delta feed (``peek``/``take(t)``),
  with deterministic ``(seed, t)``-derived synthetic sources so any
  worker can rebuild any delta;
* :class:`Ingestor` — applies deltas at the engine's host-synced chunk
  boundaries (where the partitioner rebalances and the serve loop
  publishes), re-placing only the changed leaves.

Like :class:`~repro.serve.spec.ServeSpec`, the spec rides the entry
points — ``StradsEngine.execute(..., stream=, source=)``,
``serve_while_training(..., stream=, source=)``, ``launch/serve.py
--stream`` — never the ExecutionPlan, so a stream knob can never be
silently ignored.  Apps opt in with the ``ingest()``/``ingest_specs()``
primitives (the ingest-injection contract in
:mod:`repro.core.primitives`).
"""
from .ingest import Ingestor, replay_data
from .source import (DataSource, EmptySource, LassoDriftSource,
                     LDADriftSource, MFDriftSource, ScheduledSource,
                     SyntheticLMSource)
from .spec import STREAM_KINDS, StreamSpec

__all__ = [
    "STREAM_KINDS", "StreamSpec",
    "DataSource", "EmptySource", "ScheduledSource",
    "LassoDriftSource", "LDADriftSource", "MFDriftSource",
    "SyntheticLMSource",
    "Ingestor", "replay_data",
]
