"""Where streamed deltas come from: the :class:`DataSource` protocol.

A source is host-side and *deterministic in* ``t`` — everything each
delta contains is derived from ``(seed, t)`` with the same regeneration
idiom as :mod:`repro.data.pipeline` (``seed * 1_000_003 + t``), so any
worker can rebuild any delta and a crashed run can replay the exact
stream it had ingested (see :func:`repro.stream.ingest.replay_data`).

The delta contract
------------------
``take(t)`` returns ``None`` (nothing due at boundary ``t``) or a
*list* of delta dicts.  Each delta carries per-row arrays with a shared
leading axis ``k``:

* ``"data"`` — ``{leaf_name: (k, ...) array}`` for every streamable
  leaf the app's ``ingest_specs()`` names (all of them, every delta);
* ``"rows"`` — ``(k,)`` int row slots to overwrite (``"replace"``
  kind only; ``"extend"`` computes slots from the ring cursor);
* app extras — additional per-row ``(k,)`` arrays some apps need to
  keep derived state consistent (LDA wants a ``"z"`` topic draw per
  ingested token).

Returning a *list* is deliberate: the :class:`~repro.stream.ingest.Ingestor`
applies the entries in order, and trajectories must depend only on the
(data, delta-schedule) pair — splitting one delta into several at the
same boundary changes nothing (property-tested in
``tests/test_stream.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, runtime_checkable

import numpy as np

from ..data.pipeline import SyntheticLMConfig, make_batch


def _delta_rows(delta: dict) -> int:
    """Leading-axis length of a delta's per-row arrays."""
    for leaf in delta.get("data", {}).values():
        return int(np.shape(leaf)[0])
    return 0


@runtime_checkable
class DataSource(Protocol):
    """Host-side feed of data-pytree deltas, polled at chunk
    boundaries."""

    def peek(self, t: int) -> int:
        """Rows due at boundary ``t`` without consuming them."""
        ...

    def take(self, t: int) -> Optional[List[dict]]:
        """The deltas due at boundary ``t`` (see the module docstring
        for the delta contract), or ``None``."""
        ...


class EmptySource:
    """The no-op source: a streamed run with it is bit-identical to an
    unstreamed ``execute()`` (proven in ``tests/test_stream.py``)."""

    def peek(self, t: int) -> int:
        return 0

    def take(self, t: int) -> Optional[List[dict]]:
        return None


class ScheduledSource:
    """A fixed ``{t: delta-or-list}`` table — the test/bench workhorse
    for handing the Ingestor an exact delta schedule."""

    def __init__(self, deltas: Dict[int, object]):
        self._deltas = {
            int(t): list(d) if isinstance(d, (list, tuple)) else [d]
            for t, d in deltas.items()}

    def peek(self, t: int) -> int:
        return sum(_delta_rows(d) for d in self._deltas.get(t, ()))

    def take(self, t: int) -> Optional[List[dict]]:
        return self._deltas.get(t)


def _rng(seed: int, t: int) -> np.random.Generator:
    # the (seed, step) regeneration idiom from repro.data.pipeline
    return np.random.default_rng(seed * 1_000_003 + t)


@dataclasses.dataclass
class LassoDriftSource:
    """Replace-kind drift for the lasso app: every ``t > 0`` boundary
    refreshes ``rows_per_ingest`` observation rows drawn from a slowly
    drifting ground-truth ``beta`` — so the objective genuinely moves
    under ingest (benchmarked in ``bench_stream.py``)."""

    num_rows: int
    num_features: int
    rows_per_ingest: int = 8
    k_true: int = 8
    noise: float = 0.1
    drift: float = 0.05
    seed: int = 0

    def _beta(self, t: int) -> np.ndarray:
        base = np.random.default_rng(self.seed)
        beta = np.zeros(self.num_features)
        idx = base.choice(self.num_features,
                          size=min(self.k_true, self.num_features),
                          replace=False)
        beta[idx] = base.normal(size=idx.size) * (1.0 + self.drift * t)
        return beta

    def peek(self, t: int) -> int:
        return self.rows_per_ingest if t > 0 else 0

    def take(self, t: int) -> Optional[List[dict]]:
        if t <= 0:
            return None
        rng = _rng(self.seed, t)
        k = min(self.rows_per_ingest, self.num_rows)
        rows = np.sort(rng.choice(self.num_rows, size=k, replace=False))
        # the lasso update rule assumes unit-L2 design columns; fresh
        # rows at the original per-entry scale 1/sqrt(n) keep column
        # norms ~1 so coordinate descent stays contractive under drift
        X = (rng.normal(size=(k, self.num_features))
             / np.sqrt(self.num_rows)).astype(np.float32)
        y = (X @ self._beta(t)
             + self.noise * rng.normal(size=k)).astype(np.float32)
        return [{"rows": rows, "data": {"X": X, "y": y}}]


@dataclasses.dataclass
class MFDriftSource:
    """Drift for the MF app: each ``t > 0`` boundary produces
    ``rows_per_ingest`` fresh user rows of low-rank-plus-noise ratings.
    ``kind="replace"`` names the user slots to refresh; ``"extend"``
    leaves slot choice to the ring cursor (new users arriving)."""

    num_rows: int
    num_cols: int
    rows_per_ingest: int = 4
    true_rank: int = 4
    density: float = 0.3
    noise: float = 0.05
    kind: str = "extend"
    seed: int = 0

    def peek(self, t: int) -> int:
        return self.rows_per_ingest if t > 0 else 0

    def take(self, t: int) -> Optional[List[dict]]:
        if t <= 0:
            return None
        base = np.random.default_rng(self.seed)
        V = base.normal(size=(self.true_rank, self.num_cols))
        rng = _rng(self.seed, t)
        k = min(self.rows_per_ingest, self.num_rows)
        U = rng.normal(size=(k, self.true_rank))
        A = (U @ V + self.noise * rng.normal(
            size=(k, self.num_cols))).astype(np.float32)
        mask = (rng.random((k, self.num_cols))
                < self.density).astype(np.float32)
        delta = {"data": {"A": A, "mask": mask}}
        if self.kind == "replace":
            delta["rows"] = np.sort(
                rng.choice(self.num_rows, size=k, replace=False))
        return [delta]


@dataclasses.dataclass
class LDADriftSource:
    """Drift for the LDA app: each ``t > 0`` boundary delivers
    ``tokens_per_ingest`` fresh tokens (word id, local doc id, and the
    initial topic draw ``z`` the collapsed counts need).  ``"extend"``
    slides the token window; ``"replace"`` resamples existing slots."""

    num_tokens: int
    vocab: int
    num_topics: int
    docs_per_worker: int
    tokens_per_ingest: int = 8
    kind: str = "extend"
    seed: int = 0

    def peek(self, t: int) -> int:
        return self.tokens_per_ingest if t > 0 else 0

    def take(self, t: int) -> Optional[List[dict]]:
        if t <= 0:
            return None
        rng = _rng(self.seed, t)
        k = min(self.tokens_per_ingest, self.num_tokens)
        words = rng.integers(0, self.vocab, size=k).astype(np.int32)
        docs = rng.integers(0, self.docs_per_worker,
                            size=k).astype(np.int32)
        z = rng.integers(0, self.num_topics, size=k).astype(np.int32)
        delta = {"data": {"words": words, "docs": docs}, "z": z}
        if self.kind == "replace":
            delta["rows"] = np.sort(
                rng.choice(self.num_tokens, size=k, replace=False))
        return [delta]


@dataclasses.dataclass
class SyntheticLMSource:
    """The :mod:`repro.data.pipeline` token stream as a
    :class:`DataSource`: one :func:`~repro.data.pipeline.make_batch`
    per boundary, derived entirely from ``(cfg.seed, t)``.
    ``repro.data.synthetic_batches`` iterates this source, so the
    trainer-facing generator and the streaming subsystem share one
    batch-derivation path."""

    cfg: SyntheticLMConfig
    kwargs: Optional[dict] = None

    def peek(self, t: int) -> int:
        return self.cfg.batch_size

    def take(self, t: int) -> Optional[List[dict]]:
        return [{"data": make_batch(self.cfg, t, **(self.kwargs or {}))}]
