"""The :class:`Ingestor`: applies :class:`~repro.stream.source.DataSource`
deltas to a running job at host-synced chunk boundaries.

The engine's chunked execution loop is the only place model state and
data are host-visible between compiled spans — the partitioner already
rebalances there, checkpoints already save there, the serve loop already
publishes there.  The Ingestor rides the same boundaries:

* ``"replace"`` overwrites the row slots each delta names, then
  re-places **only the changed leaves** with per-leaf ``device_put``
  (never a full ``shard_data`` rebuild — unchanged leaves are returned
  by the app's ``ingest()`` as the *same objects* and are left alone);
* ``"extend"`` appends rows as if one at a time into a capacity-padded
  ring buffer: new rows land in the padding slots first (the app's
  ``ingest_specs()["valid"]`` mask says which slots hold real rows at
  bind time), then wrap around and overwrite the oldest rows.  Data
  shapes never change, so the compiled round programs are reused — not
  recompiled (asserted in ``benchmarks/bench_stream.py``).

The cursor (``cursor``/``rows_in``/``rows_dropped``/``fill0``) is plain
flat numpy and rides the checkpoint payload beside ``"state"`` /
``"carry"`` / ``"assignment"``, so a mid-stream checkpoint resumes
bit-exactly: restore it with ``execute(..., stream_state=...)`` and
rebuild the data a resumed process no longer holds with
:func:`replay_data`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from .source import _delta_rows
from .spec import StreamSpec

_CURSOR_KEYS = ("cursor", "rows_in", "rows_dropped", "fill0")


def _place_changed(engine, old, new, specs):
    """Re-place only the leaves ``ingest()`` actually replaced (identity
    compare — the unchanged-leaves-are-the-same-objects contract)."""
    return jax.tree.map(
        lambda o, n, s: o if n is o else jax.device_put(
            n, NamedSharding(engine.mesh, s)),
        old, new, specs)


def _slice_delta(delta: dict, keep: int) -> dict:
    """The last ``keep`` rows of every per-row array in a delta."""
    k = _delta_rows(delta)
    if keep >= k:
        return delta
    out = {}
    for key, val in delta.items():
        if key == "data":
            out[key] = {leaf: v[k - keep:] for leaf, v in val.items()}
        elif key == "rows":
            out[key] = np.asarray(val)[k - keep:]
        else:
            out[key] = np.asarray(val)[k - keep:]
    return out


class Ingestor:
    """Binds a (:class:`StreamSpec`, :class:`DataSource`) pair to one
    engine + data pytree and applies deltas at boundaries."""

    def __init__(self, spec: StreamSpec, source):
        if not isinstance(spec, StreamSpec):
            raise TypeError(f"stream= wants a StreamSpec; "
                            f"got {type(spec).__name__}")
        if not callable(getattr(source, "take", None)):
            raise TypeError(f"source= wants a DataSource (peek/take); "
                            f"got {type(source).__name__}")
        self.spec = spec
        self.source = source
        self.cursor = 0        # extend: rows ever offered to the ring
        self.rows_in = 0       # rows actually written into the buffer
        self.rows_dropped = 0  # delta rows that could never land
        self.fill0 = 0         # extend: valid rows at bind time
        self.capacity = 0
        self._leaves: tuple = ()
        self._total_rows = 0
        self._bound = False
        self._restored = False

    # -- lifecycle -----------------------------------------------------------

    def bind(self, engine, data) -> "Ingestor":
        """Resolve the app's ingest primitives against one data pytree
        (row count, streamable leaves, initial ring fill)."""
        from ..core.primitives import StradsAppBase
        app = engine.app
        for prim in ("ingest", "ingest_specs"):
            fn = getattr(type(app), prim, None)
            if fn is None or fn is getattr(StradsAppBase, prim):
                raise NotImplementedError(
                    f"{type(app).__name__} declares no {prim}() primitive "
                    f"— streaming (repro.stream) needs ingest() and "
                    f"ingest_specs(); see the ingest-injection contract "
                    f"in repro.core.primitives")
        kinds = getattr(app, "supported_stream_kinds", None)
        if kinds is not None and self.spec.kind not in kinds:
            raise ValueError(
                f"{type(app).__name__} supports stream kinds {kinds}; "
                f"spec wants {self.spec.kind!r}")
        isp = app.ingest_specs()
        self._leaves = tuple(isp["leaves"])
        self._total_rows = int(data[self._leaves[0]].shape[0])
        if self.spec.capacity > self._total_rows:
            raise ValueError(
                f"capacity={self.spec.capacity} exceeds the data's "
                f"{self._total_rows} rows")
        self.capacity = self.spec.capacity or self._total_rows
        if self.spec.kind == "extend" and not self._restored:
            valid = isp.get("valid")
            self.fill0 = (int(np.asarray(valid(data)).sum())
                          if valid is not None else 0)
        self._bound = True
        return self

    def payload(self) -> dict:
        """The stream cursor as flat numpy — rides the checkpoint
        payload beside ``"state"``/``"carry"``/``"assignment"``."""
        return {k: np.int64(getattr(self, k)) for k in _CURSOR_KEYS}

    def restore(self, payload: dict) -> "Ingestor":
        """Adopt a checkpointed cursor (call before :meth:`bind`, or
        pass ``stream_state=`` to ``execute`` which does both)."""
        missing = [k for k in _CURSOR_KEYS if k not in payload]
        if missing:
            raise ValueError(f"stream payload missing {missing}")
        for k in _CURSOR_KEYS:
            setattr(self, k, int(np.asarray(payload[k])))
        self._restored = True
        return self

    # -- the boundary step ---------------------------------------------------

    def step(self, engine, state, data, t: int):
        """Apply whatever the source has due at boundary ``t``; returns
        the (possibly re-placed) ``(state, data)``.  A no-op — the very
        same objects back, no transfers, no RNG — when ``t`` is off
        cadence or the source has nothing, which is what makes an
        empty-source streamed run bit-identical to an unstreamed one.
        ``state=None`` applies the data-leaf writes only (the
        :func:`replay_data` path)."""
        if not self._bound:
            raise RuntimeError("Ingestor.step before bind()")
        if t % self.spec.ingest_every != 0:
            return state, data
        deltas = self.source.take(t)
        if not deltas:
            return state, data
        if isinstance(deltas, dict):
            deltas = [deltas]
        if state is not None:
            # a state restored from an npz checkpoint arrives as numpy
            # leaves; ingest primitives use functional-update (`.at`)
            # semantics, so lift to jax arrays once at the boundary
            # (a no-op returning the very same objects when the state
            # already lives on device)
            state = jax.tree_util.tree_map(jnp.asarray, state)
        with engine._obs_span("ingest", t=t, deltas=len(deltas)):
            for delta in deltas:
                rows, delta = self._slots(delta)
                if rows.size == 0:
                    continue
                new_data, new_state = engine.app.ingest(
                    data, state, rows, delta)
                data = _place_changed(engine, data, new_data,
                                      engine.data_specs)
                if state is not None:
                    state = _place_changed(engine, state, new_state,
                                           engine._sspec(state))
                engine._obs_event("ingest_rows", t=t,
                                  rows_in=int(rows.size),
                                  rows_dropped=self.rows_dropped)
        return state, data

    def _slots(self, delta: dict):
        """Row slots for one delta (+ the delta, tail-sliced if the
        ring cannot hold all of it), advancing the cursor."""
        k = _delta_rows(delta)
        if k == 0:
            return np.zeros((0,), np.int64), delta
        if self.spec.kind == "replace":
            rows = np.asarray(delta["rows"], np.int64)
            if rows.shape != (k,):
                raise ValueError(
                    f"replace delta rows shape {rows.shape} != ({k},)")
            if np.unique(rows).size != k:
                raise ValueError("replace delta rows must be unique")
            if rows.size and (rows.min() < 0
                              or rows.max() >= self._total_rows):
                raise ValueError(
                    f"replace delta rows out of range [0, "
                    f"{self._total_rows})")
            self.rows_in += k
            return rows, delta
        # extend: append as if row-by-row; a delta larger than the ring
        # keeps only its last `capacity` rows (the earlier ones would be
        # overwritten before the next round ever saw them)
        keep = min(k, self.capacity)
        dropped = k - keep
        start = self.fill0 + self.cursor + dropped
        rows = (start + np.arange(keep, dtype=np.int64)) % self.capacity
        self.cursor += k
        self.rows_in += keep
        self.rows_dropped += dropped
        return rows, _slice_delta(delta, keep)


def replay_data(engine, data, spec: StreamSpec, source,
                t_upto: int, stream_state: Optional[dict] = None):
    """Rebuild the data pytree a resumed process no longer holds:
    re-apply every boundary ``t < t_upto`` of a deterministic source to
    the *original* data (data-only — derived state comes from the
    checkpoint, never double-applied).  Returns ``(data, ingestor)``;
    the ingestor's cursor equals the checkpointed ``"stream"`` payload
    (pass it as ``stream_state=`` to verify)."""
    ing = Ingestor(spec, source).bind(engine, data)
    for t in range(0, t_upto, spec.ingest_every):
        _, data = ing.step(engine, None, data, t)
    if stream_state is not None:
        got, want = ing.payload(), stream_state
        for key in _CURSOR_KEYS:
            if int(np.asarray(want[key])) != int(got[key]):
                raise ValueError(
                    f"replayed stream cursor {key}={int(got[key])} != "
                    f"checkpointed {int(np.asarray(want[key]))} (source "
                    f"or t_upto does not match the original run)")
    return data, ing
