"""The declarative serving surface: :class:`ServeSpec`.

The SSP machinery (:mod:`repro.ps`) already defines a serving
consistency contract — a cached read may be served while it is at most
``s`` commits old — but until this subsystem nothing *read* model state
except the training round itself.  A :class:`ServeSpec` makes the read
path declarative, exactly like :class:`~repro.sched.spec.SchedulerSpec`
made scheduling policy and :class:`~repro.part.spec.PartitionerSpec`
made placement policy declarative:

* **frozen + hashable** — a spec is a value, usable as a sweep key;
* **validated at construction** — every invalid kind/parameter
  combination raises here, at spec-build time, never mid-serve;
* **JSON-round-trippable** — ``to_json``/``from_json`` are exact
  (defaults included), so specs live inside benchmark records
  (``BENCH_serve.json``) and CLI flags (``launch/serve.py
  --serve-kind``).

The spec is policy only — it never names an app.  What a query computes
comes from the app's ``query()`` primitive; where the served values come
from (the SSP worker caches / the KVStore) comes from the engine at
binding time (:class:`repro.serve.view.ModelView`).
"""
from __future__ import annotations

import dataclasses
import json

SERVE_KINDS = ("stale", "snapshot")

_KIND_MSG = "serve kind must be 'stale' or 'snapshot'; got {!r}"

# Which fields each kind consumes; everything else must stay at its zero
# default (a spec never carries silently-ignored knobs — the same rule
# SchedulerSpec/PartitionerSpec enforce).
_FIELDS_BY_KIND = {
    "stale": ("max_staleness", "max_batch", "batch_window_ms"),
    "snapshot": ("max_batch", "batch_window_ms"),
}


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Everything the read path needs to know about *how* model state
    may be served while training continues.

    Fields
    ------
    kind:           ``"stale"`` (serve the SSP-style mixed view:
                    worker-resident leaves read live at the boundary,
                    server-resident leaves through a
                    :class:`~repro.ps.cache.StaleCache` refreshed lazily
                    under the gate ``clock − cache.clock ≤
                    max_staleness`` — cheap, skips snapshot copies while
                    the bound holds), ``"snapshot"`` (pin the *entire*
                    state at each flush/chunk boundary — every leaf from
                    the same clock, a fully consistent view that stays
                    valid across training chunks, at the price of a full
                    copy per pin).
    max_staleness:  the serving staleness bound in committed rounds
                    (``stale`` only; 0 = refresh the cache at every
                    boundary, the BSP-fresh read).
    max_batch:      most requests one batched query program serves
                    (≥ 1; the micro-batching frontend assembles up to
                    this many queued requests per flush).
    batch_window_ms: how long a partial batch may wait for more
                    requests before it is served anyway (0 = serve
                    partial batches immediately).
    """

    kind: str
    max_staleness: int = 0
    max_batch: int = 1
    batch_window_ms: float = 0.0

    def __post_init__(self):
        if self.kind not in SERVE_KINDS:
            raise ValueError(_KIND_MSG.format(self.kind))
        v = self.max_staleness
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            raise ValueError(f"max_staleness must be an int >= 0; "
                             f"got {v!r}")
        v = self.max_batch
        if not isinstance(v, int) or isinstance(v, bool) or v < 1:
            raise ValueError(f"max_batch must be an int >= 1; got {v!r}")
        v = self.batch_window_ms
        if isinstance(v, bool) or not isinstance(v, (int, float)) or v < 0:
            raise ValueError(f"batch_window_ms must be a number >= 0; "
                             f"got {v!r}")
        used = _FIELDS_BY_KIND[self.kind]
        for field in ("max_staleness", "batch_window_ms"):
            if field not in used and getattr(self, field):
                raise ValueError(
                    f"{field}={getattr(self, field)!r} does not apply to "
                    f"kind={self.kind!r} (leave it at its default)")

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        """A plain JSON-safe dict (every field, defaults included) —
        ``from_json(to_json(s)) == s`` exactly."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj) -> "ServeSpec":
        """Rebuild from ``to_json`` output, a JSON string, or a partial
        dict (missing fields take their defaults; unknown keys raise)."""
        if isinstance(obj, (str, bytes)):
            obj = json.loads(obj)
        if not isinstance(obj, dict):
            raise TypeError(f"ServeSpec.from_json wants a dict or JSON "
                            f"string; got {type(obj).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - known
        if unknown:
            raise ValueError(f"unknown ServeSpec field(s): "
                             f"{sorted(unknown)}")
        return cls(**obj)

    @classmethod
    def default_for(cls, kind: str, **overrides) -> "ServeSpec":
        """The conventional spec for a kind — the ONE defaults table the
        CLI surfaces (``launch/serve.py --serve-kind``) resolve
        flag-built specs from, so per-site copies cannot drift.
        ``overrides`` replace individual fields on the conventional
        base."""
        if kind == "stale":
            base = dict(kind=kind, max_staleness=2, max_batch=8)
        elif kind == "snapshot":
            base = dict(kind=kind, max_batch=8)
        else:
            raise ValueError(_KIND_MSG.format(kind))
        base.update(overrides)
        return cls(**base)
