"""The online serving subsystem: bounded-staleness reads while training.

The SSP machinery (:mod:`repro.ps`) maintains worker caches whose reads
are at most ``s`` rounds stale — a serving consistency contract the
paper states but 2014-STRADS never exposed as a read path.  This package
exposes it, as the fifth declarative subsystem on the execution surface
(after :mod:`repro.sched`, :mod:`repro.part`, :mod:`repro.kernels` and
:mod:`repro.obs`):

* :class:`ServeSpec` (:mod:`repro.serve.spec`) — the frozen, hashable,
  JSON-round-trippable serving policy (``kind="stale" | "snapshot"``,
  ``max_staleness``, ``max_batch``, ``batch_window_ms``);
* :class:`ModelView` (:mod:`repro.serve.view`) — the read path: serves
  straight from the SSP worker caches / KVStore split
  (:class:`~repro.ps.server.ParameterServer` +
  :class:`~repro.ps.cache.StaleCache`) with a *measured*
  staleness-at-read bound;
* :class:`ServeFrontend` (:mod:`repro.serve.frontend`) — the
  micro-batching request frontend (queue, batch assembly, jitted
  per-app ``query()`` programs cached per (Assignment, KernelSpec));
* :func:`serve_while_training` / :func:`serve_only`
  (:mod:`repro.serve.loop`) — the continuous-training loop interleaving
  ``execute()`` chunks with serving reads at SSP flush boundaries,
  bit-exact for training by construction.

Apps opt in with one primitive: ``query(state, batch)`` (the
serving-injection contract in :mod:`repro.core.primitives`) — Lasso's
``predict``, LDA's ``infer_topics`` fold-in, MF's ``recommend`` top-k.
"""
from .spec import SERVE_KINDS, ServeSpec
from .view import ModelView, StaleReadError
from .frontend import Request, Response, ServeFrontend
from .loop import ServeReport, serve_only, serve_while_training

__all__ = [
    "SERVE_KINDS", "ServeSpec", "ModelView", "StaleReadError",
    "Request", "Response", "ServeFrontend", "ServeReport",
    "serve_only", "serve_while_training",
]
