"""The micro-batching request frontend: :class:`ServeFrontend`.

Requests (per-example pytrees, e.g. ``{"x": (J,)}`` for Lasso predict)
queue up between training chunks; ``flush()`` assembles them into
batches of at most ``ServeSpec.max_batch``, reads a state view from the
:class:`~repro.serve.view.ModelView`, and runs the app's batched
``query()`` primitive as one jitted program.  Batching policy:

* a *full* batch (``max_batch`` queued requests) is served immediately;
* a *partial* batch waits up to ``batch_window_ms`` for more arrivals
  (measured from its oldest request), then is served anyway;
* ``flush(force=True)`` drains everything regardless of the window
  (end of run — no more arrivals are coming).

Query programs are jitted once and cached per ``(Assignment,
KernelSpec)`` — the same key the engine's compiled round programs use —
so a partition rebalance or kernel-backend swap is one cache miss, and
a swap back is a hit.  Per-request latency (submit → response ready) and
per-batch staleness-at-read are recorded for the p50/p99 + histogram
reporting in ``launch/serve.py`` / ``BENCH_serve.json``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .spec import ServeSpec
from .view import ModelView


@dataclasses.dataclass
class Request:
    """One queued query: a per-example payload pytree + submit time."""
    payload: Any
    t_submit: float


@dataclasses.dataclass
class Response:
    """One served query: the per-example result slice + bookkeeping."""
    result: Any
    latency_ms: float
    staleness: int


class ServeFrontend:
    """Queue → batch assembly → jitted per-app query program."""

    def __init__(self, engine, view: ModelView, spec: ServeSpec,
                 recorder: Optional[Any] = None,
                 clock: Callable[[], float] = time.monotonic):
        if view.spec != spec:
            raise ValueError("the frontend and its ModelView must share "
                             "one ServeSpec")
        self.engine = engine
        self.view = view
        self.spec = spec
        self.recorder = recorder
        self._clock = clock
        self._queue: deque = deque()
        self._programs: dict = {}    # (Assignment, KernelSpec) -> jitted
        self.responses: List[Response] = []
        self.latencies_ms: List[float] = []

    # -- queue ---------------------------------------------------------------

    def submit(self, payload) -> None:
        """Enqueue one per-example query payload (no leading batch dim —
        the frontend stacks)."""
        self._queue.append(Request(payload, self._clock()))

    def pending(self) -> int:
        return len(self._queue)

    # -- the jitted query program --------------------------------------------

    def _program(self):
        # cached per (Assignment, KernelSpec): the engine rebinds both
        # between chunks, and a query traced under one configuration
        # must not serve another (same rule as the engine's round cache)
        key = (self.engine._assignment, self.engine._active_kern_spec)
        prog = self._programs.get(key)
        if prog is None:
            app = self.engine.app
            prog = jax.jit(lambda state, batch: app.query(state, batch))
            self._programs[key] = prog
            if self.recorder is not None:
                self.recorder.instant(
                    "cache_miss", program="query",
                    kernels=(key[1].kind if key[1] is not None else None))
        return prog

    # -- batch assembly + serving --------------------------------------------

    def _take_batch(self, force: bool) -> Optional[List[Request]]:
        q, spec = self._queue, self.spec
        if not q:
            return None
        if len(q) < spec.max_batch and not force:
            waited_ms = (self._clock() - q[0].t_submit) * 1e3
            if waited_ms < spec.batch_window_ms:
                return None        # partial batch still inside its window
        n = min(len(q), spec.max_batch)
        return [q.popleft() for _ in range(n)]

    def flush(self, force: bool = False) -> int:
        """Serve every batch the batching policy allows right now;
        returns the number of requests served."""
        served = 0
        while True:
            batch = self._take_batch(force)
            if batch is None:
                return served
            view_state, staleness = self.view.read()
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[r.payload for r in batch])
            span = (self.recorder.span("serve_batch", size=len(batch),
                                       staleness=staleness)
                    if self.recorder is not None
                    else contextlib.nullcontext())
            with span:
                out = self._program()(view_state, stacked)
                out = jax.block_until_ready(out)
            done = self._clock()
            for i, req in enumerate(batch):
                lat = (done - req.t_submit) * 1e3
                self.latencies_ms.append(lat)
                self.responses.append(Response(
                    result=jax.tree.map(lambda x, i=i: x[i], out),
                    latency_ms=lat, staleness=staleness))
            served += len(batch)

    # -- reporting -----------------------------------------------------------

    def latency_percentiles(self) -> dict:
        """``{"p50_ms", "p99_ms"}`` over every served request (NaN when
        nothing was served)."""
        if not self.latencies_ms:
            return {"p50_ms": float("nan"), "p99_ms": float("nan")}
        lat = np.asarray(self.latencies_ms)
        return {"p50_ms": float(np.percentile(lat, 50)),
                "p99_ms": float(np.percentile(lat, 99))}
