"""The continuous-training loop: ``serve_while_training``.

The loop interleaves :meth:`~repro.core.engine.StradsEngine.execute`
chunks with serving reads at the SSP flush boundaries: the plan is
chunked into spans of the executor's step length (for ``"ssp"`` that is
``rounds_per_step = lcm(s+1, phase_period)`` — exactly one flush window,
so every publish point *is* a flush), each span resumes the previous
one's :class:`~repro.core.engine.EngineCarry`/``SSPCarry`` (the same
bit-exact resume path checkpointing uses), and between spans the
committed state is published to the :class:`~repro.serve.view.ModelView`
and the queued requests are served.

Bit-exactness is structural, not hoped-for: serving touches training
only through ``publish`` (which copies what it keeps) — never the PRNG
stream, the scheduler carry, or the state buffers — so the final trained
state of a served run is bit-identical to an unserved ``execute()`` of
the same plan (``tests/test_serve.py`` asserts it leaf by leaf).

Streaming requests fold in by due round: ``requests`` is a sequence of
``(t_due, payload)`` pairs, submitted to the frontend at the first
boundary whose clock reaches ``t_due`` — the serving analogue of the
windowed executor folding streaming mini-batches in at flush points.

Spans/instants ride a caller-supplied :class:`~repro.obs.events.Recorder`
(``train_chunk`` spans around each executor span, ``serve_batch`` spans
+ ``serve_read``/``serve_refresh``/``serve_pin`` instants between them),
so an exported Chrome trace shows serving interleaved with training.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import contextlib

import jax
import jax.numpy as jnp

from ..core.plan import ExecutionPlan, ExecutionReport
from .frontend import ServeFrontend
from .spec import ServeSpec
from .view import ModelView


@dataclasses.dataclass
class ServeReport:
    """What a serving run produced: the training report (``None`` for
    ``serve_only``), every response, and the measured serving record."""
    report: Optional[ExecutionReport]
    responses: List[Any]
    latencies_ms: List[float]
    reads: List[dict]
    spec: ServeSpec
    #: final stream-cursor payload when training streamed data in
    #: (``serve_while_training(..., stream=, source=)``); None otherwise
    ingest: Optional[dict] = None

    def latency_percentiles(self) -> dict:
        import numpy as np
        if not self.latencies_ms:
            return {"p50_ms": float("nan"), "p99_ms": float("nan")}
        lat = np.asarray(self.latencies_ms)
        return {"p50_ms": float(np.percentile(lat, 50)),
                "p99_ms": float(np.percentile(lat, 99))}

    def staleness_hist(self) -> dict:
        hist: dict = {}
        for r in self.reads:
            hist[r["staleness"]] = hist.get(r["staleness"], 0) + 1
        return hist

    def max_staleness_read(self) -> int:
        return max((r["staleness"] for r in self.reads), default=0)


def _resolve_spec(spec, plan: Optional[ExecutionPlan]) -> ServeSpec:
    if spec is not None:
        if not isinstance(spec, ServeSpec):
            raise TypeError(f"wanted a ServeSpec; got "
                            f"{type(spec).__name__}")
        return spec
    # the conventional default ties the serving bound to the training
    # one: an SSP plan's reads are already s-stale, so serving at the
    # same bound adds no consistency loss
    s = plan.staleness if plan is not None and plan.executor == "ssp" else 0
    return ServeSpec.default_for("stale", max_staleness=s)


def _check_requests(requests) -> List[Tuple[int, Any]]:
    out = []
    for item in requests:
        if not (isinstance(item, tuple) and len(item) == 2
                and isinstance(item[0], int)):
            raise TypeError("serve_while_training wants requests as "
                            "(t_due, payload) pairs; got "
                            f"{type(item).__name__}")
        out.append(item)
    return sorted(out, key=lambda it: it[0])


def serve_while_training(engine, state, data, rng, plan: ExecutionPlan,
                         *, spec: Optional[ServeSpec] = None,
                         requests: Sequence[Tuple[int, Any]] = (),
                         collect=None, recorder=None,
                         chunk_rounds: Optional[int] = None,
                         stream=None, source=None,
                         stream_state: Optional[dict] = None
                         ) -> ServeReport:
    """Train ``plan`` to completion while serving ``requests`` between
    chunks.  Returns a :class:`ServeReport` whose ``report.state`` is
    bit-identical to ``engine.execute(state, data, rng, plan).state``.

    ``chunk_rounds`` overrides the publish cadence (must be a multiple
    of the executor's step length; default: exactly one step — for SSP,
    one flush window).

    ``stream`` (a :class:`~repro.stream.spec.StreamSpec`) + ``source``
    ingest data deltas at the same boundaries serving publishes at: each
    boundary ``t`` ingests *before* the chunk covering ``[t, t+chunk)``
    runs and before the clock-``t`` publish, the exact ordering
    ``engine.execute(..., stream=)`` uses — so a served streamed run's
    trained state is bit-identical to an unserved streamed one, and
    every published view includes all deltas due ≤ its clock.  The final
    cursor payload lands on the report as :attr:`ServeReport.ingest`."""
    spec = _resolve_spec(spec, plan)
    due = _check_requests(requests)
    step = engine._step_length(plan)
    chunk = chunk_rounds if chunk_rounds is not None else step
    if chunk < 1 or chunk % step:
        raise ValueError(f"chunk_rounds={chunk} must be a positive "
                         f"multiple of the {plan.executor!r} executor's "
                         f"step length {step}")
    for t_due, _ in due:
        if not 0 <= t_due <= plan.rounds:
            raise ValueError(f"request due round {t_due} outside the "
                             f"plan's 0..{plan.rounds}")
    if (stream is None) != (source is None):
        raise ValueError("stream= (a StreamSpec) and source= (a "
                         "DataSource) come as a pair — got only one")
    ing = None
    if stream is not None:
        from ..stream import Ingestor
        ing = Ingestor(stream, source)
        if stream_state is not None:
            ing.restore(stream_state)
        ing.bind(engine, data)
        if stream.ingest_every % chunk:
            raise ValueError(
                f"stream.ingest_every={stream.ingest_every} must be a "
                f"multiple of the serve chunk cadence {chunk} — ingest "
                f"boundaries land only where the loop syncs")
    elif stream_state is not None:
        raise ValueError("stream_state resumes a streamed run; pass "
                         "the stream=/source= pair with it")

    view = ModelView(engine, spec, recorder=recorder)
    frontend = ServeFrontend(engine, view, spec, recorder=recorder)

    def pump(t: int, force: bool) -> None:
        while due and due[0][0] <= t:
            frontend.submit(due.pop(0)[1])
        frontend.flush(force=force)

    # boundary 0 ingests first, so the clock-0 publish (serving before
    # any training commits) already includes the deltas due at 0
    if ing is not None:
        state, data = ing.step(engine, state, data, 0)
    view.publish(state, 0)
    pump(0, force=False)

    carry = None
    traces = []
    t = 0
    rep = None
    while t < plan.rounds:
        target = min(t + chunk, plan.rounds)
        span = (recorder.span("train_chunk", t0=t, t1=target)
                if recorder is not None else contextlib.nullcontext())
        with span:
            rep = engine.execute(state, data, rng,
                                 dataclasses.replace(plan, rounds=target),
                                 collect=collect, carry=carry)
        state, carry = rep.state, rep.carry
        rng = carry.rng
        t = int(carry.t)
        if rep.trace is not None:
            traces.append(rep.trace)
        if ing is not None and t < plan.rounds:
            state, data = ing.step(engine, state, data, t)
        view.publish(state, t)
        pump(t, force=(t >= plan.rounds))

    trace = (jax.tree.map(lambda *xs: jnp.concatenate(xs), *traces)
             if traces else None)
    report = ExecutionReport(state=state, trace=trace,
                             telemetry=rep.telemetry if rep is not None
                             else None, carry=carry, plan=plan,
                             stream=ing.payload() if ing is not None
                             else None)
    return ServeReport(report=report, responses=frontend.responses,
                       latencies_ms=frontend.latencies_ms,
                       reads=view.reads, spec=spec,
                       ingest=ing.payload() if ing is not None else None)


def serve_only(engine, state, *, spec: Optional[ServeSpec] = None,
               requests: Sequence[Any] = (), t: int = 0,
               recorder=None) -> ServeReport:
    """Serve ``requests`` (plain payloads, no due rounds) from a fixed
    trained state — the no-training baseline arm of ``BENCH_serve``.
    ``t`` stamps the clock the state is committed through."""
    spec = _resolve_spec(spec, None)
    view = ModelView(engine, spec, recorder=recorder)
    frontend = ServeFrontend(engine, view, spec, recorder=recorder)
    view.publish(state, t)
    for payload in requests:
        frontend.submit(payload)
    frontend.flush(force=True)
    return ServeReport(report=None, responses=frontend.responses,
                       latencies_ms=frontend.latencies_ms,
                       reads=view.reads, spec=spec)
