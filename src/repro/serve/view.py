"""The serving read path: :class:`ModelView`.

A ModelView is the bridge between the training loop and the request
frontend: training *publishes* committed state at its flush/chunk
boundaries (the only points where state is host-synced — the same
boundaries the partitioner and checkpointer already use), and serving
*reads* a view of the model whose consistency the
:class:`~repro.serve.spec.ServeSpec` declares:

* ``kind="stale"`` reuses the SSP read machinery verbatim: the
  server-resident leaves (the replicated KVStore half,
  :meth:`~repro.ps.server.ParameterServer.snapshot`) are served through
  a :class:`~repro.ps.cache.StaleCache`, refreshed lazily under the SSP
  gate ``clock − cache.clock ≤ max_staleness``; the worker-resident
  leaves come from the live state at the boundary (the read-my-writes
  half of SSP).  A read is therefore exactly as consistent as a worker's
  own training read — bounded staleness, verified at read time.
* ``kind="snapshot"`` pins the *entire* state (copied) at each publish,
  so the view is internally consistent (every leaf from the same clock)
  and stays valid across training chunks even when the executor donates
  the state buffers.

Every read is logged as ``{"t", "clock", "staleness"}`` — the measured
staleness-at-read is the quantity the acceptance bar (and the hypothesis
property test) is stated over, not an assumption.

Reads never write: the view holds copies (or boundary-scoped references)
of state and touches neither the training PRNG stream nor the engine
carry, which is what makes ``serve_while_training`` bit-identical to an
unserved ``execute()``.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from ..ps.cache import StaleCache
from ..ps.server import ParameterServer
from .spec import ServeSpec


class StaleReadError(RuntimeError):
    """A read was attempted that the ServeSpec's consistency contract
    cannot serve (nothing published yet, or the staleness gate failed to
    hold — the latter indicates a bug, since publish refreshes under the
    gate)."""


def _copy_tree(tree):
    # Served values must survive the executor donating the training
    # state's buffers on the next chunk, so pins/caches hold copies.
    return jax.tree.map(lambda x: jnp.array(x, copy=True), tree)


class ModelView:
    """A bounded-staleness view of an engine's model state.

    ``publish(state, t)`` is called by the training side at every
    flush/chunk boundary with the committed state and the round clock;
    ``read()`` returns ``(state_view, staleness_at_read)`` for the query
    programs.  The view never mutates what it is given.
    """

    def __init__(self, engine, spec: ServeSpec,
                 recorder: Optional[Any] = None):
        if not isinstance(spec, ServeSpec):
            raise TypeError(f"ModelView wants a ServeSpec; got "
                            f"{type(spec).__name__}")
        self.engine = engine
        self.spec = spec
        self.recorder = recorder
        self._server: Optional[ParameterServer] = None
        self._cache: Optional[StaleCache] = None   # stale: server leaves
        self._state = None                         # stale: boundary state
        self._pinned = None                        # snapshot: full state
        self._pinned_clock = 0
        self._clock = 0          # committed training rounds at last publish
        self.reads: List[dict] = []

    # -- the training side ---------------------------------------------------

    def publish(self, state, t: int) -> None:
        """Make the state committed through round ``t`` servable.  Must
        be called at a host boundary (state live on this side of any
        donation)."""
        self._clock = int(t)
        if self.spec.kind == "snapshot":
            self._pinned = _copy_tree(state)
            self._pinned_clock = self._clock
            if self.recorder is not None:
                self.recorder.instant("serve_pin", t=self._clock)
            return
        if self._server is None:
            app = self.engine.app
            self._server = ParameterServer.from_state(
                self.engine.mesh, state, app.state_specs(),
                roles=app.var_roles())
        self._state = state
        if self._cache is None or not bool(
                self._cache.fresh_enough(self._clock,
                                         self.spec.max_staleness)):
            # the SSP gate would be violated at this clock: refresh the
            # cache from the server-resident leaves (the "pull")
            self._cache = StaleCache(
                values=_copy_tree(self._server.snapshot(state)),
                clock=jnp.asarray(self._clock, jnp.int32))
            if self.recorder is not None:
                self.recorder.instant("serve_refresh", t=self._clock,
                                      nbytes=self._server.shared_nbytes())

    # -- the serving side ----------------------------------------------------

    @property
    def clock(self) -> int:
        """Committed training rounds as of the last publish."""
        return self._clock

    def read(self):
        """Serve one read: returns ``(state_view, staleness_at_read)``
        and logs the measured staleness.  ``stale`` merges the (possibly
        stale) server cache over the boundary state; ``snapshot``
        returns the pinned copy."""
        if self.spec.kind == "snapshot":
            if self._pinned is None:
                raise StaleReadError("read before the first publish — "
                                     "nothing is pinned yet")
            staleness = self._clock - self._pinned_clock
            view = self._pinned
        else:
            if self._cache is None:
                raise StaleReadError("read before the first publish — "
                                     "the serving cache is empty")
            staleness = int(self._cache.staleness(self._clock))
            if staleness > self.spec.max_staleness:
                raise StaleReadError(
                    f"staleness-at-read {staleness} exceeds the spec "
                    f"bound {self.spec.max_staleness} — publish() must "
                    f"run at every boundary")
            view = self._server.merge(self._state, self._cache.values)
        rec = {"t": self._clock,
               "clock": self._clock - staleness,
               "staleness": staleness}
        self.reads.append(rec)
        if self.recorder is not None:
            self.recorder.instant("serve_read", **rec)
        return view, staleness

    # -- measured-staleness accounting ---------------------------------------

    def staleness_hist(self) -> dict:
        """``{staleness: read count}`` over every read served so far —
        the BENCH_serve histogram."""
        hist: dict = {}
        for r in self.reads:
            hist[r["staleness"]] = hist.get(r["staleness"], 0) + 1
        return hist

    def max_staleness_read(self) -> int:
        """The worst staleness any read observed (0 when nothing was
        read)."""
        return max((r["staleness"] for r in self.reads), default=0)
