"""The pluggable scheduler subsystem.

``schedule()`` is the paper's headline primitive; this package makes the
scheduling *policy* a first-class, declarative part of the execution
surface:

* :class:`SchedulerSpec` (:mod:`repro.sched.spec`) — the frozen,
  hashable, JSON-round-trippable policy value that rides
  ``ExecutionPlan.scheduler``;
* :class:`Scheduler` (:mod:`repro.sched.protocol`) — the formal
  ``init_carry / propose / finalize / update_carry / mark_scheduled``
  contract every policy implements;
* :mod:`repro.sched.schedulers` — the five policies (round-robin,
  random, rotation, dynamic priority, block structural) sharing ONE
  greedy ρ-dependency filter with two gram backends (data Gram /
  structural graph distance);
* :mod:`repro.sched.block` — trainer-side block-coordinate helpers
  (``launch/train.py --strads``).

``repro.core.schedulers`` and ``repro.core.block_scheduler`` remain as
deprecation shims re-exporting from here.
"""
from .spec import SCHEDULER_KINDS, SchedulerSpec
from .protocol import Scheduler, SchedulerBase
from .schedulers import (BlockStructuralScheduler, DynamicPriorityScheduler,
                         RandomScheduler, RotationScheduler,
                         RoundRobinScheduler, build_scheduler,
                         dependency_filter, priority_weights,
                         sample_candidates, structural_gram)
from . import block

__all__ = [
    "SCHEDULER_KINDS", "SchedulerSpec", "Scheduler", "SchedulerBase",
    "BlockStructuralScheduler", "DynamicPriorityScheduler",
    "RandomScheduler", "RotationScheduler", "RoundRobinScheduler",
    "build_scheduler", "dependency_filter", "priority_weights",
    "sample_candidates", "structural_gram", "block",
]
