"""Schedulers: the paper's ``schedule`` strategies behind one protocol.

* :class:`RoundRobinScheduler` — fixed cyclic blocks (STRADS MF; and the
  Lasso-cyclic baseline).
* :class:`RandomScheduler` — uniform random blocks (the Shotgun /
  Lasso-RR baseline, which diverges on correlated designs at large U).
* :class:`RotationScheduler` — word-rotation over U disjoint blocks
  (STRADS LDA): worker p owns block ``(p + t) mod U`` at round t, so every
  worker touches every block once per U rounds and concurrently-sampled
  variables stay disjoint.
* :class:`DynamicPriorityScheduler` — the STRADS Lasso strategy: sample U'
  candidates with probability c_j ∝ |x_j^(t-1) − x_j^(t-2)| + η, then
  greedily keep a subset of size ≤ U whose pairwise dependencies are below
  ρ (|x_jᵀx_k| < ρ), preventing the divergence of naive parallel CD on
  correlated designs (Bradley et al., 2011).
* :class:`BlockStructuralScheduler` — the same f₁/f₂ rules at layer-block
  granularity: priorities from update norms, and the ρ filter applied to
  a *structural* gram (graph distance standing in for |x_jᵀx_k| — for
  deep nets the dependency surrogate is structural, not data-dependent,
  so it costs nothing at runtime).  See :mod:`repro.sched.block` for the
  trainer-side helpers built on it.

All five implement the :class:`~repro.sched.protocol.Scheduler` protocol
(``init_carry`` / ``propose`` / ``finalize`` / ``update_carry`` /
``mark_scheduled``); the engine builds them from a declarative
:class:`~repro.sched.spec.SchedulerSpec` via :func:`build_scheduler`.

Everything is shape-static so it jits: candidate sets have fixed size U′,
the filtered schedule is a fixed-size index vector with a validity mask.
Scheduler state lives on-device as an explicit carry the *engine* owns
(:class:`~repro.core.engine.EngineCarry.sched_carry`) — never host-side,
and no longer an app-state stowaway.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .protocol import SchedulerBase
from .spec import SchedulerSpec


# ---------------------------------------------------------------------------
# Static schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundRobinScheduler(SchedulerBase):
    """Cyclic fixed-size blocks over J variables.

    Round t schedules indices ``[t*U, ..., (t+1)*U) mod J``.
    """
    num_vars: int
    block_size: int

    def __call__(self, t: jax.Array) -> jax.Array:
        start = (t * self.block_size) % self.num_vars
        idx = (start + jnp.arange(self.block_size)) % self.num_vars
        return idx

    def propose(self, carry, rng, t, phase):
        return self(t)


@dataclasses.dataclass(frozen=True)
class RandomScheduler(SchedulerBase):
    """Uniform random block (the Shotgun / Lasso-RR baseline)."""
    num_vars: int
    block_size: int

    def __call__(self, rng: jax.Array) -> jax.Array:
        return jax.random.choice(
            rng, self.num_vars, shape=(self.block_size,), replace=False)

    def propose(self, carry, rng, t, phase):
        return self(rng)


@dataclasses.dataclass(frozen=True)
class RotationScheduler(SchedulerBase):
    """Word-rotation over U disjoint variable blocks (STRADS LDA).

    ``block_for_worker(p, t) = (p + t) mod U``.  Blocks are the contiguous
    partition of ``num_vars`` into U chunks; chunk u is
    ``[bounds[u], bounds[u+1])``.  The rotation's communication pattern is
    exposed as *static* permutation lists (``forward_perm`` /
    ``backward_perm``) because the LDA ``lax.ppermute`` needs a static
    permutation per phase.
    """
    num_vars: int
    num_workers: int

    @property
    def bounds(self) -> jnp.ndarray:
        edges = jnp.linspace(0, self.num_vars, self.num_workers + 1)
        return jnp.round(edges).astype(jnp.int32)

    def block_for_worker(self, p: jax.Array, t: jax.Array) -> jax.Array:
        return (p + t) % self.num_workers

    def block_mask(self, block: jax.Array) -> jax.Array:
        """Boolean mask of shape (num_vars,): which vars are in ``block``."""
        b = self.bounds
        j = jnp.arange(self.num_vars)
        return (j >= b[block]) & (j < b[block + 1])

    def forward_perm(self, phase: int) -> list:
        """Static ppermute pairs sending block d to its phase-t worker."""
        U = self.num_workers
        return [((d + phase) % U, d) for d in range(U)]

    def backward_perm(self, phase: int) -> list:
        """Static ppermute pairs sending each processed block home."""
        U = self.num_workers
        return [(d, (d + phase) % U) for d in range(U)]

    def propose(self, carry, rng, t, phase):
        # the rotation is implicit in the app's communication pattern
        return None

    def finalize(self, candidates, stats):
        return candidates, None


# ---------------------------------------------------------------------------
# Dynamic priority + dependency filter (STRADS Lasso)
# ---------------------------------------------------------------------------

def priority_weights(delta: jax.Array, eta: float) -> jax.Array:
    """c_j ∝ |Δx_j| + η  (paper §3.3, f₁)."""
    return jnp.abs(delta) + eta


def sample_candidates(rng: jax.Array, weights: jax.Array,
                      num_candidates: int) -> jax.Array:
    """Draw U′ distinct candidates ∝ weights via Gumbel top-k.

    Gumbel-top-k gives exact sampling-without-replacement from the
    categorical distribution ∝ weights, fully vectorized (no rejection
    loop), which is what makes the dynamic schedule cheap on-device.
    """
    logits = jnp.log(jnp.maximum(weights, 1e-30))
    g = jax.random.gumbel(rng, weights.shape, dtype=logits.dtype)
    _, idx = jax.lax.top_k(logits + g, num_candidates)
    return idx


def dependency_filter(gram: jax.Array, rho: float,
                      max_select: int) -> jax.Array:
    """Greedy ρ-dependency filter (paper §3.3, f₂) — ONE implementation
    for both dependency backends.

    ``gram`` is the U′×U′ candidate correlation block: |x_jᵀx_k| with
    standardized columns for the data-dependent (Gram) backend, or the
    0/1 :func:`structural_gram` for the graph-distance backend.  Greedily
    admit candidates in order; candidate i joins iff its correlation with
    every admitted candidate is < ρ.  Returns a boolean keep-mask of
    shape (U′,) with at most ``max_select`` True entries.  O(U′²),
    matching the paper's cost argument (U′² ≪ J²).
    """
    u = gram.shape[0]
    absg = jnp.abs(gram)

    def body(i, carry):
        keep, count = carry
        # max correlation with already-kept candidates (exclude self)
        conflict = jnp.max(jnp.where(keep, absg[i], 0.0))
        ok = (conflict < rho) & (count < max_select)
        keep = keep.at[i].set(ok)
        return keep, count + ok.astype(jnp.int32)

    keep0 = jnp.zeros((u,), dtype=bool)
    # candidate 0 always admitted (count starts at 0, conflict max over
    # empty set = 0 < rho)
    keep, _ = jax.lax.fori_loop(0, u, body, (keep0, jnp.int32(0)))
    return keep


def structural_gram(candidates: jax.Array,
                    min_distance: int) -> jax.Array:
    """The graph-distance dependency surrogate: a 0/1 "correlation" block
    where candidates closer than ``min_distance`` (adjacent layers, whose
    gradients flow through each other) count as fully correlated.  Feeds
    :func:`dependency_filter` exactly like the data Gram block does —
    any ρ ∈ (0, 1] then admits precisely the distance-filtered set."""
    d = jnp.abs(candidates[:, None] - candidates[None, :])
    return (d < min_distance).astype(jnp.float32)


def _compact_schedule(candidates: jax.Array, keep: jax.Array,
                      block_size: int) -> tuple[jax.Array, jax.Array]:
    """Compact the kept candidates to the front; pad with the first kept
    index (masked out downstream)."""
    order = jnp.argsort(~keep)          # kept first, stable
    idx = candidates[order][:block_size]
    mask = keep[order][:block_size]
    return idx, mask


@dataclasses.dataclass(frozen=True)
class DynamicPriorityScheduler(SchedulerBase):
    """STRADS Lasso scheduler: priority sampling + Gram dependency filter.

    ``propose`` samples U′ candidates ∝ the carry (the Δx history); the
    application computes the candidate Gram block (a distributed psum
    over data shards — its ``schedule_stats``, dispatched through the
    plan-resolved kernel backend, so ``plan.kernels`` decides whether
    the X_CᵀX_C hot-spot runs the reference jnp oracle or the fused
    Pallas ``gram_block``); ``finalize`` applies the ρ filter and
    returns ``(indices, mask)`` — a static-size schedule.
    """
    num_vars: int
    num_candidates: int      # U'
    block_size: int          # U  (≤ num_candidates)
    rho: float = 0.1
    eta: float = 1e-6

    needs_stats = True

    # -- carry: the Δx history driving the priorities c_j -------------------
    # A plain (J,) array so it rides the engine carry without wrappers.
    # Host code must never own it: the scanned executors keep it on-device
    # across all R rounds, and it checkpoints/resumes via EngineCarry.

    def init_carry(self) -> jax.Array:
        """Uniform priority at t=0 (every variable equally likely)."""
        return jnp.ones((self.num_vars,), jnp.float32)

    def update_carry(self, carry: jax.Array, idx: jax.Array,
                     mask: jax.Array, dx: jax.Array) -> jax.Array:
        """Fold round t's updates Δx into the history: scheduled-and-kept
        entries take |Δx|, everything else keeps its previous priority."""
        return carry.at[idx].set(
            jnp.where(mask, jnp.abs(dx), jnp.take(carry, idx)))

    def propose(self, carry: jax.Array, rng: jax.Array, t=None,
                phase: int = 0) -> jax.Array:
        c = priority_weights(carry, self.eta)
        return sample_candidates(rng, c, self.num_candidates)

    def finalize(self, candidates: jax.Array,
                 gram: jax.Array) -> tuple[jax.Array, jax.Array]:
        keep = dependency_filter(gram, self.rho, self.block_size)
        return _compact_schedule(candidates, keep, self.block_size)

    def mark_scheduled(self, carry: jax.Array,
                       candidates: jax.Array) -> jax.Array:
        """SSP in-flight exclusion: candidates already proposed in this
        staleness window drop to the η floor, so later stale proposals
        pick fresh coordinates instead of compounding the same deferred
        update (the divergence mode of stale CD)."""
        if candidates is None:
            return carry
        return carry.at[candidates].set(jnp.zeros((), carry.dtype))


@dataclasses.dataclass(frozen=True)
class BlockStructuralScheduler(SchedulerBase):
    """Layer-block scheduling: dynamic priorities + the structural ρ
    filter (graph distance instead of the data Gram — dependency between
    blocks is adjacency, known statically).

    The carry is the per-block priority table (EMA of update norms).
    ``finalize`` ignores ``stats``: the dependency surrogate is
    :func:`structural_gram`, so no distributed statistics pass is needed.
    """
    num_blocks: int
    block_size: int          # U  — blocks per step
    num_candidates: int      # U' ≥ U
    min_distance: int = 2
    rho: float = 0.5         # any value in (0,1] is equivalent (0/1 gram)
    eta: float = 1e-3
    ema: float = 0.9

    def init_carry(self) -> jax.Array:
        return jnp.ones((self.num_blocks,), jnp.float32)

    def propose(self, carry: jax.Array, rng: jax.Array, t=None,
                phase: int = 0) -> jax.Array:
        return sample_candidates(rng, carry + self.eta,
                                 self.num_candidates)

    def finalize(self, candidates: jax.Array,
                 stats=None) -> tuple[jax.Array, jax.Array]:
        gram = structural_gram(candidates, self.min_distance)
        keep = dependency_filter(gram, self.rho, self.block_size)
        return _compact_schedule(candidates, keep, self.block_size)

    def keep_mask(self, candidates: jax.Array) -> jax.Array:
        """The uncompacted (U′,) keep mask — the trainer scatters it onto
        the (num_blocks,) 0/1 schedule mask (see
        :func:`repro.sched.block.select_blocks`)."""
        gram = structural_gram(candidates, self.min_distance)
        return dependency_filter(gram, self.rho, self.block_size)

    def update_carry(self, carry: jax.Array, idx: jax.Array,
                     mask: jax.Array, dx: jax.Array) -> jax.Array:
        """EMA of per-block update magnitude; only scheduled blocks
        observed an update, the rest keep their stale priority."""
        norms = jnp.zeros_like(carry).at[idx].set(
            jnp.where(mask, jnp.abs(dx), jnp.take(carry, idx)))
        new = self.ema * carry + (1 - self.ema) * norms
        sel = jnp.zeros_like(carry, bool).at[idx].set(mask)
        return jnp.where(sel, new, carry)

    def mark_scheduled(self, carry, candidates):
        if candidates is None:
            return carry
        return carry.at[candidates].set(jnp.zeros((), carry.dtype))


# ---------------------------------------------------------------------------
# Spec → scheduler (the injection registry)
# ---------------------------------------------------------------------------

def build_scheduler(spec: SchedulerSpec, *, num_vars: int,
                    num_workers: int):
    """Materialize the policy a :class:`SchedulerSpec` declares for a
    concrete app: ``num_vars`` is the app's schedulable-variable count
    (``StradsAppBase.num_schedulable()``), ``num_workers`` the data-mesh
    width.  The spec stays app-agnostic; this is the one place structure
    meets policy."""
    if not isinstance(spec, SchedulerSpec):
        raise TypeError(f"build_scheduler wants a SchedulerSpec; got "
                        f"{type(spec).__name__}")
    if spec.num_candidates > num_vars:
        raise ValueError(
            f"spec.num_candidates={spec.num_candidates} exceeds the "
            f"app's {num_vars} schedulable variables (top-U′ sampling "
            f"needs U′ <= J)")
    if spec.block_size > num_vars:
        raise ValueError(
            f"spec.block_size={spec.block_size} exceeds the app's "
            f"{num_vars} schedulable variables (a block larger than J "
            f"would schedule duplicates)")
    if spec.kind == "round_robin":
        return RoundRobinScheduler(num_vars, spec.block_size)
    if spec.kind == "random":
        return RandomScheduler(num_vars, spec.block_size)
    if spec.kind == "rotation":
        return RotationScheduler(num_vars, num_workers)
    if spec.kind == "dynamic_priority":
        return DynamicPriorityScheduler(
            num_vars=num_vars, num_candidates=spec.num_candidates,
            block_size=spec.block_size, rho=spec.rho, eta=spec.eta)
    # "block_structural" (spec validation admits nothing else)
    return BlockStructuralScheduler(
        num_blocks=num_vars, block_size=spec.block_size,
        num_candidates=spec.num_candidates,
        min_distance=spec.min_distance, rho=spec.rho, eta=spec.eta,
        ema=spec.ema)
