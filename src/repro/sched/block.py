"""Beyond-paper: STRADS block-coordinate scheduling for deep-net training.

The 2014 paper schedules *individual* model variables (Lasso coefficients,
word-topic rows).  A 2026 Big Model has billions of parameters organized
into natural blocks — transformer layers, MoE experts, embedding slices.
This module transplants the paper's DynamicPriority schedule to those
blocks:

* priority  c_b ∝ ‖Δθ_b‖ + η            (the Lasso f₁ rule, per block)
* dependency filter: adjacent layers are "correlated" (their gradients
  flow through each other); we avoid co-scheduling blocks closer than
  ``min_distance`` — the *same* greedy ρ filter as the Lasso scheduler
  (:func:`repro.sched.schedulers.dependency_filter`), fed the
  :func:`~repro.sched.schedulers.structural_gram` (graph distance
  standing in for |x_jᵀx_k|; for deep nets the dependency surrogate is
  structural, not data-dependent, so it costs nothing at runtime).
* push/pull: the optimizer update for unscheduled blocks is masked to
  zero, so per step only the scheduled blocks move — block-coordinate
  descent over the network.

The MoE router is the same idea executed at token granularity (router =
schedule, expert FFN = push, weighted combine = pull, all_to_all = sync);
see models/moe.py.

:class:`BlockScheduleConfig` remains the trainer-facing surface
(``launch/train.py --strads``, ``train/step.py``); it round-trips to the
declarative :class:`~repro.sched.spec.SchedulerSpec` via
:func:`config_from_spec` / :meth:`BlockScheduleConfig.to_spec`, so one
plan file can drive the block-scheduled trainer too.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .schedulers import dependency_filter, sample_candidates, structural_gram
from .spec import SchedulerSpec


def _leaf_name(path) -> str:
    """'/'-joined pytree key path (the one flattened-path-name helper —
    same convention as checkpoint/npz and core/kvstore)."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


@dataclasses.dataclass(frozen=True)
class BlockScheduleConfig:
    num_blocks: int
    blocks_per_step: int          # U
    candidates_per_step: int      # U' ≥ U
    min_distance: int = 2         # dependency filter radius (layers)
    eta: float = 1e-3             # exploration floor (paper's η)
    ema: float = 0.9              # priority EMA decay
    rho: float = 0.5              # threshold over the 0/1 structural gram

    def to_spec(self) -> SchedulerSpec:
        """The declarative twin (``kind="block_structural"``) — what a
        plan file carries instead of this trainer config."""
        return SchedulerSpec(kind="block_structural",
                             block_size=self.blocks_per_step,
                             num_candidates=self.candidates_per_step,
                             rho=self.rho, eta=self.eta,
                             min_distance=self.min_distance, ema=self.ema)


def config_from_spec(spec: SchedulerSpec,
                     num_blocks: int) -> BlockScheduleConfig:
    """Materialize the trainer config a ``block_structural`` spec
    declares (``num_blocks`` is structural — it comes from the model
    layout, never the spec)."""
    if spec.kind != "block_structural":
        raise ValueError(f"the block-coordinate trainer needs a "
                         f"kind='block_structural' spec; got {spec.kind!r}")
    return BlockScheduleConfig(
        num_blocks=num_blocks,
        blocks_per_step=min(spec.block_size, num_blocks),
        candidates_per_step=min(spec.num_candidates, num_blocks),
        min_distance=spec.min_distance, eta=spec.eta, ema=spec.ema,
        rho=spec.rho)


def init_priority(cfg: BlockScheduleConfig) -> jax.Array:
    """Uniform initial priorities (all blocks equally urgent)."""
    return jnp.ones((cfg.num_blocks,), jnp.float32)


def select_blocks(cfg: BlockScheduleConfig, priority: jax.Array,
                  rng: jax.Array) -> jax.Array:
    """schedule(): returns a (num_blocks,) 0/1 mask of blocks to update.

    Priority sampling (f₁) then the shared greedy ρ filter (f₂) over the
    structural gram — the duplicated distance-filter loop this module
    used to carry is gone."""
    cand = sample_candidates(rng, priority + cfg.eta, cfg.candidates_per_step)
    keep = dependency_filter(structural_gram(cand, cfg.min_distance),
                             cfg.rho, cfg.blocks_per_step)
    mask0 = jnp.zeros((cfg.num_blocks,), jnp.float32)
    return mask0.at[cand].set(keep.astype(jnp.float32))


def update_priority(cfg: BlockScheduleConfig, priority: jax.Array,
                    block_update_norms: jax.Array,
                    scheduled: jax.Array) -> jax.Array:
    """pull-side bookkeeping: EMA of per-block update magnitude.

    Only scheduled blocks observed an update this step; unscheduled blocks
    keep their stale priority (they will decay toward rescheduling via η)."""
    new = cfg.ema * priority + (1 - cfg.ema) * block_update_norms
    return jnp.where(scheduled > 0, new, priority)


def mask_updates_by_block(updates: Any, block_of_param: Dict[str, int],
                          mask: jax.Array) -> Any:
    """Zero the optimizer update of every parameter whose block is
    unscheduled.  ``block_of_param`` maps flattened param path → block id."""
    flat = jax.tree_util.tree_flatten_with_path(updates)
    leaves, treedef = flat
    out = []
    for path, leaf in leaves:
        b = block_of_param.get(_leaf_name(path), None)
        out.append(leaf if b is None else leaf * mask[b])
    return jax.tree_util.tree_unflatten(treedef, out)


def block_norms(updates: Any, block_of_param: Dict[str, int],
                num_blocks: int) -> jax.Array:
    """Per-block L2 norm of the (pre-mask) updates — feeds priorities."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(updates)
    sq = jnp.zeros((num_blocks,), jnp.float32)
    for path, leaf in leaves:
        b = block_of_param.get(_leaf_name(path), None)
        if b is not None:
            sq = sq.at[b].add(jnp.sum(jnp.square(leaf).astype(jnp.float32)))
    return jnp.sqrt(sq)
