"""The declarative scheduling surface: :class:`SchedulerSpec`.

``schedule()`` is the paper's headline primitive — STRADS's claim is that
*scheduling policy* (priority sampling + ρ-dependency filtering, Lee et
al. 2014 §3.3; block-level structure-aware scheduling, Lee et al. 2013)
is what buys the convergence speedups.  A :class:`SchedulerSpec` makes
that policy a declarative value on the :class:`~repro.core.ExecutionPlan`
exactly like the executor choice already is:

* **frozen + hashable** — a spec is a value, usable as a sweep key;
* **validated at construction** — every invalid kind/parameter
  combination raises here, at spec-build time, never at trace time;
* **JSON-round-trippable** — ``to_json``/``from_json`` are exact
  (defaults included), so specs live inside checked-in plan files
  (``examples/plans/``), benchmark records (``BENCH_sched.json``) and
  CLI flags (``launch/dryrun.py --scheduler/--rho``).

The spec is policy only — it never names an app.  Structural dimensions
(how many schedulable variables, how many workers) come from the app and
mesh at injection time (``repro.sched.build_scheduler``), so one spec
sweeps across lasso/LDA/MF unchanged.
"""
from __future__ import annotations

import dataclasses
import json

SCHEDULER_KINDS = ("round_robin", "random", "rotation", "dynamic_priority",
                   "block_structural")

_KIND_MSG = ("scheduler kind must be 'round_robin', 'random', 'rotation', "
             "'dynamic_priority' or 'block_structural'; got {!r}")

# Which fields each kind consumes; everything else must stay at its zero
# default (a spec never carries silently-ignored knobs).
_FIELDS_BY_KIND = {
    "round_robin": ("block_size",),
    "random": ("block_size",),
    "rotation": (),
    "dynamic_priority": ("block_size", "num_candidates", "rho", "eta"),
    "block_structural": ("block_size", "num_candidates", "rho", "eta",
                         "min_distance", "ema"),
}


@dataclasses.dataclass(frozen=True)
class SchedulerSpec:
    """Everything the engine needs to know about *which* variables to
    schedule each round.

    Fields
    ------
    kind:           ``"round_robin"`` (fixed cyclic blocks — STRADS MF),
                    ``"random"`` (uniform blocks, the Shotgun / Lasso-RR
                    baseline), ``"rotation"`` (disjoint block rotation —
                    STRADS LDA), ``"dynamic_priority"`` (priority sampling
                    + Gram ρ-filter — STRADS Lasso, paper §3.3),
                    ``"block_structural"`` (dynamic priorities with the
                    graph-distance ρ-filter — the beyond-paper deep-net
                    block scheduler).
    block_size:     U — concurrent updates per round (0 for ``rotation``,
                    whose blocks are the worker partition).
    num_candidates: U′ — proposal pool for the dynamic kinds (≥ U).
    rho:            ρ — dependency threshold (> 0; values > 1 disable
                    the filter, a legal degenerate sweep point).  For
                    ``dynamic_priority`` the Gram bound |x_jᵀx_k| < ρ;
                    for ``block_structural`` the threshold over the 0/1
                    structural gram (any value in (0, 1] admits exactly
                    the distance-filtered set — ``min_distance`` is the
                    real knob there, 0.5 the conventional value).
    eta:            η — exploration floor added to the priorities
                    (dynamic kinds only; ≥ 0).
    min_distance:   graph-distance radius of the structural filter
                    (``block_structural`` only): blocks closer than this
                    are never co-scheduled.
    ema:            priority EMA decay for ``block_structural`` (the
                    trainer folds per-block update norms into priorities
                    with this decay; 0 ≤ ema < 1).
    """

    kind: str
    block_size: int = 0
    num_candidates: int = 0
    rho: float = 0.0
    eta: float = 0.0
    min_distance: int = 0
    ema: float = 0.0

    def __post_init__(self):
        if self.kind not in SCHEDULER_KINDS:
            raise ValueError(_KIND_MSG.format(self.kind))
        for field in ("block_size", "num_candidates", "min_distance"):
            v = getattr(self, field)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                raise ValueError(f"{field} must be an int >= 0; got {v!r}")
        for field in ("rho", "eta", "ema"):
            v = getattr(self, field)
            if isinstance(v, bool) or not isinstance(v, (int, float)) \
                    or v < 0:
                raise ValueError(f"{field} must be a number >= 0; "
                                 f"got {v!r}")
        used = _FIELDS_BY_KIND[self.kind]
        for field in ("block_size", "num_candidates", "rho", "eta",
                      "min_distance", "ema"):
            if field not in used and getattr(self, field):
                raise ValueError(
                    f"{field}={getattr(self, field)!r} does not apply to "
                    f"kind={self.kind!r} (leave it at its default)")
        if "block_size" in used and self.block_size < 1:
            raise ValueError(f"kind={self.kind!r} needs block_size >= 1; "
                             f"got {self.block_size!r}")
        if "num_candidates" in used:
            if self.num_candidates < self.block_size:
                raise ValueError(
                    f"num_candidates (U') must be >= block_size (U); got "
                    f"U'={self.num_candidates} < U={self.block_size}")
            if self.rho <= 0:
                raise ValueError(
                    f"kind={self.kind!r} needs rho > 0 (rho = 0 admits "
                    f"no candidate at all; rho > 1 is legal and disables "
                    f"the filter); got {self.rho!r}")
        if self.kind == "block_structural":
            if self.min_distance < 1:
                raise ValueError(f"block_structural needs min_distance "
                                 f">= 1; got {self.min_distance!r}")
            if not 0 <= self.ema < 1:
                raise ValueError(f"ema must be in [0, 1); got "
                                 f"{self.ema!r}")

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        """A plain JSON-safe dict (every field, defaults included) —
        ``from_json(to_json(s)) == s`` exactly."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj) -> "SchedulerSpec":
        """Rebuild from ``to_json`` output, a JSON string, or a partial
        dict (missing fields take their defaults; unknown keys raise)."""
        if isinstance(obj, (str, bytes)):
            obj = json.loads(obj)
        if not isinstance(obj, dict):
            raise TypeError(f"SchedulerSpec.from_json wants a dict or JSON "
                            f"string; got {type(obj).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - known
        if unknown:
            raise ValueError(f"unknown SchedulerSpec field(s): "
                             f"{sorted(unknown)}")
        return cls(**obj)

    @classmethod
    def default_for(cls, kind: str, block_size: int = 32,
                    num_candidates: int = 0,
                    **overrides) -> "SchedulerSpec":
        """The conventional spec for a kind — the ONE defaults table the
        CLI surfaces (``dryrun --scheduler``, ``train --scheduler``)
        resolve flag-built specs from, so per-site copies cannot drift.
        ``overrides`` replace individual fields on the conventional
        base."""
        if kind == "rotation":
            base = dict(kind=kind)
        elif kind in ("round_robin", "random"):
            base = dict(kind=kind, block_size=block_size)
        elif kind == "dynamic_priority":
            base = dict(kind=kind, block_size=block_size,
                        num_candidates=num_candidates or 4 * block_size,
                        rho=0.3, eta=1e-6)
        elif kind == "block_structural":
            base = dict(kind=kind, block_size=block_size,
                        num_candidates=num_candidates or 2 * block_size,
                        rho=0.5, eta=1e-3, min_distance=2, ema=0.9)
        else:
            raise ValueError(_KIND_MSG.format(kind))
        base.update(overrides)
        return cls(**base)
