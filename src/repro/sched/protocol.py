"""The formal :class:`Scheduler` protocol (the v2 scheduler-injection
contract).

Before this module the protocol was an informal convention buried in
docstrings: schedulers "should" expose ``init_carry``/``update_carry``
and the engine hoped the app threaded the carry through its state
pytree.  Now it is a typed contract the engine drives directly:

    carry  = scheduler.init_carry()                 # once per run
    cand   = scheduler.propose(carry, rng, t, phase)
    idx, m = scheduler.finalize(cand, stats)        # stats = psum'd Gram
    carry' = scheduler.update_carry(carry, idx, m, dx)

* ``init_carry`` returns the scheduler's on-device state (e.g. the Δx
  priority history) or ``None`` for stateless policies.  The engine owns
  the carry: it rides :class:`~repro.core.engine.EngineCarry` /
  :class:`~repro.ps.ssp.SSPCarry` (never the app state pytree), so it
  checkpoints, resumes and donates with the rest of the executor carry.
* ``propose`` draws the candidate set from the carry (shape-static: U′
  indices).  Stateless kinds derive it from ``t``/``rng`` alone.
* ``finalize`` applies the dependency filter to the candidates given the
  distributed statistics (``schedule_stats`` psum — the candidate Gram
  block for the data-dependent filter, ignored by the structural one)
  and returns ``(indices, mask)``, a fixed-size schedule.
* ``update_carry`` folds the committed update magnitudes ``dx`` of the
  scheduled block back into the carry (identity for stateless kinds).
* ``mark_scheduled`` is the SSP in-flight exclusion hook: zero the
  priority of candidates already proposed inside the current staleness
  window so later (≤ s-stale) proposals pick fresh variables instead of
  compounding the same deferred update.

Every scheduler is a frozen dataclass — a hashable value, safe as part
of a jit cache key — and every method is jit-traceable with shape-static
outputs.
"""
from __future__ import annotations

from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

Carry = Any          # scheduler scan carry (pytree or None)
Candidates = Any     # proposal output (usually an int index vector)


@runtime_checkable
class Scheduler(Protocol):
    """The pluggable scheduling policy (built from a
    :class:`~repro.sched.spec.SchedulerSpec` by
    :func:`~repro.sched.build_scheduler`)."""

    #: True when ``finalize`` needs distributed schedule statistics (the
    #: app's ``schedule_stats`` psum — e.g. the candidate Gram block).
    needs_stats: bool

    def init_carry(self) -> Carry: ...

    def propose(self, carry: Carry, rng: jax.Array, t: jax.Array,
                phase: int) -> Candidates: ...

    def finalize(self, candidates: Candidates,
                 stats: Any) -> tuple[jax.Array, jax.Array]: ...

    def update_carry(self, carry: Carry, idx: jax.Array, mask: jax.Array,
                     dx: jax.Array) -> Carry: ...

    def mark_scheduled(self, carry: Carry,
                       candidates: Candidates) -> Carry: ...


class SchedulerBase:
    """Stateless defaults: no carry, no stats, full-block mask."""

    needs_stats = False

    def init_carry(self) -> Optional[Any]:
        return None

    def finalize(self, candidates, stats):
        """Identity filter: keep the whole candidate block."""
        return candidates, jnp.ones(jnp.shape(candidates), bool)

    def update_carry(self, carry, idx, mask, dx):
        return carry

    def mark_scheduled(self, carry, candidates):
        return carry
