"""Serving steps: batched prefill + autoregressive decode.

``decode_32k`` / ``long_500k`` dry-run shapes lower ``decode_step`` — one
new token against a ``cache_len`` KV cache / recurrent state.  For full-
attention architectures ``long_500k`` uses the sliding-window variant
(ring-buffer cache of ``LONG_WINDOW`` slots), which is what makes the
shape sub-quadratic; SSM/hybrid archs carry O(1) recurrent state instead
(their "cache_len" only sizes the attention slots they do have, if any).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models import model as M

# Sliding-window width used for long-context decode on attention archs.
LONG_WINDOW = 8192


def make_prefill_step(cfg, cache_len: int, window: Optional[int] = None):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, cache_len=cache_len,
                         window=window)
    return prefill_step


def make_decode_step(cfg, window: Optional[int] = None):
    def decode_step(params, cache, token, pos):
        return M.decode_step(cfg, params, cache, token, pos, window=window)
    return decode_step


def greedy_generate(cfg, params, batch: Dict[str, jax.Array], *,
                    steps: int, cache_len: int,
                    window: Optional[int] = None,
                    rng: Optional[jax.Array] = None,
                    temperature: float = 0.0) -> jax.Array:
    """Prefill then generate ``steps`` tokens (greedy or sampled).

    Returns (B, steps) int32.  Runs as a lax.scan over decode steps, so it
    jits into a single program — this is the serving driver the examples
    use."""
    logits, cache = M.prefill(cfg, params, batch, cache_len=cache_len,
                              window=window)
    n_front = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    start = batch["tokens"].shape[1] + n_front

    def pick(lg, key):
        lg = lg[:, :cfg.vocab_size]
        if temperature <= 0.0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(key, lg / temperature).astype(jnp.int32)

    key = rng if rng is not None else jax.random.PRNGKey(0)
    tok0 = pick(logits, key)

    def step(carry, i):
        cache, tok, key = carry
        key, sub = jax.random.split(key)
        lg, cache = M.decode_step(cfg, params, cache, tok,
                                  jnp.int32(start) + i, window=window)
        nxt = pick(lg, sub)
        return (cache, nxt, key), tok

    (_, _, _), toks = jax.lax.scan(step, (cache, tok0, key),
                                   jnp.arange(steps, dtype=jnp.int32))
    return jnp.moveaxis(toks, 0, 1)                       # (B, steps)
