"""Training steps.

* ``make_train_step`` — standard full-parameter AdamW step (the dry-run
  lowers this for the ``train_4k`` shape).
* ``make_strads_train_step`` — the paper's technique as a first-class
  trainer feature: a DynamicPriority block scheduler (repro.sched.block)
  picks which layer-blocks receive optimizer updates each step
  (schedule), per-block update norms are the partial results (push), the
  masked AdamW commit is the aggregation (pull), and SPMD program order
  is the BSP sync.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sched.block import (BlockScheduleConfig, init_priority,
                           select_blocks, update_priority)
from ..models import model as M
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from .losses import cross_entropy, token_accuracy


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None
    peak_lr: float = 3e-4
    microbatches: int = 1            # grad accumulation (llama4-class fit)
    accum_dtype: str = "bfloat16"    # grad accumulator dtype


def _lr(tc: TrainConfig, step: jax.Array) -> jax.Array:
    if tc.schedule is None:
        return jnp.asarray(tc.peak_lr, jnp.float32)
    return tc.schedule(step)


def init_train_state(cfg, tc: TrainConfig, rng: jax.Array) -> Dict[str, Any]:
    params = M.init_params(cfg, rng)
    return {"params": params, "opt": adamw_init(params, tc.adamw),
            "step": jnp.zeros((), jnp.int32)}


def loss_fn(cfg, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = M.forward(cfg, params, batch, train=True)
    label_mask = batch.get("label_mask")
    ce, _ = cross_entropy(logits, batch["labels"], cfg.vocab_size,
                          label_mask)
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux,
                  "acc": token_accuracy(logits, batch["labels"],
                                        cfg.vocab_size)}


def _accumulated_grads(cfg, tc: TrainConfig, params, batch):
    """Grad accumulation over ``tc.microbatches`` via lax.scan: live
    activation footprint shrinks ×microbatches (the fit-enabler for the
    400B-class train_4k dry-run); grads accumulate in ``accum_dtype``."""
    mb = tc.microbatches
    split = lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
    batches = jax.tree_util.tree_map(split, batch)
    adt = jnp.dtype(tc.accum_dtype)
    acc0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, adt), params)

    def mb_step(acc, mbatch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, mbatch), has_aux=True)(params)
        acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(adt), acc, grads)
        return acc, (loss, metrics)

    acc, (losses, metricses) = jax.lax.scan(mb_step, acc0, batches)
    grads = jax.tree_util.tree_map(lambda a: a / mb, acc)
    loss = jnp.mean(losses)
    metrics = jax.tree_util.tree_map(jnp.mean, metricses)
    return loss, metrics, grads


def make_train_step(cfg, tc: TrainConfig):
    def train_step(state, batch):
        if tc.microbatches > 1:
            loss, metrics, grads = _accumulated_grads(
                cfg, tc, state["params"], batch)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch),
                has_aux=True)(state["params"])
        lr = _lr(tc, state["step"])
        new_p, new_opt, gnorm = adamw_update(
            grads, state["opt"], state["params"], lr, tc.adamw)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return ({"params": new_p, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)
    return train_step


# ---------------------------------------------------------------------------
# STRADS block-coordinate training
# ---------------------------------------------------------------------------

def layer_blocks(cfg, params) -> Tuple[Dict[str, int], int]:
    """Assign every parameter to a block: one block per layer-group scan
    step (plus one for embeddings/head/shared)."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    if cfg.family == "ssm":
        num_layer_blocks = cfg.num_layers
    else:
        from ..models.transformer import group_layout
        num_layer_blocks, _ = group_layout(cfg)
    mapping: Dict[str, int] = {}
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if name.startswith("layers/layer_"):          # unrolled xlstm
            mapping[name] = int(name.split("_")[1].split("/")[0])
        elif name.startswith("layers/"):
            mapping[name] = -1                        # scanned: per-step mask
        else:
            mapping[name] = num_layer_blocks          # embeddings & co
    return mapping, num_layer_blocks + 1


def make_strads_train_step(cfg, tc: TrainConfig, sched: BlockScheduleConfig,
                           staleness: int = 0):
    """Block-coordinate variant.  State gains "priority" and "rng".

    For scanned stacks the per-layer mask is applied along the stacked
    leading dim (every layer-group leaf has shape (steps, ...)); for
    unrolled stacks the block_of_param mapping is used.

    ``staleness > 0`` is the SSP-style stale-schedule read (repro.ps): a
    fresh block schedule is adopted only every ``staleness + 1`` steps
    and served from the cached copy in between, so the priorities a
    schedule acts on are up to ``staleness`` steps old (state gains a
    "mask" cache; scheduled blocks then see several consecutive updates,
    the block-coordinate analogue of an SSP window).  ``staleness=0``
    adopts a fresh schedule every step — the original behavior."""
    refresh = staleness + 1

    def train_step(state, batch):
        rng, sub = jax.random.split(state["rng"])
        fresh_mask = select_blocks(sched, state["priority"], sub)
        if staleness:
            mask = jnp.where(state["step"] % refresh == 0,
                             fresh_mask, state["mask"])
        else:
            mask = fresh_mask

        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(state["params"])

        def mask_updates(updates):
            def leaf(path, u):
                name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                                for p in path)
                if name.startswith("layers/layer_"):
                    b = int(name.split("_")[1].split("/")[0])
                    return u * mask[b]
                if name.startswith("layers/"):        # scanned (steps, ...)
                    m = mask[:u.shape[0]].reshape(
                        (u.shape[0],) + (1,) * (u.ndim - 1))
                    return u * m.astype(u.dtype)
                return u * mask[-1]
            return jax.tree_util.tree_map_with_path(leaf, updates)

        def norms(updates):
            sq = jnp.zeros((sched.num_blocks,), jnp.float32)
            for path, u in jax.tree_util.tree_flatten_with_path(updates)[0]:
                name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                                for p in path)
                uf = jnp.square(u.astype(jnp.float32))
                if name.startswith("layers/layer_"):
                    b = int(name.split("_")[1].split("/")[0])
                    sq = sq.at[b].add(jnp.sum(uf))
                elif name.startswith("layers/"):
                    per = jnp.sum(uf, axis=tuple(range(1, u.ndim)))
                    sq = sq.at[:u.shape[0]].add(per)
                else:
                    sq = sq.at[-1].add(jnp.sum(uf))
            return jnp.sqrt(sq)

        lr = _lr(tc, state["step"])
        # capture pre-mask updates for priorities via a small closure hack:
        captured = {}
        def mask_and_capture(updates):
            captured["norms"] = norms(updates)
            return mask_updates(updates)
        new_p, new_opt, gnorm = adamw_update(
            grads, state["opt"], state["params"], lr, tc.adamw,
            update_mask=mask_and_capture)
        priority = update_priority(sched, state["priority"],
                                   captured["norms"], mask)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr,
                       blocks_active=jnp.sum(mask))
        out = {"params": new_p, "opt": new_opt, "step": state["step"] + 1,
               "priority": priority, "rng": rng}
        if staleness:
            out["mask"] = mask
        return (out, metrics)

    return train_step


def init_strads_state(cfg, tc: TrainConfig, sched: BlockScheduleConfig,
                      rng: jax.Array, staleness: int = 0) -> Dict[str, Any]:
    r1, r2 = jax.random.split(rng)
    st = init_train_state(cfg, tc, r1)
    st["priority"] = init_priority(sched)
    st["rng"] = r2
    if staleness:
        # step 0 always recomputes (0 % refresh == 0): any init works
        st["mask"] = jnp.zeros((sched.num_blocks,), jnp.float32)
    return st
