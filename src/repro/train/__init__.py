from .losses import cross_entropy, token_accuracy  # noqa: F401
from .step import TrainConfig, make_train_step, make_strads_train_step, \
    init_train_state  # noqa: F401
from .serve import make_prefill_step, make_decode_step, greedy_generate  # noqa: F401
