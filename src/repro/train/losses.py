"""Token-level losses over the padded-vocab logits.

Vocab padding (sharding/rules.padded_vocab) is masked to −inf before the
softmax so the normalizer only runs over real classes."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _mask_pad(logits: jax.Array, vocab_size: int) -> jax.Array:
    vp = logits.shape[-1]
    if vp == vocab_size:
        return logits
    mask = jnp.arange(vp) < vocab_size
    return jnp.where(mask, logits, -1e30)


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_size: int,
                  label_mask: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Mean CE over (B,S) tokens.  Returns (loss, denominator)."""
    lf = _mask_pad(logits.astype(jnp.float32), vocab_size)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if label_mask is None:
        label_mask = jnp.ones_like(nll)
    denom = jnp.maximum(jnp.sum(label_mask), 1.0)
    return jnp.sum(nll * label_mask) / denom, denom


def token_accuracy(logits: jax.Array, labels: jax.Array, vocab_size: int
                   ) -> jax.Array:
    lf = _mask_pad(logits.astype(jnp.float32), vocab_size)
    return jnp.mean((jnp.argmax(lf, -1) == labels).astype(jnp.float32))
