"""Summarize, validate, and re-export saved telemetry artifacts.

    PYTHONPATH=src python -m repro.launch.trace <artifact.json> [...]
    PYTHONPATH=src python -m repro.launch.trace <artifact.json> --check
    PYTHONPATH=src python -m repro.launch.trace <artifact.json> \
        --chrome out.trace.json --jsonl out.jsonl

An artifact is any JSON file carrying a :class:`~repro.obs.report
.RunReport` — a bare ``report.to_json()`` dump, a dry-run engine record
(``launch/dryrun.py --telemetry``/``--plan`` puts one under
``"run_report"``), or a ``BENCH_obs.json`` entry.  The CLI prints each
report's :meth:`~repro.obs.report.RunReport.summary` and, with
``--check``, enforces the observability contract offline:

* the file parses and the spec round-trips
  (:func:`~repro.obs.report.report_from_json`);
* the device-counter identities hold — per-phase round totals sum to
  the run's rounds and the ρ-filter ledger balances
  (``accepted + killed == proposed``, all non-negative);
* the host event log is strictly nested with non-negative durations
  (:func:`~repro.obs.events.validate_spans`) — exactly what a Chrome
  trace viewer needs to render it as a flame graph.

``--chrome``/``--jsonl`` re-export the (first) report's event log; the
Chrome file loads in ``chrome://tracing`` / Perfetto.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import List, Optional, Tuple

from ..obs.events import validate_spans, write_chrome_trace, write_jsonl
from ..obs.report import RunReport, report_from_json

def extract_report_dicts(obj) -> List[dict]:
    """Every RunReport dict found in a loaded artifact — the object
    itself when it *is* one (a ``to_json()`` dump has spec + executor +
    counters), else a full recursive walk, so embedded sections (a
    dry-run record's ``"run_report"``, a BENCH entry's ``"telemetry"``)
    are found wherever the artifact put them."""
    if isinstance(obj, dict):
        if ("spec" in obj and "executor" in obj and "counters" in obj
                and isinstance(obj["spec"], dict)):
            return [obj]
        return [d for v in obj.values()
                for d in extract_report_dicts(v)]
    if isinstance(obj, list):
        return [d for item in obj for d in extract_report_dicts(item)]
    return []


def check_report(rep: RunReport) -> Optional[str]:
    """``None`` when the report honors the counter identities and the
    span-nesting contract, else the first violated clause."""
    c = rep.counters
    if c:
        for k in ("rounds", "sched_size", "proposed", "accepted",
                  "killed"):
            if c.get(k, 0) < 0:
                return f"counter {k!r} is negative ({c[k]})"
        if sum(c.get("rounds_per_phase", [])) != c.get("rounds", 0):
            return (f"phase-counter totals {c['rounds_per_phase']} do "
                    f"not sum to rounds {c['rounds']}")
        if c.get("accepted", 0) + c.get("killed", 0) != \
                c.get("proposed", 0):
            return (f"rho-filter ledger unbalanced: accepted "
                    f"{c['accepted']} + killed {c['killed']} != proposed "
                    f"{c['proposed']}")
    err = validate_spans(rep.events)
    if err is not None:
        return err
    if rep.ssp is not None:
        hist = [int(v) for v in rep.ssp.hist]
        if any(v < 0 for v in hist):
            return f"ssp staleness histogram has negative bins {hist}"
        if c and sum(hist) != c.get("rounds", 0):
            return (f"ssp staleness histogram covers {sum(hist)} rounds "
                    f"but the counters ran {c['rounds']}")
    return None


def load_reports(path: str) -> Tuple[List[RunReport], Optional[str]]:
    """(reports, error) for one artifact file — parse errors come back
    as the error string instead of raising, so --check can report them
    uniformly."""
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [], f"unreadable ({e})"
    dicts = extract_report_dicts(obj)
    if not dicts:
        return [], "no RunReport section found"
    try:
        return [report_from_json(d) for d in dicts], None
    except (KeyError, ValueError, TypeError) as e:
        return [], f"malformed RunReport ({e!r})"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize (and --check) the RunReport telemetry "
                    "recorded in saved artifact JSON files.")
    ap.add_argument("paths", nargs="+",
                    help="artifact JSON paths or globs")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every report parses, its counter "
                         "identities hold, and its spans are strictly "
                         "nested with non-negative durations")
    ap.add_argument("--chrome", default="",
                    help="write the first report's event log as a Chrome "
                         "trace-event file (chrome://tracing / Perfetto)")
    ap.add_argument("--jsonl", default="",
                    help="write the first report's event log as JSONL")
    args = ap.parse_args(argv)

    files: List[str] = []
    for p in args.paths:
        hits = sorted(glob.glob(p))
        files.extend(hits if hits else [p])

    bad: List[str] = []
    first: Optional[RunReport] = None
    for path in files:
        name = os.path.basename(path)
        reports, err = load_reports(path)
        if err is not None:
            print(f"{name}: {err}")
            bad.append(name)
            continue
        for rep in reports:
            if first is None:
                first = rep
            verdict = check_report(rep)
            print(f"{name}:")
            for line in rep.summary().splitlines():
                print(f"  {line}")
            if verdict is None:
                print("  [ok]")
            else:
                print(f"  [INVALID: {verdict}]")
                bad.append(name)
    if not files:
        print("no artifacts matched")
        return 1
    if first is not None:
        if args.chrome:
            print(f"chrome trace → "
                  f"{write_chrome_trace(first.events, args.chrome)}")
        if args.jsonl:
            print(f"jsonl → {write_jsonl(first.events, args.jsonl)}")
    elif args.chrome or args.jsonl:
        print("nothing to export: no report parsed")
        return 1
    if args.check and bad:
        print(f"--check failed: {len(bad)}/{len(files)} artifact(s) "
              f"with missing, malformed, or invalid telemetry: "
              f"{sorted(set(bad))}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
