import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init); everything else follows.
"""Multi-pod dry-run: ``.lower().compile()`` every (architecture × input
shape) on the production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch chatglm3-6b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

``--engine lasso|lda|mf`` instead lowers the multi-round STRADS executor
(``StradsEngine.run_scanned``) on a worker mesh carved from the forced
512-device topology — proving that R rounds × U workers compile into ONE
XLA program (scan + psum + donated state) at production scale:

    PYTHONPATH=src python -m repro.launch.dryrun --engine lasso \
        --workers 16 --rounds 16 --pipeline-depth 1

``--staleness s`` lowers the bounded-staleness SSP program
(``StradsEngine.run_ssp`` — worker caches, lazy pushes, batched flush
collectives) instead of the BSP scan:

    PYTHONPATH=src python -m repro.launch.dryrun --engine lda \
        --workers 16 --rounds 16 --staleness 2

``--scheduler``/``--rho``, ``--partitioner`` and ``--kernels`` override
the app's default scheduling/partitioning/kernel-backend policies from
flags; the resolved ``SchedulerSpec``/``PartitionerSpec``/``KernelSpec``
dicts (and the initial variable→worker assignment's shape) are recorded
in the artifact, along with the trip-count-aware HLO analysis and the
roofline terms (``launch/roofline.py`` renders/checks them):

    PYTHONPATH=src python -m repro.launch.dryrun --engine lasso \
        --workers 16 --rounds 16 --kernels pallas

``--plan plan.json`` (with ``--engine``) AOT-lowers a declarative
:class:`repro.core.ExecutionPlan` instead of the per-flag form — the
plan's executor/rounds/staleness/workers/scheduler/partitioner drive
the lowering and the plan dict is recorded in the result JSON:

    PYTHONPATH=src python -m repro.launch.dryrun --engine lasso \
        --plan examples/plans/ssp_s2.json

Streaming ingest (:mod:`repro.stream`) needs no dry-run mode of its
own: deltas land between compiled spans at host-synced boundaries, and
the ``"extend"`` ring keeps data shapes static, so a streamed run lowers
*exactly* the programs the unstreamed plan lowers — e.g.
``examples/plans/serve_stream.json`` (the CI-smoked serving+streaming
plan) dry-runs like any other SSP plan.

Results land in ``benchmarks/results/dryrun/<arch>__<shape>__<mesh>[__tag]
.json`` (existing files are skipped unless --force), which
``benchmarks/roofline.py`` renders into EXPERIMENTS.md §Dry-run/§Roofline.
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import ARCHS, INPUT_SHAPES, get_config
from ..sharding.rules import activation_mesh
from . import roofline as RL
from .mesh import make_production_mesh
from .specs import build, skip_reason

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")
# --engine records have a different schema (no arch/shape/mesh keys), so
# they live beside — not inside — the dryrun dir that roofline_report
# globs for its tables.
ENGINE_RESULTS_DIR = os.path.join(os.path.dirname(RESULTS_DIR), "engine")


def _result_path(arch, shape, mesh_name, tag):
    name = f"{arch}__{shape}__{mesh_name}"
    if tag:
        name += f"__{tag}"
    return os.path.join(RESULTS_DIR, name + ".json")


def run_one(arch: str, shape_name: str, mesh_name: str, tag: str = "",
            keep_hlo: bool = False) -> dict:
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.size
    out = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": chips, "tag": tag or "baseline"}

    reason = skip_reason(arch, shape_name)
    if reason:
        out["skipped"] = reason
        return out

    from .specs import apply_variant
    cfg = apply_variant(get_config(arch), tag or "baseline")
    shp = INPUT_SHAPES[shape_name]
    spec = build(arch, shape_name, mesh, variant=tag or "baseline")
    out["meta"] = spec.meta

    t0 = time.time()
    with activation_mesh(mesh):
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         donate_argnums=spec.donate_argnums)
        lowered = jitted.lower(*spec.args)
    out["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    out["compile_s"] = round(time.time() - t0, 2)

    # --- memory analysis (proves it fits) --------------------------------
    try:
        ma = compiled.memory_analysis()
        mem = {k: int(getattr(ma, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes") if hasattr(ma, k)}
        mem["total_per_device"] = (mem.get("argument_size_in_bytes", 0)
                                   + mem.get("temp_size_in_bytes", 0)
                                   + mem.get("output_size_in_bytes", 0)
                                   - mem.get("alias_size_in_bytes", 0))
        out["memory"] = mem
    except Exception as e:                                   # pragma: no cover
        out["memory"] = {"error": repr(e)}

    # --- cost analysis (per-partition FLOPs / bytes) ---------------------
    try:
        ca = compiled.cost_analysis()
        out["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes": float(ca.get("bytes accessed", 0.0)),
                       "transcendentals":
                           float(ca.get("transcendentals", 0.0))}
    except Exception as e:                                   # pragma: no cover
        out["cost"] = {"error": repr(e)}

    # --- full HLO analysis (loop-trip-count aware) ------------------------
    # XLA:CPU cost_analysis counts while bodies once; analyze_hlo walks the
    # call graph and charges every dot/collective by its enclosing trip
    # counts — see roofline.py.
    hlo = compiled.as_text()
    out["hlo_bytes"] = len(hlo)
    ana = RL.analyze_hlo(hlo, chips)
    out["hlo_analysis"] = ana.to_json()
    if keep_hlo:
        path = _result_path(arch, shape_name, mesh_name, tag) + ".hlo"
        with open(path, "w") as f:
            f.write(hlo)

    # --- roofline terms ---------------------------------------------------
    out["roofline"] = RL.roofline_terms(ana.flops, ana.bytes,
                                        ana.wire_bytes)
    out["roofline_raw_cost_analysis"] = RL.roofline_terms(
        out["cost"].get("flops", 0.0), out["cost"].get("bytes", 0.0),
        ana.wire_bytes)
    useful = RL.model_flops(cfg, shp)
    out["model_flops"] = useful
    hlo_flops_global = ana.flops * chips
    out["useful_flops_ratio"] = (useful / hlo_flops_global
                                 if hlo_flops_global else 0.0)
    return out


def _build_engine(engine: str, workers: int, mesh):
    """(eng, state, data, meta) for one of the three paper apps at a
    dry-run-friendly scale."""
    import numpy as np

    rng = np.random.default_rng(0)
    if engine == "lasso":
        from ..apps import lasso
        n, J = workers * 64, 1024
        X, y, _ = lasso.synthetic_correlated(rng, n=n, J=J, k_true=16)
        # The DEFAULT policy keeps the historical dry-run workload
        # (U=32, U'=128, rho=0.3 — a representative dynamic schedule, so
        # engine artifacts stay comparable across PRs), but it is no
        # longer baked in: run_engine resolves plan.scheduler /
        # --scheduler / --rho over this default via eng.set_scheduler
        # and records the spec that actually lowered in the artifact.
        cfg = lasso.LassoConfig(num_features=J, lam=0.02, block_size=32,
                                num_candidates=128)
        eng = lasso.make_engine(cfg, mesh)
        data = eng.shard_data({"X": X, "y": y})
        state = eng.init_state(jax.random.key(0), y=y)
        return eng, state, data, {"n": n, "J": J}
    if engine == "lda":
        from ..apps import lda
        cfg = lda.LDAConfig(vocab=workers * 64, num_topics=32,
                            num_workers=workers, tokens_per_worker=256,
                            docs_per_worker=16)
        words, docs, z0 = lda.synthetic_corpus(rng, cfg, true_topics=8)
        eng = lda.make_engine(cfg, mesh)
        data = eng.shard_data({"words": words, "docs": docs})
        state = eng.init_state(jax.random.key(0), words=words, docs=docs,
                               z0=z0)
        return eng, state, data, {"vocab": cfg.vocab,
                                  "topics": cfg.num_topics}
    if engine == "mf":
        from ..apps import mf
        N, M, K = workers * 64, 512, 16
        A, mask = mf.synthetic_ratings(rng, N, M, true_rank=K,
                                       density=0.2)
        cfg = mf.MFConfig(num_rows=N, num_cols=M, rank=K, lam=0.05)
        eng = mf.make_engine(cfg, mesh)
        data = eng.shard_data({"A": A, "mask": mask})
        state = eng.init_state(jax.random.key(0), A=A, mask=mask)
        return eng, state, data, {"N": N, "M": M, "K": K}
    raise ValueError(f"unknown engine {engine!r}")


def engine_rounds(engine: str, workers: int, rounds: int,
                  staleness, unroll: int = 1) -> int:
    """Rounds actually lowered: the SSP program needs a whole number of
    lcm(staleness+1, phase_period) steps, the scanned program a whole
    number of phase_period × unroll steps — round up either way (the
    result names the artifact, keeping the skip-cache key honest)."""
    import math
    period = workers if engine == "lda" else {"lasso": 1, "mf": 2}[engine]
    L = (period * unroll if staleness is None
         else math.lcm(staleness + 1, period))
    return -(-rounds // L) * L


def run_engine(engine: str, workers: int, rounds: int, depth: int,
               staleness=None, unroll: int = 1, scheduler=None,
               sched_kind: str = "", rho=None, partitioner=None,
               part_kind: str = "", kernels=None,
               kern_kind: str = "", telemetry=None) -> dict:
    """Lower + compile the scanned (or, with ``staleness``, the SSP)
    STRADS executor on a ``workers``-wide data mesh (a slice of the
    forced-512 topology).  ``rounds`` must already be step-aligned
    (see :func:`engine_rounds`).  ``scheduler`` is an optional
    :class:`repro.sched.SchedulerSpec` overriding the app default;
    ``sched_kind``/``rho`` are the flag form, resolved against the app's
    own ``default_scheduler_spec()`` (so ``--rho`` alone moves only the
    threshold).  ``partitioner``/``part_kind`` do the same for the
    :class:`repro.part.PartitionerSpec` (flag form built by
    ``PartitionerSpec.default_for``), and ``kernels``/``kern_kind`` for
    the :class:`repro.kernels.KernelSpec` serving the round body's
    hot-spots.  ``telemetry`` (a :class:`repro.obs.TelemetrySpec`)
    instruments the lowering: the device counters ride the lowered
    program's scan carry (proving the instrumented program compiles at
    production scale), ``kind="trace"`` times the lower/compile phases
    with a host :class:`~repro.obs.events.Recorder`, and the resolved
    spec + a :class:`~repro.obs.report.RunReport` land in the artifact
    (``roofline --check``/``launch.trace`` read them back).  The
    resolved spec dicts — and the initial variable→worker assignment's
    shape — are recorded in the result, plus the trip-count-aware HLO
    analysis and roofline terms."""
    import numpy as np
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:workers]), ("data",))
    eng, state, data, meta = _build_engine(engine, workers, mesh)
    if scheduler is None and (sched_kind or rho is not None):
        scheduler = _override_spec(eng.app.default_scheduler_spec(),
                                   sched_kind, rho)
    eng.set_scheduler(scheduler)               # None → app default
    if partitioner is None and part_kind:
        from ..part import PartitionerSpec
        partitioner = PartitionerSpec.default_for(part_kind)
    eng.set_partitioner(partitioner)           # None → app default
    if kernels is None and kern_kind:
        from ..kernels import KernelSpec
        kernels = KernelSpec.default_for(kern_kind)
    eng.set_kernels(kernels)                   # None → app default → reference

    out = {"engine": engine, "workers": workers, "rounds": rounds,
           "pipeline_depth": depth, **meta}
    if eng.scheduler_spec is not None:
        out["scheduler"] = eng.scheduler_spec.to_json()
    if eng.partitioner_spec is not None:
        out["partitioner"] = eng.partitioner_spec.to_json()
        asgn = eng.partition_assignment
        out["assignment"] = {"num_vars": asgn.num_vars,
                             "num_workers": asgn.num_workers,
                             "version": asgn.version}
    if eng.kernel_spec is not None:
        out["kernels"] = eng.kernel_spec.to_json()
    if unroll != 1:
        out["phase_unroll"] = unroll
    import contextlib

    import jax.numpy as jnp
    rec = None
    obs0 = None
    if telemetry is not None:
        from ..obs import Recorder, init_counters
        out["telemetry"] = telemetry.to_json()
        obs0 = init_counters(eng.phase_period)
        if telemetry.events:
            rec = Recorder(profiler=telemetry.profiler)
    sc0 = eng.init_sched_carry()
    t0 = time.time()
    with rec.span("lower") if rec is not None else contextlib.nullcontext():
        if staleness is None:
            fn = eng.scanned_fn(rounds, pipeline_depth=depth,
                                unroll=unroll)
            lowered = fn.lower(state, data, jax.random.key(1),
                               jnp.int32(0), sc0, obs0)
        else:
            from .. import ps
            out["staleness"] = staleness
            fn = eng.ssp_fn(rounds, staleness=staleness)
            lowered = fn.lower(state, data, jax.random.key(1),
                               jnp.int32(0), ps.init_clocks(workers), sc0,
                               obs0)
    out["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    with (rec.span("compile") if rec is not None
          else contextlib.nullcontext()):
        compiled = lowered.compile()
    out["compile_s"] = round(time.time() - t0, 2)
    if telemetry is not None:
        from ..obs import RunReport
        executor = ("ssp" if staleness is not None
                    else ("pipelined" if depth else "scan"))
        out["run_report"] = RunReport.build(telemetry, executor, rounds,
                                            recorder=rec).to_json()
    try:
        ma = compiled.memory_analysis()
        out["memory"] = {k: int(getattr(ma, k)) for k in
                         ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes")
                         if hasattr(ma, k)}
    except Exception as e:                                # pragma: no cover
        out["memory"] = {"error": repr(e)}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes": float(ca.get("bytes accessed", 0.0))}
    except Exception as e:                                # pragma: no cover
        out["cost"] = {"error": repr(e)}
    # Trip-count-aware HLO analysis + roofline terms, same as run_one:
    # the R-round scan lowers to a while loop whose body XLA:CPU
    # cost_analysis counts once — analyze_hlo charges it R times, and
    # the psum collectives give the ring-model t_collective term that
    # `python -m repro.launch.roofline --check` asserts nonzero.
    hlo = compiled.as_text()
    out["hlo_bytes"] = len(hlo)
    ana = RL.analyze_hlo(hlo, workers)
    out["hlo_analysis"] = ana.to_json()
    out["roofline"] = RL.roofline_terms(ana.flops, ana.bytes,
                                        ana.wire_bytes)
    return out


def _override_spec(base, kind: str, rho):
    """Resolve the --scheduler/--rho flags against the app's OWN default
    policy: ``--rho`` alone keeps the default kind/U/U′ and moves only
    the threshold; a kind switch keeps the default block size and fills
    the remaining fields with that kind's conventional values."""
    import dataclasses as dc

    from ..sched import SchedulerSpec

    if kind and (base is None or kind != base.kind):
        bs = (base.block_size if base is not None and base.block_size
              else 32)
        nc = (base.num_candidates
              if base is not None and base.num_candidates >= bs
              else 0)
        base = SchedulerSpec.default_for(kind, block_size=bs,
                                         num_candidates=nc)
    if rho is not None:
        if base is None:
            raise SystemExit("--rho needs a policy to apply to: the app "
                             "has no default scheduler spec and no "
                             "--scheduler kind was given")
        base = dc.replace(base, rho=rho)   # spec validation guards kinds
    return base


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) pair")
    ap.add_argument("--tag", default="", help="variant tag (e.g. 'opt')")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--engine", choices=("lasso", "lda", "mf"),
                    help="lower the scanned STRADS executor instead of an "
                         "arch × shape spec")
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    choices=(0, 1))
    ap.add_argument("--staleness", type=int, default=None,
                    help="with --engine: lower the bounded-staleness SSP "
                         "executor (repro.ps) instead of the BSP scan")
    ap.add_argument("--plan", default="",
                    help="with --engine: an ExecutionPlan JSON file; its "
                         "executor/rounds/staleness/workers/scheduler "
                         "drive the lowering (overrides the per-flag "
                         "form)")
    ap.add_argument("--scheduler", default="",
                    help="with --engine: SchedulerSpec kind overriding "
                         "the app's default policy (round_robin|random|"
                         "rotation|dynamic_priority|block_structural)")
    ap.add_argument("--rho", type=float, default=None,
                    help="with --engine: dependency threshold ρ for the "
                         "dynamic scheduler kinds (overrides the app "
                         "default spec)")
    ap.add_argument("--partitioner", default="",
                    help="with --engine: PartitionerSpec kind overriding "
                         "the app's default partition policy (static|"
                         "size_balanced|load_balanced)")
    ap.add_argument("--kernels", default="",
                    choices=("", "reference", "pallas"),
                    help="with --engine: KernelSpec kind overriding the "
                         "app's default hot-spot backend (flag form "
                         "built by KernelSpec.default_for)")
    ap.add_argument("--telemetry", default="",
                    choices=("", "counters", "trace"),
                    help="with --engine: TelemetrySpec kind instrumenting "
                         "the lowering (device counters in the lowered "
                         "scan carry; 'trace' also times lower/compile "
                         "and embeds a RunReport in the artifact)")
    args = ap.parse_args()
    if args.plan and not args.engine:
        ap.error("--plan requires --engine (plans drive the STRADS "
                 "executor lowering, not the arch × shape specs)")
    if args.plan and (args.scheduler or args.rho is not None
                      or args.partitioner or args.kernels
                      or args.telemetry):
        ap.error("--scheduler/--rho/--partitioner/--kernels/--telemetry "
                 "conflict with --plan (the plan's scheduler/partitioner/"
                 "kernels/telemetry fields — possibly null/false = app "
                 "default/off — are authoritative); edit the plan file "
                 "instead")

    os.makedirs(RESULTS_DIR, exist_ok=True)

    if args.engine:
        os.makedirs(ENGINE_RESULTS_DIR, exist_ok=True)
        plan = None
        workers, rounds_req = args.workers, args.rounds
        depth, staleness, unroll = args.pipeline_depth, args.staleness, 1
        spec = None
        part_spec = None
        kern_spec = None
        tele_spec = None
        if args.plan:
            from ..core import ExecutionPlan
            with open(args.plan) as f:
                plan = ExecutionPlan.from_json(f.read())
            if plan.executor == "loop":
                raise SystemExit(
                    "a 'loop' plan is a per-round host loop — it has no "
                    "single-program lowering; use scan/pipelined/ssp")
            workers = plan.workers or args.workers
            rounds_req, depth = plan.rounds, plan.depth
            staleness = plan.staleness if plan.executor == "ssp" else None
            unroll = plan.phase_unroll
            spec = plan.scheduler         # None → the app's default policy
            part_spec = plan.partitioner  # None → the app's default
            kern_spec = plan.kernels      # None → app default → reference
            tele_spec = plan.telemetry or None   # False → uninstrumented
        elif args.telemetry:
            from ..obs import TelemetrySpec
            tele_spec = TelemetrySpec.default_for(args.telemetry)
        variant = (f"s{staleness}" if staleness is not None
                   else f"d{depth}")
        if spec is not None:
            variant += f"__{spec.kind}"
            if spec.rho:
                variant += f"-rho{spec.rho:g}"
        elif args.scheduler or args.rho is not None:
            variant += f"__{args.scheduler or 'default'}"
            if args.rho is not None:
                variant += f"-rho{args.rho:g}"
        if part_spec is not None:
            variant += f"__part-{part_spec.kind}"
        elif args.partitioner:
            variant += f"__part-{args.partitioner}"
        if kern_spec is not None:
            variant += f"__k-{kern_spec.kind}"
        elif args.kernels:
            variant += f"__k-{args.kernels}"
        if tele_spec is not None:
            variant += f"__obs-{tele_spec.kind}"
        rounds = engine_rounds(args.engine, workers, rounds_req, staleness,
                               unroll)
        if rounds != rounds_req:
            print(f"[note] rounds {rounds_req} → {rounds} "
                  f"(whole executor steps)")
        name = (f"strads-{args.engine}__U{workers}"
                f"__R{rounds}__{variant}")
        path = os.path.join(ENGINE_RESULTS_DIR, name + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip-cached] {name}")
            return
        print(f"[dryrun] {name} ...", flush=True)
        res = run_engine(args.engine, workers, rounds, depth, staleness,
                         unroll=unroll, scheduler=spec,
                         sched_kind="" if args.plan else args.scheduler,
                         rho=None if args.plan else args.rho,
                         partitioner=part_spec,
                         part_kind="" if args.plan else args.partitioner,
                         kernels=kern_spec,
                         kern_kind="" if args.plan else args.kernels,
                         telemetry=tele_spec)
        if plan is not None:
            # record what actually ran: engine_rounds may have aligned
            # the round count to whole SSP steps
            import dataclasses
            res["plan"] = dataclasses.replace(plan, rounds=rounds).to_json()
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"  lower {res['lower_s']}s compile {res['compile_s']}s"
              f"  args {res['memory'].get('argument_size_in_bytes', -1)}B"
              f"  temp {res['memory'].get('temp_size_in_bytes', -1)}B")
        r = res["roofline"]
        print(f"  kernels {res.get('kernels', {}).get('kind', '?')}"
              f"  Tc {r['t_compute']*1e3:.2f}ms"
              f"  Tm {r['t_memory']*1e3:.2f}ms"
              f"  Tx {r['t_collective']*1e3:.2f}ms → {r['dominant']}")
        return
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    pairs = ([(a, s) for a in ARCHS for s in INPUT_SHAPES]
             if args.all else [(args.arch, args.shape)])

    failures = []
    for arch, shape in pairs:
        for mesh_name in meshes:
            path = _result_path(arch, shape, mesh_name, args.tag)
            if os.path.exists(path) and not args.force:
                print(f"[skip-cached] {arch} {shape} {mesh_name}")
                continue
            print(f"[dryrun] {arch} × {shape} × {mesh_name} ...",
                  flush=True)
            try:
                res = run_one(arch, shape, mesh_name, args.tag,
                              args.keep_hlo)
            except Exception:
                print(traceback.format_exc())
                failures.append((arch, shape, mesh_name))
                res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "tag": args.tag or "baseline",
                       "error": traceback.format_exc(limit=3)}
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            if "skipped" in res:
                print(f"  skipped: {res['skipped']}")
            elif "error" not in res:
                r = res["roofline"]
                print(f"  lower {res['lower_s']}s compile {res['compile_s']}s"
                      f"  mem/dev {res['memory'].get('total_per_device', -1)/2**30:.2f} GiB"
                      f"  Tc {r['t_compute']*1e3:.2f}ms Tm {r['t_memory']*1e3:.2f}ms"
                      f"  Tx {r['t_collective']*1e3:.2f}ms → {r['dominant']}")
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
