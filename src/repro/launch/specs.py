"""Per-(arch × input-shape) step functions and ShapeDtypeStruct input
specs for the multi-pod dry-run.

``build(arch, shape_name, mesh, ...)`` returns a :class:`LoweringSpec`
with the step function to jit, abstract inputs (weak-type-correct,
sharding-annotated, zero allocation) and in_shardings — everything
``dryrun.py`` needs to ``.lower().compile()``.

Shape semantics (DESIGN.md §6):
  train_4k     → train_step          (all 10 archs)
  prefill_32k  → prefill_step        (hubert: encode_step — encoder fwd)
  decode_32k   → decode_step, full 32k cache   (hubert skipped)
  long_500k    → decode_step, sub-quadratic path: recurrent state for
                 ssm/hybrid, ring-buffer sliding-window cache
                 (LONG_WINDOW=8192) for attention archs (hubert skipped)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs import INPUT_SHAPES, get_config
from ..models import model as M
from ..optim.adamw import AdamWConfig
from ..sharding import rules
from ..train.serve import LONG_WINDOW
from ..train.step import TrainConfig, make_train_step


@dataclasses.dataclass
class LoweringSpec:
    arch: str
    shape: str
    fn: Callable                      # positional-args step function
    args: Tuple[Any, ...]             # ShapeDtypeStructs (sharded)
    in_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    skip_reason: Optional[str] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    kind = INPUT_SHAPES[shape_name].kind
    if cfg.encoder_only and kind == "decode":
        return "encoder-only (hubert): no autoregressive decode step"
    return None


def _abstract(shape, dtype, mesh, axes):
    return jax.ShapeDtypeStruct(
        shape, dtype,
        sharding=NamedSharding(mesh, rules.resolve(mesh, axes, shape)))


def _batch_specs(cfg, B: int, S: int, mesh, with_labels: bool):
    d = jnp.dtype(cfg.dtype)
    batch: Dict[str, Any] = {}
    tok_axes = (rules.BATCH, None)
    if cfg.frontend == "audio":
        batch["frames"] = _abstract((B, S, cfg.d_model), d, mesh,
                                    (rules.BATCH, None, None))
    else:
        batch["tokens"] = _abstract((B, S), jnp.int32, mesh, tok_axes)
    if cfg.frontend == "vision":
        batch["frontend"] = _abstract((B, cfg.frontend_tokens, cfg.d_model),
                                      d, mesh, (rules.BATCH, None, None))
    if with_labels:
        batch["labels"] = _abstract((B, S), jnp.int32, mesh, tok_axes)
    return batch


def _tree_shardings(tree):
    return jax.tree_util.tree_map(lambda x: x.sharding, tree)


def train_adamw_config(cfg) -> AdamWConfig:
    """Very large models keep AdamW moments in bf16 so params+moments fit
    the 256-chip HBM budget (DESIGN.md §7)."""
    big = M.num_params(cfg) > 100e9
    return AdamWConfig(moment_dtype="bfloat16" if big else "float32")


def abstract_train_state(cfg, mesh, ac: AdamWConfig):
    params = M.abstract_params(cfg, mesh)
    mdt = jnp.dtype(ac.moment_dtype)
    mom = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, mdt, sharding=p.sharding),
        params)
    count = jax.ShapeDtypeStruct((), jnp.int32,
                                 sharding=NamedSharding(mesh,
                                                        PartitionSpec()))
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh,
                                                       PartitionSpec()))
    return {"params": params,
            "opt": {"m": mom, "v": jax.tree_util.tree_map(lambda x: x, mom),
                    "count": count},
            "step": step}


def apply_variant(cfg, variant: str):
    """§Perf variants: beyond-paper optimizations, selectable per dry-run
    tag so baseline and optimized artifacts coexist in the results dir."""
    if variant == "opt":
        if cfg.family == "moe":
            cfg = dataclasses.replace(cfg, moe_impl="sort")
        if cfg.family in ("ssm", "hybrid") and cfg.ssm_state:
            cfg = dataclasses.replace(cfg, ssm_impl="ssd")
    elif variant.startswith("opt-ssd") and cfg.ssm_state:
        cfg = dataclasses.replace(cfg, ssm_impl="ssd")
    # any other tag labels a code-state (sharding/layout changes live in
    # the default path); config is unchanged
    return cfg


def build(arch: str, shape_name: str, mesh,
          variant: str = "baseline") -> LoweringSpec:
    cfg = apply_variant(get_config(arch), variant)
    shp = INPUT_SHAPES[shape_name]
    reason = skip_reason(arch, shape_name)
    if reason:
        return LoweringSpec(arch, shape_name, None, (), None,
                            skip_reason=reason)
    B, S = shp.global_batch, shp.seq_len

    if shp.kind == "train":
        ac = train_adamw_config(cfg)
        # grad accumulation for very large models: 4 microbatches brings
        # the llama4-class activation footprint under the 16 GiB v5e HBM
        # (§Perf iteration 3)
        mb = 1
        n_params = M.num_params(cfg)
        if variant not in ("baseline", "", "opt"):
            if n_params > 100e9:
                mb = 8 if variant == "opt4" else 4
            elif n_params > 30e9 or variant == "opt-mb2":
                mb = 2
        tc = TrainConfig(adamw=ac, microbatches=mb)
        state = abstract_train_state(cfg, mesh, ac)
        batch = _batch_specs(cfg, B, S, mesh, with_labels=True)
        fn = make_train_step(cfg, tc)
        args = (state, batch)
        return LoweringSpec(arch, shape_name, fn, args,
                            _tree_shardings(args), donate_argnums=(0,),
                            meta={"moment_dtype": ac.moment_dtype})

    params = M.abstract_params(cfg, mesh)

    if shp.kind == "prefill":
        batch = _batch_specs(cfg, B, S, mesh, with_labels=False)
        if cfg.encoder_only:
            fn = lambda p, b: M.encode_step(cfg, p, b)
            meta = {"adapted": "encoder forward (no KV cache)"}
        else:
            fn = lambda p, b: M.prefill(cfg, p, b, cache_len=S)
            meta = {"cache_len": S}
        args = (params, batch)
        return LoweringSpec(arch, shape_name, fn, args,
                            _tree_shardings(args), meta=meta)

    # decode kinds
    long = shape_name == "long_500k"
    window = LONG_WINDOW if (long and _needs_window(cfg)) else None
    cache_len = (window if window is not None else S)
    cache = M.abstract_cache(cfg, B, cache_len, mesh)
    token = _abstract((B,), jnp.int32, mesh, (rules.BATCH,))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, PartitionSpec()))
    fn = lambda p, c, t, q: M.decode_step(cfg, p, c, t, q, window=window)
    args = (params, cache, token, pos)
    return LoweringSpec(arch, shape_name, fn, args, _tree_shardings(args),
                        donate_argnums=(1,),
                        meta={"cache_len": cache_len, "window": window,
                              "sub_quadratic":
                                  "recurrent state" if cfg.family == "ssm"
                                  else ("hybrid state + windowed shared attn"
                                        if cfg.family == "hybrid"
                                        else (f"sliding window {window}"
                                              if window else "full cache"))})


def _needs_window(cfg) -> bool:
    """Archs whose only sequence mixer is attention need the sliding-window
    variant for long_500k; hybrids window their (shared) attention blocks
    too, since a 500k dense cache per shared block would defeat the point."""
    return cfg.family in ("dense", "vlm", "moe", "hybrid")
