"""Model-zoo LM decode driver: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve_lm --arch granite-3-2b \
        --preset reduced --batch 4 --prompt-len 64 --gen 32

This drives the dormant transformer model zoo (``repro.models`` /
``repro.train.serve``) — ring-buffer KV cache / recurrent states, a
jit-scanned greedy/temperature generation loop — at CPU-friendly scale.
It is **not** the STRADS serving path: serving model state out of the
STRADS engine's SSP caches (bounded-staleness reads, request batching,
serve-while-train) lives in :mod:`repro.serve` behind
``python -m repro.launch.serve``.
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import ARCHS, get_config
from ..data import SyntheticLMConfig, make_batch
from ..models import model as M
from ..train.serve import greedy_generate
from .mesh import make_test_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="granite-3-2b")
    ap.add_argument("--preset", choices=("reduced", "full"),
                    default="reduced")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window decode (ring-buffer cache)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")

    mesh = make_test_mesh()
    rng = jax.random.PRNGKey(args.seed)
    prm = M.init_params(cfg, rng)

    dcfg = SyntheticLMConfig(vocab_size=cfg.vocab_size,
                             seq_len=args.prompt_len,
                             batch_size=args.batch, seed=args.seed)
    dkw = {}
    if cfg.frontend == "vision":
        dkw = {"frontend_tokens": cfg.frontend_tokens,
               "d_model": cfg.d_model}
    batch = make_batch(dcfg, 0, **dkw)
    batch.pop("labels")

    window = args.window or None
    n_front = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    cache_len = (min(window, args.prompt_len + args.gen + n_front)
                 if window else args.prompt_len + args.gen + n_front)

    gen = jax.jit(lambda p, b, k: greedy_generate(
        cfg, p, b, steps=args.gen, cache_len=cache_len, window=window,
        rng=k, temperature=args.temperature))
    t0 = time.time()
    toks = gen(prm, batch, rng)
    toks.block_until_ready()
    wall = time.time() - t0
    t0 = time.time()
    toks = gen(prm, batch, rng)
    toks.block_until_ready()
    hot = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} cache={cache_len} window={window}")
    print(f"compile+run {wall:.2f}s, hot run {hot:.2f}s "
          f"({args.batch * args.gen / max(hot, 1e-9):.1f} tok/s)")
    print("sample tokens:", toks[0, :16].tolist())
    return toks


if __name__ == "__main__":
    main()
