"""STRADS serving CLI: bounded-staleness reads while training continues.

    PYTHONPATH=src python -m repro.launch.serve --engine lasso \
        --plan examples/plans/ssp_s2.json --requests 64

Builds a laptop-scale synthetic workload for one of the three paper
apps, runs :func:`repro.serve.serve_while_training` (or, with
``--serve-only``, serves a trained snapshot with no interleaved
training), and reports p50/p99 request latency, throughput, and the
*measured* staleness-at-read histogram — every read is checked against
``ServeSpec.max_staleness``, and the exit is nonzero if the bound was
violated.  ``--trace`` exports a Chrome trace showing serve batches
interleaved with training chunks; ``--out`` writes the full JSON
artifact (spec/plan dicts embedded).

``--stream`` additionally folds synthetic drift deltas into the
training data at the same chunk boundaries serving reads at
(:mod:`repro.stream`; ``--stream-kind``/``--ingest-every`` shape the
StreamSpec) and reports rows-ingested/dropped alongside p50/p99 — the
full continuous-operation loop: reads and writes riding one boundary.

The model-zoo LM decode driver that used to live at this path is now
``python -m repro.launch.serve_lm``.
"""
from __future__ import annotations

import argparse
import json
import math

import jax
import jax.numpy as jnp
import numpy as np

ENGINES = ("lasso", "lda", "mf")


def _build(engine: str, workers: int, mesh, seed: int):
    """(eng, state, data, request payloads generator) at serving-smoke
    scale for one of the three paper apps."""
    rng = np.random.default_rng(seed)
    if engine == "lasso":
        from ..apps import lasso
        n, J = workers * 32, 128
        X, y, _ = lasso.synthetic_correlated(rng, n=n, J=J, k_true=8)
        cfg = lasso.LassoConfig(num_features=J, lam=0.02, block_size=8,
                                num_candidates=32)
        eng = lasso.make_engine(cfg, mesh)
        data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
        state = eng.init_state(jax.random.key(seed), y=y)

        def payload(i):
            return {"x": jnp.asarray(X[i % n])}
    elif engine == "lda":
        from ..apps import lda
        cfg = lda.LDAConfig(vocab=workers * 32, num_topics=8,
                            num_workers=workers, tokens_per_worker=64,
                            docs_per_worker=8)
        words, docs, z0 = lda.synthetic_corpus(rng, cfg, true_topics=4)
        eng = lda.make_engine(cfg, mesh)
        data = eng.shard_data({"words": jnp.asarray(words),
                               "docs": jnp.asarray(docs)})
        state = eng.init_state(jax.random.key(seed), words=words,
                               docs=docs, z0=z0)
        docs_q = rng.integers(0, cfg.vocab, size=(256, 16)).astype(np.int32)

        def payload(i):
            return {"words": jnp.asarray(docs_q[i % len(docs_q)])}
    elif engine == "mf":
        from ..apps import mf
        N, M = workers * 16, 64
        A, mask = mf.synthetic_ratings(rng, N, M, true_rank=4)
        cfg = mf.MFConfig(num_rows=N, num_cols=M, rank=8)
        eng = mf.make_engine(cfg, mesh)
        data = eng.shard_data({"A": jnp.asarray(A),
                               "mask": jnp.asarray(mask)})
        state = eng.init_state(jax.random.key(seed), A=jnp.asarray(A),
                               mask=jnp.asarray(mask))

        def payload(i):
            return {"user": jnp.int32(i % N)}
    else:
        raise SystemExit(f"unknown engine {engine!r}")
    return eng, state, data, payload


def _phase_period(engine: str, workers: int) -> int:
    return workers if engine == "lda" else {"lasso": 1, "mf": 2}[engine]


def _drift_source(engine: str, workers: int, kind: str, seed: int):
    """A deterministic drift source matching ``_build``'s workload
    dimensions (same laptop scale, fresh rows every ingest boundary)."""
    from ..stream import (LassoDriftSource, LDADriftSource,
                          MFDriftSource)
    if engine == "lasso":
        return LassoDriftSource(num_rows=workers * 32, num_features=128,
                                rows_per_ingest=4 * workers,
                                seed=seed + 2)
    if engine == "lda":
        return LDADriftSource(num_tokens=workers * 64,
                              vocab=workers * 32, num_topics=8,
                              docs_per_worker=8,
                              tokens_per_ingest=8 * workers, kind=kind,
                              seed=seed + 2)
    return MFDriftSource(num_rows=workers * 16, num_cols=64,
                         rows_per_ingest=2 * workers, true_rank=4,
                         kind=kind, seed=seed + 2)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="serve model state out of the STRADS SSP caches")
    ap.add_argument("--engine", choices=ENGINES, required=True)
    ap.add_argument("--plan", default="",
                    help="ExecutionPlan JSON file (conflicts with "
                         "--rounds/--staleness/--workers)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--staleness", type=int, default=None)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--serve-kind", choices=("stale", "snapshot"),
                    default="stale")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="serving staleness bound in rounds (stale kind "
                         "only; default: the plan's SSP staleness)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--batch-window-ms", type=float, default=0.0)
    ap.add_argument("--serve-only", action="store_true",
                    help="train first, then serve the final state "
                         "(no interleaving)")
    ap.add_argument("--stream", action="store_true",
                    help="fold synthetic drift deltas into the training "
                         "data at chunk boundaries (repro.stream)")
    ap.add_argument("--stream-kind", choices=("replace", "extend"),
                    default=None,
                    help="StreamSpec kind (default: replace for lasso, "
                         "extend otherwise)")
    ap.add_argument("--ingest-every", type=int, default=None,
                    help="ingest cadence in rounds (default: one SSP "
                         "window; aligned up like --rounds)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace of the interleaved run")
    ap.add_argument("--out", default="",
                    help="write the JSON artifact (spec/plan embedded)")
    args = ap.parse_args(argv)

    if not args.stream:
        for flag, name in ((args.stream_kind, "--stream-kind"),
                           (args.ingest_every, "--ingest-every")):
            if flag is not None:
                raise SystemExit(f"{name} needs --stream (it configures "
                                 f"the streaming ingest)")

    from ..core import ExecutionPlan, worker_mesh
    from ..obs import Recorder
    from ..serve import ServeSpec, serve_only, serve_while_training

    if args.plan:
        for flag, name in ((args.rounds, "--rounds"),
                           (args.staleness, "--staleness"),
                           (args.workers, "--workers")):
            if flag is not None:
                raise SystemExit(f"{name} conflicts with --plan (the "
                                 f"plan file already declares it)")
        with open(args.plan) as f:
            plan = ExecutionPlan.from_json(f.read())
        workers = plan.workers or jax.device_count()
    else:
        workers = args.workers or jax.device_count()
        staleness = 1 if args.staleness is None else args.staleness
        rounds = 12 if args.rounds is None else args.rounds
        # whole SSP windows: round up to lcm(s+1, phase_period) steps
        L = math.lcm(staleness + 1, _phase_period(args.engine, workers))
        aligned = -(-rounds // L) * L
        if aligned != rounds:
            print(f"[align] rounds {rounds} -> {aligned} "
                  f"(whole SSP windows of {L})")
        plan = ExecutionPlan(executor="ssp", rounds=aligned,
                             staleness=staleness, workers=workers)

    if plan.workers is not None and plan.workers != workers:
        raise SystemExit(f"plan.workers={plan.workers} but "
                         f"{workers} requested")
    if workers > jax.device_count():
        raise SystemExit(
            f"{workers} workers want {workers} devices but only "
            f"{jax.device_count()} are visible (force more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    mesh = worker_mesh(workers)

    kw = dict(max_batch=args.max_batch,
              batch_window_ms=args.batch_window_ms)
    if args.serve_kind == "stale":
        kw["max_staleness"] = (args.max_staleness
                               if args.max_staleness is not None
                               else (plan.staleness
                                     if plan.executor == "ssp" else 0))
    elif args.max_staleness is not None:
        raise SystemExit("--max-staleness applies to --serve-kind stale "
                         "only (snapshot pins at boundaries)")
    spec = ServeSpec.default_for(args.serve_kind, **kw)

    eng, state, data, payload = _build(args.engine, workers, mesh,
                                       args.seed)
    rec = Recorder()
    rng = jax.random.key(args.seed + 1)

    stream_kw: dict = {}
    sspec = None
    if args.stream:
        from ..stream import StreamSpec
        kind = args.stream_kind or ("replace" if args.engine == "lasso"
                                    else "extend")
        L = math.lcm((plan.staleness + 1) if plan.executor == "ssp"
                     else 1, _phase_period(args.engine, workers))
        every = args.ingest_every if args.ingest_every else L
        aligned = -(-every // L) * L
        if aligned != every:
            print(f"[align] ingest-every {every} -> {aligned} "
                  f"(whole boundary windows of {L})")
        sspec = StreamSpec.default_for(kind, ingest_every=aligned)
        stream_kw = dict(stream=sspec,
                         source=_drift_source(args.engine, workers,
                                              kind, args.seed))

    if args.serve_only:
        rep0 = eng.execute(state, data, rng, plan, **stream_kw)
        srep = serve_only(eng, rep0.state, spec=spec,
                          requests=[payload(i)
                                    for i in range(args.requests)],
                          t=plan.rounds, recorder=rec)
        srep.ingest = rep0.stream
    else:
        reqs = [((i * plan.rounds) // max(args.requests, 1), payload(i))
                for i in range(args.requests)]
        srep = serve_while_training(eng, state, data, rng, plan,
                                    spec=spec, requests=reqs,
                                    recorder=rec, **stream_kw)

    pct = srep.latency_percentiles()
    hist = srep.staleness_hist()
    worst = srep.max_staleness_read()
    print(f"engine={args.engine} workers={workers} "
          f"executor={plan.executor} rounds={plan.rounds} "
          f"requests={len(srep.responses)}")
    print(f"serve spec: {spec.to_json()}")
    print(f"latency p50={pct['p50_ms']:.2f}ms p99={pct['p99_ms']:.2f}ms")
    if srep.ingest is not None:
        print(f"stream spec: {sspec.to_json()}")
        print(f"rows ingested={int(srep.ingest['rows_in'])} "
              f"dropped={int(srep.ingest['rows_dropped'])}")
    print(f"staleness-at-read hist: "
          f"{ {k: hist[k] for k in sorted(hist)} } (max {worst})")
    if args.trace:
        rec.write_chrome_trace(args.trace)
        print(f"wrote {args.trace}")
    if args.out:
        artifact = {
            "engine": args.engine, "workers": workers,
            "requests": len(srep.responses),
            "serve_spec": spec.to_json(), "plan": plan.to_json(),
            "latency": pct,
            "staleness_hist": {str(k): v for k, v in hist.items()},
            "max_staleness_read": worst,
            "reads": srep.reads,
        }
        if srep.ingest is not None:
            artifact["stream_spec"] = sspec.to_json()
            artifact["ingest"] = {k: int(v)
                                  for k, v in srep.ingest.items()}
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"wrote {args.out}")
    if spec.kind == "stale" and worst > spec.max_staleness:
        raise SystemExit(f"staleness bound violated: read at {worst} > "
                         f"max_staleness {spec.max_staleness}")
    return srep


if __name__ == "__main__":
    main()
