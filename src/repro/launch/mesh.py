"""Production mesh builders.

Functions, not module-level constants — importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (data, model) single pod; 2×16×16 (pod, data, model) for the
    two-pod 512-chip deployment."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices=None):
    """Whatever devices exist, as a (data, model) mesh — used by CPU
    integration tests (1 device → trivial mesh, 8 fake devices → 4×2)."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
