"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step on the
TPU v5e target (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

    T_compute    = HLO_FLOPs_per_chip / 197e12
    T_memory     = HLO_bytes_per_chip / 819e9
    T_collective = Σ ring-model wire bytes per chip / 50e9

``cost_analysis()`` provides per-partition FLOPs/bytes (the compiled
module is the per-device SPMD program).  Collective bytes are NOT in
cost_analysis — we parse the post-SPMD HLO text and apply a ring cost
model per op:

    all-gather        F·(n−1)/n      (F = full/result tensor bytes)
    reduce-scatter    F·(n−1)/n      (F = n × result bytes)
    all-reduce        2·F·(n−1)/n
    all-to-all        F·(n−1)/n
    collective-permute F

Also usable as a CLI over saved dry-run artifacts (the ``--engine``
records carry the same ``hlo_analysis``/``roofline`` keys as the
arch × shape ones):

    PYTHONPATH=src python -m repro.launch.roofline \
        benchmarks/results/engine/strads-lasso__U16__R16__d1.json --check

prints the three terms per artifact and, with ``--check``, exits
nonzero unless every artifact's t_compute / t_memory / t_collective
are finite and nonzero — the CI smoke that the cost model never
silently degenerates (a zero t_collective means the psum collectives
vanished from the lowering or the parser lost them).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# result types of an op line: e.g. "bf16[2,512,320]{2,1,0}" (maybe inside
# a tuple "(bf16[..], f32[..])")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))               # [num_groups, group_size]
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                  # ring-model bytes per chip
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0

    def to_json(self):
        return {"wire_bytes": self.wire_bytes, "by_kind": self.by_kind,
                "count": self.count}


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    """Scan post-SPMD HLO for collectives; sum ring-model wire bytes."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        typestr, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue                          # counted at -start
        result_bytes = _shape_bytes(typestr)
        if result_bytes == 0:
            continue
        n = max(_group_size(line, total_devices), 1)
        if n == 1:
            continue
        if kind == "all-gather":
            wire = result_bytes * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = result_bytes * (n - 1)     # result is F/n
        elif kind == "all-reduce":
            wire = 2 * result_bytes * (n - 1) / n
        elif kind == "all-to-all":
            wire = result_bytes * (n - 1) / n
        else:                                 # collective-permute
            wire = result_bytes
        stats.wire_bytes += wire
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + wire
        stats.count += 1
    return stats


# ---------------------------------------------------------------------------
# Full HLO analysis with while-loop trip-count multiplication
# ---------------------------------------------------------------------------
#
# XLA:CPU's ``compiled.cost_analysis()`` counts each while-loop *body* once,
# so a scan-over-layers program under-reports FLOPs/bytes by ~num_layers×
# (and the naive collective scan under-reports wire bytes the same way).
# This analyzer parses the post-SPMD HLO text, builds the computation call
# graph, extracts loop trip counts from the canonical counter-compare
# pattern, and charges every dot/collective/op by the product of its
# enclosing trip counts.

_CALLED_RE = re.compile(r"(?:body|condition|to_apply|calls|"
                        r"called_computations=\{)=?%?([\w.\-]+)")
_FUSION_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*s(?:8|16|32|64)\[\]\s+"
                       r"constant\((\d+)\)")


def _parse_instr(line: str):
    """Parse '[ROOT ]%name = <type> <op>(...' with a balanced-paren scan
    (regex breaks on tuple types containing /*index=N*/ comments).
    Returns (name, typestr, op) or None."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") and "=" not in s.split(" ", 1)[0]:
        if "=" not in s:
            return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].strip().lstrip("%")
    rest = s[eq + 3:].lstrip()
    if rest.startswith("("):                 # tuple type: balanced scan
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    typestr = rest[:i + 1]
                    tail = rest[i + 1:].lstrip()
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        typestr = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    op = tail.split("(", 1)[0].strip()
    if not op or not re.fullmatch(r"[\w\-]+", op):
        return None
    return name, typestr, op
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _split_top(s: str) -> List[str]:
    """Split on commas at paren/bracket depth 0."""
    out, depth, buf = [], 0, ""
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append(buf.strip())
            buf = ""
        else:
            buf += ch
    if buf.strip():
        out.append(buf.strip())
    return out


def _parse_computations(text: str):
    """Split HLO text into computations: name → list of instruction lines,
    plus name → parameter declarations.  Handles tuple-typed parameters
    (nested parens) that defeat a naive regex."""
    comps: Dict[str, List[str]] = {}
    params: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        s = line.strip()
        if cur is None:
            if (s.endswith("{") and ") -> " in s
                    and "=" not in s.split("(", 1)[0]):
                head = s[:-1].strip()
                if head.startswith("ENTRY "):
                    head = head[len("ENTRY "):]
                name = head.split("(", 1)[0].strip().lstrip("%")
                psec = head.split("(", 1)[1].rsplit(") ->", 1)[0]
                comps[name] = []
                params[name] = _split_top(psec)
                cur = name
        else:
            if s == "}" or s.startswith("}, "):
                cur = None
            else:
                comps[cur].append(s)
    return comps, params


def _shape_dims(typestr: str):
    m = _SHAPE_RE.search(typestr)
    if not m:
        return None, ()
    dt = m.group(1)
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) \
        else ()
    return dt, dims


def _trip_count(cond_lines: List[str]) -> int:
    """Trip count from the canonical `compare(counter, constant), LT`
    pattern in a while condition (scan lowers to this)."""
    consts = {}
    for ln in cond_lines:
        m = _CONST_RE.search(ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if " compare(" not in ln:
            continue
        ops = _OPERANDS_RE.search(ln.split("compare", 1)[1])
        if not ops:
            continue
        names = [o.strip().lstrip("%").split(" ")[-1].lstrip("%")
                 for o in ops.group(1).split(",")]
        for n in names:
            n = n.split("]")[-1].strip().lstrip("%")
            if n in consts:
                return max(consts[n], 1)
        # operand may be typed: "s32[] %constant.5"
        for o in ops.group(1).split(","):
            o = o.strip()
            for cname, val in consts.items():
                if o.endswith(cname):
                    return max(val, 1)
    # compare may be wrapped in a fusion; fall back to the largest s32
    # constant in the condition (the loop bound in canonical scans)
    if consts:
        return max(max(consts.values()), 1)
    return 1


@dataclasses.dataclass
class HloAnalysis:
    flops: float = 0.0                   # per-chip dot/conv FLOPs
    bytes: float = 0.0                   # per-chip operand+result bytes
    wire_bytes: float = 0.0              # per-chip ring-model collective B
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    loop_multipliers: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    def to_json(self):
        return {"flops": self.flops, "bytes": self.bytes,
                "wire_bytes": self.wire_bytes, "by_kind": self.by_kind,
                "collective_count": self.collective_count}


def analyze_hlo(text: str, total_devices: int) -> HloAnalysis:
    comps, params = _parse_computations(text)

    # --- computation multipliers via the call graph -----------------------
    # multiplier(entry) = 1; a while body/condition inherits caller × trip.
    callers: Dict[str, List[Tuple[str, int]]] = {c: [] for c in comps}
    fusion_called: Dict[str, bool] = {}      # comp → called ONLY as fusion
    for cname, lines in comps.items():
        for ln in lines:
            parsed = _parse_instr(ln)
            op = parsed[2] if parsed else ""
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                cm = re.search(r"condition=%?([\w.\-]+)", ln)
                if bm and cm and bm.group(1) in comps:
                    ktc = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"',
                                    ln)
                    trip = (int(ktc.group(1)) if ktc
                            else _trip_count(comps[cm.group(1)]))
                    callers[bm.group(1)].append((cname, trip))
                    fusion_called.setdefault(bm.group(1), False)
                    fusion_called[bm.group(1)] = False
                    if cm.group(1) in comps:
                        callers[cm.group(1)].append((cname, trip))
                        fusion_called[cm.group(1)] = False
            else:
                fus = set(c for c in _FUSION_CALL_RE.findall(ln)
                          if c in comps)
                for c in _CALLED_RE.findall(ln):
                    if c not in comps:
                        continue
                    callers[c].append((cname, 1))
                    is_fus = c in fus
                    if c in fusion_called:
                        fusion_called[c] = fusion_called[c] and is_fus
                    else:
                        fusion_called[c] = is_fus

    mult: Dict[str, int] = {}

    def get_mult(c: str, depth=0) -> int:
        if c in mult:
            return mult[c]
        if depth > 50 or not callers[c]:
            mult[c] = 1
            return 1
        mult[c] = max(get_mult(p, depth + 1) * t for p, t in callers[c])
        return mult[c]

    # --- per-instruction accounting ---------------------------------------
    out = HloAnalysis()
    for cname, lines in comps.items():
        m_c = get_mult(cname)
        if m_c > 1:
            out.loop_multipliers[cname] = m_c
        # Ops inside fusion bodies stay in registers/loop scope: they move
        # no HBM bytes themselves (the fusion call site is charged), but
        # dots inside fusions are still real FLOPs.
        in_fusion_body = fusion_called.get(cname, False)
        # symbol table: instr name → typestr (incl. computation params)
        symtab: Dict[str, str] = {}
        for p in params.get(cname, []):
            parts = p.split(":", 1)
            if len(parts) == 2:
                symtab[parts[0].strip().lstrip("%")] = parts[1].strip()
        parsed_lines = []
        for ln in lines:
            pr = _parse_instr(ln)
            if pr:
                symtab[pr[0]] = pr[1]
                parsed_lines.append((ln, pr))
        for ln, (name, typestr, op) in parsed_lines:
            result_bytes = _shape_bytes(typestr)
            if op in ("parameter", "constant", "get-tuple-element",
                      "tuple", "bitcast", "after-all"):
                continue
            if not in_fusion_body:
                # memory: result + operands (≈ bytes-accessed at HBM)
                opnds = _OPERANDS_RE.search(ln.split(op + "(", 1)[-1]
                                            if op + "(" in ln else ln)
                body = ln.split(op + "(", 1)
                operand_bytes = 0
                if len(body) == 2:
                    # operands run to the matching close paren
                    depth, buf = 1, ""
                    for ch in body[1]:
                        if ch == "(":
                            depth += 1
                        elif ch == ")":
                            depth -= 1
                            if depth == 0:
                                break
                        buf += ch
                    for o in _split_top(buf):
                        o = o.strip().lstrip("%")
                        o = o.split(" ")[-1].lstrip("%")
                        if o in symtab:
                            operand_bytes += _shape_bytes(symtab[o])
                        elif "[" in o:
                            operand_bytes += _shape_bytes(o)
                out.bytes += (result_bytes + operand_bytes) * m_c

            if op == "dot":
                dt, rdims = _shape_dims(typestr)
                n_out = 1
                for dd in rdims:
                    n_out *= dd
                cdims = _DOT_DIMS_RE.search(ln)
                csize = 1
                args = ln.split(op + "(", 1)
                if cdims and len(args) == 2:
                    first = _split_top(args[1].rsplit(")", 1)[0])[0].strip()
                    first = first.lstrip("%")
                    lhs_t = (first if "[" in first
                             else symtab.get(first.split(" ")[-1], ""))
                    _, ldims = _shape_dims(lhs_t)
                    for ci in cdims.group(1).split(","):
                        if ci != "" and int(ci) < len(ldims):
                            csize *= ldims[int(ci)]
                out.flops += 2.0 * n_out * csize * m_c
            elif op in _COLLECTIVES or any(
                    op == c + s for c in _COLLECTIVES
                    for s in ("-start",)):
                base = op.replace("-start", "")
                if base not in _COLLECTIVES:
                    continue
                n = max(_group_size(ln, total_devices), 1)
                if n == 1:
                    continue
                if base == "all-gather":
                    wire = result_bytes * (n - 1) / n
                elif base == "reduce-scatter":
                    wire = result_bytes * (n - 1)
                elif base == "all-reduce":
                    wire = 2 * result_bytes * (n - 1) / n
                elif base == "all-to-all":
                    wire = result_bytes * (n - 1) / n
                else:
                    wire = result_bytes
                out.wire_bytes += wire * m_c
                out.by_kind[base] = out.by_kind.get(base, 0.0) + wire * m_c
                out.collective_count += m_c
    return out


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs per step: 6·N·D train, 2·N·D inference
    (N = active params for MoE)."""
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per seq


def active_params(cfg) -> int:
    """Active-per-token parameter count from the real template (excludes
    non-routed experts; embeddings counted once)."""
    from ..models import model as M
    total = M.num_params(cfg)
    if cfg.family != "moe":
        return total
    # subtract the non-active expert weights
    from ..models.transformer import group_layout
    steps, subs = group_layout(cfg)
    moe_layers = sum(1 for _, k in subs if k == "moe") * steps
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = moe_layers * (cfg.num_experts - cfg.experts_per_token) \
        * per_expert
    return total - inactive


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   wire_bytes_per_chip: float) -> Dict[str, float]:
    t_c = flops_per_chip / PEAK_FLOPS
    t_m = bytes_per_chip / HBM_BW
    t_x = wire_bytes_per_chip / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "dominant": dom[1],
            "bound_s": max(t_c, t_m, t_x)}


# Ridge point of the v5e roofline: the arithmetic intensity (FLOPs per
# HBM byte) above which a kernel is compute-bound.  bench_kernels
# reports each kernel's measured intensity against this peak ratio.
RIDGE_INTENSITY = PEAK_FLOPS / HBM_BW


def arithmetic_intensity(flops: float, bytes_accessed: float) -> float:
    """Measured FLOPs-per-byte; 0.0 for a byte-free (degenerate) record."""
    return flops / bytes_accessed if bytes_accessed else 0.0


# ---------------------------------------------------------------------------
# CLI: render/check saved dry-run artifacts
# ---------------------------------------------------------------------------

_TERMS = ("t_compute", "t_memory", "t_collective")


def check_terms(r: Dict[str, float]) -> bool:
    """True iff all three roofline terms are finite and nonzero."""
    import math
    return all(isinstance(r.get(k), (int, float))
               and math.isfinite(r[k]) and r[k] > 0.0 for k in _TERMS)


def main(argv=None) -> int:
    import argparse
    import glob as _glob
    import json
    import os

    ap = argparse.ArgumentParser(
        description="Print (and --check) the roofline terms recorded in "
                    "dry-run artifact JSON files.")
    ap.add_argument("paths", nargs="+",
                    help="artifact JSON paths or globs (e.g. "
                         "benchmarks/results/engine/*.json)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every artifact's t_compute/"
                         "t_memory/t_collective are finite and nonzero")
    args = ap.parse_args(argv)

    files: List[str] = []
    for p in args.paths:
        hits = sorted(_glob.glob(p))
        files.extend(hits if hits else [p])

    bad: List[str] = []
    for path in files:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                rec = json.load(f)
        except OSError as e:
            print(f"{name}: unreadable ({e})")
            bad.append(name)
            continue
        r = rec.get("roofline")
        if r is None and isinstance(rec.get("hlo_analysis"), dict):
            ana = rec["hlo_analysis"]
            r = roofline_terms(ana.get("flops", 0.0),
                               ana.get("bytes", 0.0),
                               ana.get("wire_bytes", 0.0))
        if r is None:
            print(f"{name}: no roofline/hlo_analysis recorded")
            bad.append(name)
            continue
        ok = check_terms(r)
        ana = rec.get("hlo_analysis", {})
        ai = arithmetic_intensity(ana.get("flops", 0.0),
                                  ana.get("bytes", 0.0))
        print(f"{name}: Tc {r['t_compute']*1e3:.3f}ms "
              f"Tm {r['t_memory']*1e3:.3f}ms "
              f"Tx {r['t_collective']*1e3:.3f}ms "
              f"→ {r['dominant']}  AI {ai:.2f} flop/B "
              f"(ridge {RIDGE_INTENSITY:.0f})  "
              f"[{'ok' if ok else 'DEGENERATE'}]")
        if not ok:
            bad.append(name)
        # instrumented artifacts (dryrun --telemetry trace / a plan's
        # TelemetrySpec) also carry measured wall-clock phase spans —
        # print them beside the modelled terms, and under --check hold
        # them to the same strict-nesting contract the trace CLI does
        rr = rec.get("run_report")
        events = (rr or {}).get("events") or []
        spans = [e for e in events if e.get("ph") == "X"]
        if spans:
            from ..obs.events import validate_spans
            err = validate_spans(events)
            timed = "  ".join(f"{e['name']} {e['dur']/1e6:.2f}s"
                              for e in spans)
            print(f"  measured phases: {timed}"
                  + ("" if err is None else f"  [INVALID: {err}]"))
            if err is not None:
                bad.append(name)
    if not files:
        print("no artifacts matched")
        return 1
    if args.check and bad:
        print(f"--check failed: {len(bad)}/{len(files)} artifact(s) with "
              f"missing or degenerate roofline terms: {bad}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
