"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --preset reduced --steps 200 --batch 8 --seq 256

Runs the real substrate end to end on whatever devices exist (CPU here,
TPU pods via the same pjit path — the mesh is built from jax.devices()):
synthetic data pipeline → pjit'd train step (AdamW + schedule) →
checkpointing.  ``--strads`` turns on the paper's technique as
block-coordinate scheduled training (repro.sched.block); the block
policy is a declarative ``SchedulerSpec`` (``--scheduler``/``--rho``
flags or ``plan.scheduler`` — kind ``block_structural``).

``--scan-steps K`` rolls K train steps into a single ``lax.scan`` XLA
program with donated state (the training-substrate twin of
``StradsEngine.run_scanned``): one dispatch and one host sync per K
steps instead of per step.

``--staleness s`` (with ``--strads``) serves the block schedule from an
SSP-style stale cache: priorities are re-read and the schedule recomputed
only every s+1 steps (the trainer twin of ``StradsEngine.run_ssp``).

``--plan plan.json`` drives the same knobs declaratively from an
:class:`repro.core.ExecutionPlan` (rounds → steps, ``phase_unroll`` →
scan chunk, ``staleness``, ``checkpoint_every``), so one checked-in plan
file reproduces a run shape exactly — including across ``--resume``.

Checkpoints written via ``--ckpt-dir`` hold the *full* train state
(params, optimizer moments, step, and in strads mode the scheduler
priority/rng), so ``--resume`` continues bit-exactly: a resumed run
matches an uninterrupted one (tested in tests/test_ckpt_resume.py).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config
from ..sched import SchedulerSpec
from ..sched.block import config_from_spec
from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..data import SyntheticLMConfig, make_batch
from ..optim import AdamWConfig, cosine_schedule, wsd_schedule
from ..sharding.rules import activation_mesh
from ..train import TrainConfig, make_train_step, init_train_state
from ..train.step import init_strads_state, make_strads_train_step
from .mesh import make_test_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="minicpm-2b")
    ap.add_argument("--preset", choices=("reduced", "full"),
                    default="reduced")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", choices=("cosine", "wsd"), default=None)
    ap.add_argument("--strads", action="store_true",
                    help="STRADS block-coordinate scheduled updates")
    ap.add_argument("--scan-steps", type=int, default=1,
                    help="steps per lax.scan chunk (1 = host loop)")
    ap.add_argument("--blocks-per-step", type=int, default=0,
                    help="U for --strads (default: half the blocks)")
    ap.add_argument("--staleness", type=int, default=0,
                    help="SSP-style stale block schedule for --strads: "
                         "recompute the schedule every s+1 steps only")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in "
                         "--ckpt-dir (bit-exact: full state is saved)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan", default="",
                    help="ExecutionPlan JSON driving the run shape: "
                         "rounds→steps, phase_unroll→scan-steps (scanned "
                         "executors), staleness→--staleness (implies "
                         "--strads), checkpoint_every→--ckpt-every, "
                         "scheduler→the --strads block policy; overrides "
                         "those flags")
    ap.add_argument("--scheduler", default="",
                    help="SchedulerSpec kind for the --strads block "
                         "schedule (only 'block_structural' has a "
                         "trainer lowering); implies --strads")
    ap.add_argument("--rho", type=float, default=None,
                    help="structural-filter threshold ρ for --scheduler "
                         "(with the 0/1 structural gram any value in "
                         "(0,1] is equivalent; min_distance is the real "
                         "knob)")
    args = ap.parse_args(argv)

    if args.plan and (args.scheduler or args.rho is not None):
        ap.error("--scheduler/--rho conflict with --plan (the plan's "
                 "scheduler field — possibly null = default — is "
                 "authoritative); edit the plan file instead")
    sched_spec = None
    if args.plan:
        from ..core import ExecutionPlan
        with open(args.plan) as f:
            plan = ExecutionPlan.from_json(f.read())
        unsupported = [name for name, v in
                       (("telemetry", plan.telemetry),
                        ("collect_every", plan.collect_every),
                        ("workers", plan.workers),
                        # block-coordinate training has no variable-
                        # ownership store to repartition — only the
                        # paper apps consume plan.partitioner
                        ("partitioner", plan.partitioner),
                        # ...and no lasso_partial/gram_block hot-spots
                        # either: plan.kernels only drives the paper apps
                        ("kernels", plan.kernels)) if v]
        if unsupported:
            ap.error(f"--plan fields the trainer has no surface for "
                     f"(they would be silently dropped): {unsupported}")
        args.steps = plan.rounds
        args.scan_steps = (plan.phase_unroll
                           if plan.executor in ("scan", "pipelined")
                           else 1)
        args.staleness = plan.staleness
        if plan.staleness:
            args.strads = True           # stale schedules are strads-only
        if plan.checkpoint_every:
            args.ckpt_every = plan.checkpoint_every
        if plan.scheduler is not None:
            sched_spec = plan.scheduler
            args.strads = True           # a block policy is strads-only
        print(f"plan: {plan.to_json()}")
    elif args.scheduler or args.rho is not None:
        kind = args.scheduler or "block_structural"
        if kind != "block_structural":
            ap.error(f"the trainer's block-coordinate lowering only "
                     f"takes kind='block_structural'; got {kind!r} "
                     f"(the paper apps take any kind via their fit "
                     f"plans)")
        args.strads = True               # spec built once nblocks is known
    if sched_spec is not None and sched_spec.kind != "block_structural":
        ap.error(f"plan.scheduler kind {sched_spec.kind!r} has no "
                 f"trainer lowering (block-coordinate training needs "
                 f"'block_structural')")

    cfg = get_config(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
    # default schedule: WSD for minicpm (its paper's schedule), else cosine
    sched_kind = args.schedule or ("wsd" if args.arch == "minicpm-2b"
                                   else "cosine")
    if sched_kind == "wsd":
        schedule = wsd_schedule(args.lr, args.steps // 10,
                                int(args.steps * 0.7),
                                args.steps - args.steps // 10
                                - int(args.steps * 0.7))
    else:
        schedule = cosine_schedule(args.lr, args.steps // 10, args.steps)
    tc = TrainConfig(adamw=AdamWConfig(), schedule=schedule)

    mesh = make_test_mesh()
    print(f"arch={cfg.name} preset={args.preset} devices={mesh.size} "
          f"mesh={dict(mesh.shape)}")

    rng = jax.random.PRNGKey(args.seed)
    if args.strads:
        from ..models.transformer import group_layout
        if cfg.family == "ssm":
            nblocks = cfg.num_layers + 1
        else:
            nblocks = group_layout(cfg)[0] + 1
        u = args.blocks_per_step or max(1, nblocks // 2)
        if sched_spec is None:
            # the conventional block_structural defaults, with the
            # trainer's historical adjacency radius of 1 layer-group
            sched_spec = SchedulerSpec.default_for(
                "block_structural", block_size=u,
                num_candidates=min(nblocks, 2 * u), min_distance=1,
                **({"rho": args.rho} if args.rho is not None else {}))
        sched = config_from_spec(sched_spec, nblocks)
        state = init_strads_state(cfg, tc, sched, rng,
                                  staleness=args.staleness)
        step_fn = make_strads_train_step(cfg, tc, sched,
                                         staleness=args.staleness)
        print(f"STRADS block scheduling: {sched.blocks_per_step}/"
              f"{nblocks} blocks per step "
              f"(spec: {sched_spec.to_json()})"
              + (f", schedule staleness {args.staleness}"
                 if args.staleness else ""))
    else:
        state = init_train_state(cfg, tc, rng)
        step_fn = make_train_step(cfg, tc)

    def chunk_fn(state, batches):
        # K steps as one scanned XLA program (run_scanned's sibling)
        def body(st, batch):
            return step_fn(st, batch)
        return jax.lax.scan(body, state, batches)

    with activation_mesh(mesh):
        if args.scan_steps > 1:
            chunk_jit = jax.jit(chunk_fn, donate_argnums=(0,))
        else:
            step_jit = jax.jit(step_fn, donate_argnums=(0,))

    dcfg = SyntheticLMConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                             batch_size=args.batch, seed=args.seed)
    dkw = {}
    if cfg.frontend == "audio":
        dkw = {"frames": True, "d_model": cfg.d_model}
    elif cfg.frontend == "vision":
        dkw = {"frontend_tokens": cfg.frontend_tokens,
               "d_model": cfg.d_model}

    def log_step(i, metrics, t0, history):
        m = {k: float(v) for k, v in metrics.items()}
        m["step"] = i
        m["wall_s"] = round(time.time() - t0, 1)
        history.append(m)
        print(f"step {i:5d}  loss {m['loss']:.4f}  acc {m['acc']:.3f}"
              f"  gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}"
              f"  [{m['wall_s']}s]")

    def maybe_ckpt(i, chunk=None):
        # For a scanned chunk, fire if ANY step in it crossed a ckpt_every
        # boundary (the saved state is end-of-chunk — coarser cadence, but
        # no silently skipped checkpoints when the periods don't align).
        due = (any((j + 1) % args.ckpt_every == 0 for j in chunk)
               if chunk is not None else (i + 1) % args.ckpt_every == 0)
        if args.ckpt_dir and due:
            # full state (params + opt + step [+ scheduler]) so --resume
            # continues the exact run, optimizer moments included
            p = save_checkpoint(args.ckpt_dir, i + 1, state)
            print(f"checkpoint → {p}")

    start0 = 0
    if args.resume and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore_checkpoint(args.ckpt_dir, last, state)
            start0 = last
            print(f"resumed from step {last} ({args.ckpt_dir})")

    history = []
    t0 = time.time()
    if args.scan_steps > 1:
        K = args.scan_steps
        for start in range(start0, args.steps, K):
            steps = range(start, min(start + K, args.steps))
            batches = [make_batch(dcfg, j, **dkw) for j in steps]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
            state, ms = chunk_jit(state, stacked)
            last = steps[-1]
            if (any(j % args.log_every == 0 for j in steps)
                    or last == args.steps - 1):
                log_step(last, jax.tree.map(lambda v: v[-1], ms), t0,
                         history)
            maybe_ckpt(last, chunk=steps)
    else:
        for i in range(start0, args.steps):
            batch = make_batch(dcfg, i, **dkw)
            state, metrics = step_jit(state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                log_step(i, metrics, t0, history)
            maybe_ckpt(i)
    if history:
        print(json.dumps({"first_loss": history[0]["loss"],
                          "last_loss": history[-1]["loss"],
                          "steps": args.steps,
                          "wall_s": history[-1]["wall_s"]}))
    return history


if __name__ == "__main__":
    main()
