"""SSP telemetry: per-round staleness histograms + push/pull byte accounting.

Two halves:

* a small **device-side** pytree carried through the scan (staleness
  histogram, max observed read staleness) — this is what the staleness-
  invariant property test asserts over, so the bound is checked against
  what the compiled program actually did, not against the window algebra.
  Since the unified observability subsystem landed, the device half
  *lives* in :mod:`repro.obs.counters` (``staleness_init`` /
  ``observe_read`` — the same scan-carried-int32 pattern now serves all
  four executors); this module re-exports it under its historical names;
* **host-side static** byte accounting, captured while the executor
  traces (partial-update bytes deferred per window, aggregated per flush,
  server bytes pulled into caches per refresh) — per-round shapes are
  static, so these are exact without any device traffic.

An :class:`SSPTelemetry` summary joins the two; under a plan-level
:class:`~repro.obs.spec.TelemetrySpec` it becomes the ``ssp`` section of
the run's :class:`~repro.obs.report.RunReport`, and chunked
(``checkpoint_every``) runs merge per-chunk summaries via
:func:`merge_summaries`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from ..obs.counters import observe_read, staleness_init

__all__ = ["SSPTelemetry", "device_init", "observe_read", "summarize",
           "merge_summaries"]

# historical name for the relocated device half (repro.obs.counters)
device_init = staleness_init


@dataclasses.dataclass
class SSPTelemetry:
    """One SSP run, summarized."""
    staleness_bound: int
    rounds: int
    flushes: int
    hist: np.ndarray          # rounds whose reads were k clocks stale
    max_staleness: int        # device-observed; must be <= staleness_bound
    clocks: np.ndarray        # final per-worker vector clock
    bytes_pushed: int         # partial-update bytes aggregated at flushes
    bytes_deferred_peak: int  # largest pending buffer between flushes
    bytes_pulled: int         # server bytes refreshed into worker caches

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["hist"] = [int(v) for v in self.hist]
        d["clocks"] = [int(v) for v in self.clocks]
        return d


def summarize(device: Dict[str, jnp.ndarray], info: dict, *,
              staleness: int, rounds: int, flushes: int,
              clocks) -> SSPTelemetry:
    """Join the device-side carry with the trace-time static accounting
    (``info`` is filled by the executor while tracing)."""
    return SSPTelemetry(
        staleness_bound=staleness,
        rounds=rounds,
        flushes=flushes,
        hist=np.asarray(device["hist"]),
        max_staleness=int(device["max_staleness"]),
        clocks=np.asarray(clocks),
        bytes_pushed=int(info.get("push_bytes_per_step", 0)
                         * info.get("num_steps", 0)),
        bytes_deferred_peak=int(info.get("deferred_bytes_peak", 0)),
        bytes_pulled=int(info.get("shared_bytes", 0)) * flushes,
    )


def merge_summaries(parts: List[SSPTelemetry]) -> SSPTelemetry:
    """Join per-chunk summaries of one chunked (``checkpoint_every``)
    run: counts and histograms add, the observed max is the max of
    maxes, and the final chunk's vector clocks are the run's."""
    if not parts:
        raise ValueError("merge_summaries needs at least one summary")
    head = parts[0]
    for p in parts[1:]:
        if p.staleness_bound != head.staleness_bound:
            raise ValueError(
                f"cannot merge SSP summaries across staleness bounds "
                f"{head.staleness_bound} != {p.staleness_bound}")
    return SSPTelemetry(
        staleness_bound=head.staleness_bound,
        rounds=sum(p.rounds for p in parts),
        flushes=sum(p.flushes for p in parts),
        hist=np.sum([np.asarray(p.hist) for p in parts], axis=0),
        max_staleness=max(p.max_staleness for p in parts),
        clocks=np.asarray(parts[-1].clocks),
        bytes_pushed=sum(p.bytes_pushed for p in parts),
        bytes_deferred_peak=max(p.bytes_deferred_peak for p in parts),
        bytes_pulled=sum(p.bytes_pulled for p in parts),
    )
