"""SSP telemetry: per-round staleness histograms + push/pull byte accounting.

Two halves:

* a small **device-side** pytree carried through the scan (staleness
  histogram, max observed read staleness) — this is what the staleness-
  invariant property test asserts over, so the bound is checked against
  what the compiled program actually did, not against the window algebra;
* **host-side static** byte accounting, captured while the executor
  traces (partial-update bytes deferred per window, aggregated per flush,
  server bytes pulled into caches per refresh) — per-round shapes are
  static, so these are exact without any device traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp
import numpy as np


def device_init(staleness: int) -> Dict[str, jnp.ndarray]:
    """Scan-carried telemetry: histogram over observed read staleness
    (bins 0..s) and the running max."""
    return {"hist": jnp.zeros((staleness + 1,), jnp.int32),
            "max_staleness": jnp.int32(0)}


def observe_read(telem: Dict[str, jnp.ndarray], clock,
                 cache_clock) -> Dict[str, jnp.ndarray]:
    """Record one round's read: how stale was the cache it was served
    from?  (``clock`` and ``cache_clock`` are device scalars.)"""
    st = jnp.asarray(clock, jnp.int32) - jnp.asarray(cache_clock, jnp.int32)
    return {"hist": telem["hist"].at[st].add(1),
            "max_staleness": jnp.maximum(telem["max_staleness"], st)}


@dataclasses.dataclass
class SSPTelemetry:
    """One SSP run, summarized."""
    staleness_bound: int
    rounds: int
    flushes: int
    hist: np.ndarray          # rounds whose reads were k clocks stale
    max_staleness: int        # device-observed; must be <= staleness_bound
    clocks: np.ndarray        # final per-worker vector clock
    bytes_pushed: int         # partial-update bytes aggregated at flushes
    bytes_deferred_peak: int  # largest pending buffer between flushes
    bytes_pulled: int         # server bytes refreshed into worker caches

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["hist"] = [int(v) for v in self.hist]
        d["clocks"] = [int(v) for v in self.clocks]
        return d


def summarize(device: Dict[str, jnp.ndarray], info: dict, *,
              staleness: int, rounds: int, flushes: int,
              clocks) -> SSPTelemetry:
    """Join the device-side carry with the trace-time static accounting
    (``info`` is filled by the executor while tracing)."""
    return SSPTelemetry(
        staleness_bound=staleness,
        rounds=rounds,
        flushes=flushes,
        hist=np.asarray(device["hist"]),
        max_staleness=int(device["max_staleness"]),
        clocks=np.asarray(clocks),
        bytes_pushed=int(info.get("push_bytes_per_step", 0)
                         * info.get("num_steps", 0)),
        bytes_deferred_peak=int(info.get("deferred_bytes_peak", 0)),
        bytes_pulled=int(info.get("shared_bytes", 0)) * flushes,
    )
