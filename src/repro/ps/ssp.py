"""The SSP executor: bounded-staleness push/pull on the scanned engine.

Stale-Synchronous Parallel (Xing et al. 2016; LightLDA, Yuan et al. 2014)
relaxes BSP by letting workers read shared parameters up to ``s`` clocks
stale.  On the STRADS primitives that becomes:

* **reads** of server-resident variables (the replicated state leaves —
  see ``repro.ps.server``) are served from a worker-local
  :class:`~repro.ps.cache.StaleCache` instead of the freshly committed
  value;
* **pushes** aggregate lazily: each round's partial results ``z`` go into
  a pending-update buffer (no collective), and only when the staleness
  gate ``clock - cache.clock <= s`` would be violated does a **flush**
  run — one batched psum for every deferred round, then the deferred
  commits (``ssp_commit_shared``, default ``pull``) replayed in round
  order, then a cache refresh;
* **worker-local** state stays exact: commit-through runs every round so
  a worker always sees its *own* writes immediately (the SSP
  read-my-writes guarantee) — only other workers' contributions arrive
  late.

Which writes commit through, which defer, and which schedule-priority
entries are masked for in-flight exclusion is **derived from the app's
placement declarations** (the v2 primitive protocol — see
:mod:`repro.core.primitives` and :class:`repro.core.kvstore.VarTable`):
a ``local`` leaf whose key path names a worker-resident (sharded) state
leaf is its committed value and commits every round; the remaining
``local`` leaves are buffered until the flush, where the app's own
``pull`` replays per deferred round with ``local`` reconstructed;
``role="priority"`` VarSpecs get the in-flight exclusion.  With an
injected scheduler (the v2 scheduler-injection contract) the priority
table lives in the engine-owned scheduler carry instead: the window
scheduler masks it via ``scheduler.mark_scheduled`` between stale
proposals, folds it forward via ``app.sched_update`` per replayed
commit, and returns it as ``SSPCarry.sched_carry``.  Apps that still
define the deprecated v1 ``ssp_*`` hook overrides are honored with a
``DeprecationWarning``.

Rounds therefore execute in windows of ``s + 1``: the first round of a
window reads a fresh snapshot (staleness 0), the last reads one that is
``s`` commits old.  Schedules for a whole window are computed up front
from the same snapshot — the direct generalization of the engine's
``pipeline_depth=1`` schedule prefetch (one-round-stale schedules) to
``≤ s``-round-stale schedules, with the window's ``schedule_stats``
reductions batched into a single collective.

At ``staleness=0`` every window is one round: the gate forces a flush
after every push, the batched psum degenerates to the BSP pull
aggregation, and the executor is **bit-identical** to
``StradsEngine.run_scanned(pipeline_depth=0)`` — the correctness anchor
(``tests/test_ssp.py``).  At ``s >= 1`` the program issues ~2 collectives
per window instead of ~2 per round; the price is staleness error in the
deferred commits, which ``benchmarks/bench_ssp.py`` measures as
objective-vs-round for ``s ∈ {0,1,2,4}``.

Built on the same ``lax.scan`` skeleton as ``run_scanned``: one XLA
program for all R rounds, donated state, no per-round host sync.  The
scan carries ``(state, rng, round counter, vector clocks, telemetry,
engine-wide counters)``; the carry is exposed as :class:`SSPCarry` so a
run can be checkpointed
and resumed exactly (``checkpoint/npz.py`` round-trips it, clocks
included).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.compat import shard_map
from ..core.engine import DATA_AXIS
from ..core.kvstore import VarTable
from ..obs import counters as obs_counters
from . import telemetry as T
from .cache import StaleCache
from .server import ParameterServer, init_clocks, tick


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SSPCarry:
    """Resumable executor carry: PRNG stream, next round, vector clocks,
    the engine-owned scheduler carry (Δx priority history; ``None``
    for stateless policies), and — under a plan-level
    :class:`~repro.obs.spec.TelemetrySpec` — the device telemetry
    counters (:mod:`repro.obs.counters`; ``None`` uninstrumented) — the
    SSP twin of :class:`repro.core.engine.EngineCarry`."""
    rng: jax.Array
    t: jax.Array                 # int32: next round index
    clocks: jax.Array            # (num_workers,) per-worker vector clock
    sched_carry: Any = None      # scheduler carry (Δx history, …)
    obs: Any = None              # device telemetry counters (or None)


def rounds_per_step(engine, staleness: int) -> int:
    """Rounds one scan step unrolls: windows of ``s+1`` must tile the
    app's static-phase cycle, so it is lcm(s+1, phase_period)."""
    return math.lcm(staleness + 1, engine.phase_period)


# ---------------------------------------------------------------------------
# Collective batching
# ---------------------------------------------------------------------------

def _batched_psum(trees: List[Any], axis_name: str) -> List[Any]:
    """psum a list of pytrees in one collective per dtype: every leaf is
    raveled and concatenated, reduced once, and split back.  Elementwise
    sums are unchanged, so this is bit-identical to per-leaf psum — and a
    window's deferred pushes cost one launch.  Single-leaf groups skip
    the concat/split round-trip entirely."""
    flats, defs = zip(*(jax.tree_util.tree_flatten(t) for t in trees))
    leaves = [leaf for f in flats for leaf in f]
    summed: List[Any] = [None] * len(leaves)
    by_dtype: dict = {}
    for i, leaf in enumerate(leaves):
        by_dtype.setdefault(jnp.asarray(leaf).dtype, []).append(i)
    for _, idxs in by_dtype.items():
        if len(idxs) == 1:
            i = idxs[0]
            summed[i] = jax.lax.psum(leaves[i], axis_name)
            continue
        flat = jnp.concatenate([jnp.ravel(leaves[i]) for i in idxs])
        red = jax.lax.psum(flat, axis_name)
        off = 0
        for i in idxs:
            n = leaves[i].size
            summed[i] = red[off:off + n].reshape(leaves[i].shape)
            off += n
    out, k = [], 0
    for f, d in zip(flats, defs):
        out.append(jax.tree_util.tree_unflatten(d, summed[k:k + len(f)]))
        k += len(f)
    return out


# ---------------------------------------------------------------------------
# Commit/defer/exclusion — derived from placement (v2) or legacy hooks
# ---------------------------------------------------------------------------

_LEGACY_HOOKS = ("ssp_commit_local", "ssp_defer_local",
                 "ssp_commit_shared", "ssp_mark_scheduled")


class _DerivedHooks:
    """The v2 contract: everything follows from the VarSpec placement
    (commit-through of worker-resident ``local`` writes, deferral of the
    rest, flush-time replay of the app's own ``pull``, in-flight
    exclusion over ``role="priority"`` leaves)."""

    def __init__(self, app, table: VarTable):
        self.app = app
        self.table = table

    def commit_local(self, state, sched, local, data, phase):
        return self.table.commit_local(state, local, phase)

    def defer_local(self, local, phase):
        return self.table.defer_local(local, phase)

    def commit_shared(self, state, sched, z, keep, data, phase):
        local = self.table.rebuild_local(state, keep, phase)
        return self.app.pull(state, sched, z, local, data, phase)

    def mark_scheduled(self, view, candidates, phase):
        return self.table.mark_scheduled(view, candidates)


class _LegacyHooks:
    """v1 per-app ``ssp_*`` hook overrides (deprecated), with the old
    StradsAppBase defaults filled in for whichever hooks are missing."""

    def __init__(self, app):
        self.app = app

    def commit_local(self, state, sched, local, data, phase):
        fn = getattr(self.app, "ssp_commit_local", None)
        return fn(state, sched, local, data, phase) if fn else state

    def defer_local(self, local, phase):
        fn = getattr(self.app, "ssp_defer_local", None)
        return fn(local, phase) if fn else local

    def commit_shared(self, state, sched, z, keep, data, phase):
        fn = getattr(self.app, "ssp_commit_shared", None)
        if fn:
            return fn(state, sched, z, keep, data, phase)
        return self.app.pull(state, sched, z, keep, data, phase)

    def mark_scheduled(self, view, candidates, phase):
        fn = getattr(self.app, "ssp_mark_scheduled", None)
        return fn(view, candidates, phase) if fn else view


def _make_hooks(app, table: VarTable):
    legacy = [n for n in _LEGACY_HOOKS if callable(getattr(app, n, None))]
    if legacy:
        warnings.warn(
            f"{type(app).__name__} defines v1 SSP hook(s) {legacy}; they "
            f"are deprecated — the v2 protocol derives commit/defer/"
            f"exclusion from VarSpec placement (see repro.core.primitives)",
            DeprecationWarning, stacklevel=3)
        return _LegacyHooks(app)
    return _DerivedHooks(app, table)


# ---------------------------------------------------------------------------
# Round pieces (shard_map regions)
# ---------------------------------------------------------------------------

def _window_schedules(eng, hooks, view, sc, data, subs, ts, phases):
    """propose → [batched schedule_stats psum] → schedule for a whole
    window, all reading the same stale cache view and window-start
    scheduler carry (schedule staleness ≤ s — the generalization of the
    depth-1 pipeline prefetch).  Between proposals the view/carry pass
    through the in-flight exclusion (``scheduler.mark_scheduled`` on the
    engine-owned carry; ``role="priority"`` VarSpecs for state-resident
    tables) so later proposals in the window avoid variables already in
    flight; only later *proposals* see the marks — stats and the schedule
    decisions read the pristine stale view/carry."""
    app = eng.app
    keys = [jax.random.split(sub) for sub in subs]
    cands = []
    marked = view
    marked_sc = sc
    for i, ((r1, _), t, ph) in enumerate(zip(keys, ts, phases)):
        c = app.propose(marked, marked_sc, r1, t, ph)
        cands.append(c)
        if i + 1 < len(subs):        # only later proposals see the mark
            marked = hooks.mark_scheduled(marked, c, ph)
            marked_sc = eng.mark_sched_carry(marked_sc, c)
    if eng._needs_stats:
        def stats_fn(data, st, cands):
            stats = [app.schedule_stats(data, st, c, ph)
                     for c, ph in zip(cands, phases)]
            return tuple(_batched_psum(stats, DATA_AXIS))
        stats = shard_map(
            stats_fn, mesh=eng.mesh,
            in_specs=(eng.data_specs, eng._sspec(view), P()),
            out_specs=P(),
        )(data, view, tuple(cands))
    else:
        stats = [None] * len(subs)
    return [app.schedule(view, sc, c, s, r2, t, ph)
            for c, s, (_, r2), t, ph in zip(cands, stats, keys, ts, phases)]


def _fused_round(eng, hooks, view, data, sched, phase, nbytes_out: list):
    """``staleness=0`` fast path: the window is a single round, so defer
    nothing — push → commit-through → pull aggregation → shared commit in
    ONE shard_map region, structurally the BSP ``_apply`` round (without
    commit-through writes it is exactly push → psum → pull)."""
    app = eng.app
    sspec = eng._sspec(view)
    num_workers = eng.mesh.shape[DATA_AXIS]

    def f(data, st, sched):
        z, local = app.push(data, st, sched, phase)
        st = hooks.commit_local(st, sched, local, data, phase)
        keep = hooks.defer_local(local, phase)
        nbytes_out.append(_tree_nbytes(z) * num_workers)
        Z = jax.tree.map(lambda a: jax.lax.psum(a, DATA_AXIS), z)
        return hooks.commit_shared(st, sched, Z, keep, data, phase)

    return shard_map(f, mesh=eng.mesh,
                     in_specs=(eng.data_specs, sspec, P()),
                     out_specs=sspec)(data, view, sched)


def _push_round(eng, hooks, view, data, sched, phase):
    """push (no aggregation) + the immediate commit-through of
    worker-resident ``local`` writes.

    Partials and deferred locals come back with a leading worker axis
    (sharded over ``data``) — the pending-update buffer layout."""
    app = eng.app
    sspec = eng._sspec(view)

    def f(data, st, sched):
        z, local = app.push(data, st, sched, phase)
        st = hooks.commit_local(st, sched, local, data, phase)
        keep = hooks.defer_local(local, phase)
        pend = jax.tree.map(lambda a: jnp.asarray(a)[None], (z, keep))
        return pend, st

    (z_pend, keep_pend), state = shard_map(
        f, mesh=eng.mesh,
        in_specs=(eng.data_specs, sspec, P()),
        out_specs=(P(DATA_AXIS), sspec),
    )(data, view, sched)
    return z_pend, keep_pend, state


def _flush_aggregate(eng, z_pends):
    """The lazy push: one batched psum over every deferred partial."""
    def f(zs):
        own = [jax.tree.map(lambda a: a[0], z) for z in zs]
        return tuple(_batched_psum(own, DATA_AXIS))

    return shard_map(f, mesh=eng.mesh, in_specs=(P(DATA_AXIS),),
                     out_specs=P())(tuple(z_pends))


def _commit_round(eng, hooks, state, data, sched, z, keep_pend, phase):
    """Replay one deferred commit with its aggregated partials (the app's
    own ``pull`` under the v2 protocol, with ``local`` reconstructed from
    the live state + the deferred buffer)."""
    sspec = eng._sspec(state)

    def f(data, st, sched, z, keep):
        local = jax.tree.map(lambda a: a[0], keep)
        return hooks.commit_shared(st, sched, z, local, data, phase)

    return shard_map(
        f, mesh=eng.mesh,
        in_specs=(eng.data_specs, sspec, P(), P(), P(DATA_AXIS)),
        out_specs=sspec,
    )(data, state, sched, z, keep_pend)


# ---------------------------------------------------------------------------
# The scanned SSP program
# ---------------------------------------------------------------------------

def _tree_nbytes(tree: Any) -> int:
    return sum(leaf.size * jnp.asarray(leaf).dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(tree))


def _build_ssp(eng, num_steps: int, staleness: int,
               collect: Optional[Callable], donate: bool, info: dict):
    W = staleness + 1
    period = eng.phase_period
    L = rounds_per_step(eng, staleness)

    def scanned(state, data, rng, t0, clocks, sc0, obs0=None):
        # The server/cache split follows the engine's KV store when one
        # was built (place_state) — a repartition re-derives that
        # store's VarSpecs, and the per-assignment program cache key
        # guarantees this trace re-runs after a move; engines driven
        # without place_state fall back to the app's declarations.
        if eng.kvstore is not None:
            server = ParameterServer(eng.mesh, eng.kvstore)
        else:
            server = ParameterServer.from_state(eng.mesh, state,
                                                eng._sspec(state),
                                                roles=eng.app_roles())
        hooks = _make_hooks(eng.app, VarTable(server.store))
        # engine-wide counters (the telemetry-injection contract):
        # observe only the schedule pytree, so the instrumented program
        # stays bit-identical in state/PRNG
        num_cand = eng._obs_num_candidates()

        def step(carry, _):
            state, rng, t, clocks, sc, telem, obs = carry
            ys: list = []
            cache = StaleCache(values=server.snapshot(state),
                               clock=jnp.asarray(t, jnp.int32))
            for w0 in range(0, L, W):
                phases = [(w0 + k) % period for k in range(W)]
                ts = []
                subs = []
                for k in range(W):
                    rng, sub = jax.random.split(rng)
                    subs.append(sub)
                    ts.append(t + (w0 + k))
                # The SSP gate, unrolled: this window's last read is
                # exactly at the bound (W - 1 == staleness clocks stale),
                # so the flush below is forced before the next round.
                assert W - 1 <= staleness

                view = server.merge(state, cache.values)
                scheds = _window_schedules(eng, hooks, view, sc, data,
                                           subs, ts, phases)

                if W == 1:
                    # single-round window: nothing to defer — fused path
                    zb: list = []
                    new_state = _fused_round(eng, hooks, view, data,
                                             scheds[0], phases[0], zb)
                    sc = eng._sched_update(sc, view, new_state, scheds[0],
                                           phases[0])
                    state = new_state
                    telem = T.observe_read(telem, ts[0], cache.clock)
                    if obs is not None:
                        obs = obs_counters.observe_round(
                            obs, scheds[0], phases[0], num_cand)
                    clocks = tick(clocks)
                    if not info.get("traced"):
                        info["deferred_bytes_peak"] = max(
                            info.get("deferred_bytes_peak", 0), sum(zb))
                        info["push_bytes_per_step"] = (
                            info.get("push_bytes_per_step", 0) + sum(zb))
                    if collect is not None:
                        ys.append(collect(state))
                    cache = cache.refresh(server.snapshot(state),
                                          ts[-1] + 1)
                    continue

                z_pends, keep_pends = [], []
                for k in range(W):
                    view = server.merge(state, cache.values)
                    zp, kp, state = _push_round(eng, hooks, view, data,
                                                scheds[k], phases[k])
                    z_pends.append(zp)
                    keep_pends.append(kp)
                    telem = T.observe_read(telem, ts[k], cache.clock)
                    if obs is not None:
                        obs = obs_counters.observe_round(
                            obs, scheds[k], phases[k], num_cand)
                    clocks = tick(clocks)

                # The staleness bound now forces a sync: flush the pending
                # buffer (one batched collective), replay the deferred
                # commits in round order, refresh the cache.  The
                # scheduler carry folds forward per replayed commit, in
                # round order — exactly when the deferred Δx commits.
                if not info.get("traced"):
                    wb = sum(_tree_nbytes(z) for z in z_pends)
                    info["deferred_bytes_peak"] = max(
                        info.get("deferred_bytes_peak", 0), wb)
                    info["push_bytes_per_step"] = (
                        info.get("push_bytes_per_step", 0) + wb)
                zs = _flush_aggregate(eng, z_pends)
                for k in range(W):
                    new_state = _commit_round(eng, hooks, state, data,
                                              scheds[k], zs[k],
                                              keep_pends[k], phases[k])
                    sc = eng._sched_update(sc, state, new_state,
                                           scheds[k], phases[k])
                    state = new_state
                    if collect is not None:
                        ys.append(collect(state))
                cache = cache.refresh(server.snapshot(state), ts[-1] + 1)

            out = None
            if collect is not None:
                out = jax.tree.map(lambda *xs: jnp.stack(xs), *ys)
            return (state, rng, t + L, clocks, sc, telem, obs), out

        telem0 = T.device_init(staleness)
        (state, rng, t, clocks, sc, telem, obs), ys = jax.lax.scan(
            step, (state, rng, jnp.asarray(t0, jnp.int32), clocks, sc0,
                   telem0, obs0),
            None, length=num_steps)
        if not info.get("traced"):
            info["traced"] = True
            info["num_steps"] = num_steps
            info["shared_bytes"] = server.shared_nbytes()
        if collect is not None:
            ys = jax.tree.map(
                lambda x: x.reshape((num_steps * L,) + x.shape[2:]), ys)
        return state, SSPCarry(rng=rng, t=t, clocks=clocks,
                               sched_carry=sc, obs=obs), telem, ys

    return jax.jit(scanned, donate_argnums=(0,) if donate else ())


def _get_ssp_fn(eng, num_steps: int, staleness: int,
                collect: Optional[Callable], donate: bool):
    # keyed per (SchedulerSpec, Assignment, KernelSpec): a partition move
    # re-derives the server/cache split from the repartitioned KVStore
    # specs at the next trace, and a swap back to a previous
    # configuration is a cache hit
    key = ("ssp", eng._active_spec, eng._assignment,
           eng._active_kern_spec, num_steps, staleness, collect, donate)
    hit = eng._scan_cache.get(key)
    if hit is None:
        eng._obs_event("cache_miss", program="ssp", num_steps=num_steps,
                       staleness=staleness, **eng._cache_key_args())
        info: dict = {}
        hit = (_build_ssp(eng, num_steps, staleness, collect, donate, info),
               info)
        eng._scan_cache[key] = hit
    return hit


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def ssp_fn(eng, num_rounds: int, *, staleness: int = 0,
           collect: Optional[Callable] = None, donate: bool = True):
    """The jitted ``(state, data, rng, t0, clocks, sched_carry, obs) →
    (state, carry, telemetry, trace)`` SSP program, exposed for AOT
    ``.lower().compile()`` (``launch/dryrun.py --engine ... --staleness``;
    pass ``engine.init_sched_carry()`` for a fresh run and ``None`` — or
    ``repro.obs.init_counters(engine.phase_period)`` — for ``obs``).
    """
    num_steps = _check_rounds(eng, num_rounds, staleness)
    return _get_ssp_fn(eng, num_steps, staleness, collect, donate)[0]


def _check_rounds(eng, num_rounds: int, staleness: int) -> int:
    if staleness < 0:
        raise ValueError(f"staleness must be >= 0, got {staleness}")
    L = rounds_per_step(eng, staleness)
    num_steps, tail = divmod(num_rounds, L)
    if tail or num_steps == 0:
        raise ValueError(
            f"run_ssp needs num_rounds to be a positive multiple of "
            f"lcm(staleness+1, phase_period) = {L}; got {num_rounds}")
    return num_steps


_UNSET = object()


def run_ssp(eng, state, data, rng, num_rounds: int, *, staleness: int = 0,
            collect: Optional[Callable] = None, donate: bool = True,
            with_telemetry: bool = False, t0: int = 0,
            clocks: Optional[jax.Array] = None,
            sched_carry0: Any = _UNSET, obs0: Any = None,
            return_carry: bool = False):
    """Execute ``num_rounds`` rounds under bounded staleness ``s``.

    ``staleness=0`` reproduces ``run_scanned(pipeline_depth=0)`` (and the
    host loop) bit-for-bit — same PRNG stream, same op order.  At ``s>=1``
    reads of server-resident state are up to ``s`` rounds stale and pushes
    aggregate lazily (one batched collective per ``s+1``-round window).

    ``collect(state)`` is evaluated after every committed round inside
    the flush; the stacked trace has leading axis ``num_rounds``.

    ``t0``/``clocks``/``sched_carry0`` resume a previous run (pass the
    values from a saved :class:`SSPCarry`; ``t0`` must be a multiple of
    the step length, ``sched_carry0`` is the engine-owned scheduler
    carry — omitted, a fresh ``scheduler.init_carry()`` is used, which
    is only correct at ``t0=0``).  ``obs0`` threads the engine-wide
    device telemetry counters (:func:`repro.obs.counters.init_counters`,
    or a previous :class:`SSPCarry`'s ``obs``) through the scan;
    ``None`` runs uninstrumented.  ``return_carry=True`` appends the
    final carry to the return value; ``with_telemetry=True`` appends an
    :class:`~repro.ps.telemetry.SSPTelemetry`.
    """
    num_steps = _check_rounds(eng, num_rounds, staleness)
    L = rounds_per_step(eng, staleness)
    if t0 % L:
        raise ValueError(f"t0 must be a multiple of the step length {L} "
                         f"(phase/window alignment); got {t0}")
    num_workers = eng.mesh.shape[DATA_AXIS]
    if clocks is None:
        clocks = init_clocks(num_workers)
    if sched_carry0 is _UNSET:
        sched_carry0 = eng.init_sched_carry()
        if t0 and sched_carry0 is not None:
            warnings.warn(
                "run_ssp(t0>0) without sched_carry0 reinitializes the "
                "stateful scheduler's priorities; pass the "
                "SSPCarry.sched_carry a previous run returned for a "
                "bit-exact resume", UserWarning, stacklevel=2)
    fn, info = _get_ssp_fn(eng, num_steps, staleness, collect, donate)
    state, carry, telem, ys = fn(state, data, rng,
                                 jnp.int32(t0), jnp.asarray(clocks),
                                 sched_carry0, obs0)

    ret = [state]
    if collect is not None:
        ret.append(ys)
    if with_telemetry:
        flushes = num_steps * (L // (staleness + 1))
        ret.append(T.summarize(telem, info, staleness=staleness,
                               rounds=num_rounds, flushes=flushes,
                               clocks=carry.clocks))
    if return_carry:
        ret.append(carry)
    return ret[0] if len(ret) == 1 else tuple(ret)
