"""Worker-local stale caches for server-resident variables.

A worker never talks to the parameter server directly: reads go through a
:class:`StaleCache` — a snapshot of the server values stamped with the
clock it was taken at.  The SSP consistency gate (Xing et al. 2016) is

    clock - cache.clock <= s

i.e. a cached read may be served while it is at most ``s`` commits old;
once the bound would be violated the executor must flush its pending
updates and refresh the cache (the only points where the psum/all-gather
collectives run).  ``repro.ps.ssp`` evaluates the gate while unrolling the
round loop, so the refresh points are compiled into the scanned program —
the gate *is* the window structure, not a runtime branch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StaleCache:
    """A worker's view of the server: values + the clock they were read at.

    ``values`` is the flat {path: array} dict produced by
    :meth:`~repro.ps.server.ParameterServer.snapshot`; ``clock`` is the
    (device) round counter at snapshot time.
    """
    values: Dict[str, Any]
    clock: jax.Array

    def staleness(self, clock) -> jax.Array:
        """How many commits behind the server this cache is."""
        return jnp.asarray(clock, jnp.int32) - self.clock

    def fresh_enough(self, clock, bound: int):
        """The SSP gate: may a read at ``clock`` still be served?"""
        return self.staleness(clock) <= bound

    def refresh(self, values: Dict[str, Any], clock) -> "StaleCache":
        """A fresh snapshot (after a flush made the server current)."""
        return StaleCache(values=values,
                          clock=jnp.asarray(clock, jnp.int32))
