"""The sharded parameter server: versioned shared variables + vector clocks.

The 2014-STRADS model store was a distributed key-value parameter server;
under SPMD its *values* are just the replicated leaves of the state pytree
(see ``core/kvstore.py``).  This module adds what bounded staleness needs
on top of that store:

* a classification of the state into **server-resident** variables (the
  replicated leaves — every worker sees one committed value, refreshed by
  a collective) and **worker-resident** variables (the sharded leaves — a
  worker always reads its own current copy), derived from the same
  ``VarSpec`` machinery the engine uses for placement;
* ``snapshot``/``merge`` — extract the server values into a worker cache
  and serve reads through it (the SSP read path in ``repro.ps.cache``);
* per-worker **vector clocks** (Xing et al. 2016 §SSP): worker p's clock
  counts the rounds it has committed; a cached read is legal while
  ``clock - min_clock <= s``.  Under SPMD the workers advance in lockstep
  so the vector collapses to a shared scalar — we still carry the vector,
  because it is the quantity the SSP invariant (and its property test) is
  stated over, and an asynchronous multi-controller backend would
  diverge it.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core.kvstore import (KVStore, is_replicated, path_name,
                            store_from_tree)


class ParameterServer:
    """Bookkeeping for the server-resident half of an app's state."""

    def __init__(self, mesh: Mesh, store: KVStore):
        self.mesh = mesh
        self.store = store
        self.shared_names = frozenset(
            n for n, vs in store.specs.items() if is_replicated(vs.spec))

    @classmethod
    def from_state(cls, mesh: Mesh, state: Any, spec_tree: Any,
                   roles=None) -> "ParameterServer":
        """``roles`` forwards the app's declarative VarSpec role map
        (``var_roles()``) so the SSP machinery can derive the in-flight
        exclusion from ``role="priority"`` leaves."""
        return cls(mesh, store_from_tree(mesh, state, spec_tree,
                                         roles=roles))

    # -- read path -----------------------------------------------------------

    def snapshot(self, state: Any) -> Dict[str, jax.Array]:
        """The server-resident leaves, as a flat {path: value} cache dict
        (the payload of a worker's :class:`~repro.ps.cache.StaleCache`)."""
        return {path_name(p): leaf
                for p, leaf in jax.tree_util.tree_flatten_with_path(state)[0]
                if path_name(p) in self.shared_names}

    def merge(self, state: Any, cache: Dict[str, jax.Array]) -> Any:
        """Serve a read: server-resident leaves come from the (possibly
        stale) cache, worker-resident leaves from the live state."""
        return jax.tree_util.tree_map_with_path(
            lambda p, x: cache.get(path_name(p), x), state)

    # -- accounting ----------------------------------------------------------

    def shared_nbytes(self) -> int:
        """Bytes a cache refresh moves into every worker (the 'pull')."""
        return sum(self.store.specs[n].nbytes() for n in self.shared_names)

    def local_nbytes(self) -> int:
        return self.store.total_bytes() - self.shared_nbytes()


# ---------------------------------------------------------------------------
# Vector clocks
# ---------------------------------------------------------------------------

def init_clocks(num_workers: int) -> jax.Array:
    """All workers start at clock 0."""
    return jnp.zeros((num_workers,), jnp.int32)


def tick(clocks: jax.Array) -> jax.Array:
    """Every worker commits a round (SPMD: lockstep advance)."""
    return clocks + 1


def min_clock(clocks: jax.Array) -> jax.Array:
    """The slowest worker's clock — the staleness reference point."""
    return jnp.min(clocks)
