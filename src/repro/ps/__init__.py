"""Bounded-staleness parameter-server subsystem (SSP executor).

Layers, bottom up: ``server`` (server-/worker-resident classification of
the state over ``core/kvstore``, vector clocks), ``cache`` (worker-local
stale caches + the SSP consistency gate), ``ssp`` (the scanned
bounded-staleness executor, ``StradsEngine.run_ssp``), ``telemetry``
(staleness histograms, push/pull byte accounting).
"""
from .cache import StaleCache
from .server import ParameterServer, init_clocks, min_clock, tick
from .ssp import SSPCarry, rounds_per_step, run_ssp, ssp_fn
from .telemetry import SSPTelemetry

__all__ = [
    "StaleCache", "ParameterServer", "init_clocks", "min_clock", "tick",
    "SSPCarry", "rounds_per_step", "run_ssp", "ssp_fn", "SSPTelemetry",
]
