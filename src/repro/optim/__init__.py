from .adamw import AdamWConfig, adamw_init, adamw_update, opt_specs  # noqa: F401
from .schedules import cosine_schedule, wsd_schedule  # noqa: F401
