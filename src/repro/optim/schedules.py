"""LR schedules: cosine-with-warmup and WSD (warmup–stable–decay, the
MiniCPM schedule [arXiv:2404.06395] — assigned arch minicpm-2b trains
with it)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor_frac * peak_lr + (1 - floor_frac) * peak_lr \
            * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, warm, cos)
    return lr


def wsd_schedule(peak_lr: float, warmup: int, stable: int, decay: int,
                 floor_frac: float = 0.01):
    """Warmup → flat plateau → short exponential-ish decay tail."""
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        tail = peak_lr * (floor_frac ** t)
        out = jnp.where(s < warmup, warm,
                        jnp.where(s < warmup + stable, peak_lr, tail))
        return out
    return lr
