"""AdamW with dtype-configurable moments and global-norm clipping.

Moments shard exactly like their parameters (the spec tree is reused), so
FSDP params give ZeRO-sharded optimizer state for free.  ``moment_dtype``
lets very large models (llama4-maverick) keep m/v in bf16 to fit the HBM
budget — see DESIGN.md §7 and the dry-run memory analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"


def adamw_init(params: Any, cfg: AdamWConfig) -> Any:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads: Any, opt: Any, params: Any, lr: jax.Array,
                 cfg: AdamWConfig,
                 update_mask: Optional[Callable[[Any], Any]] = None,
                 ) -> Tuple[Any, Any, jax.Array]:
    """One AdamW step.  Returns (new_params, new_opt, pre-clip grad norm).

    ``update_mask``: optional fn(updates_tree) → masked updates — the hook
    the STRADS block scheduler uses to zero unscheduled blocks."""
    count = opt["count"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    dt = jnp.dtype(cfg.moment_dtype)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def moments(g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        return m_new, v_new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    flat_p = treedef.flatten_up_to(params)
    new_m, new_v, upd = [], [], []
    for g, m, v in zip(flat_g, flat_m, flat_v):
        mf, vf = moments(g, m, v)
        new_m.append(mf.astype(dt))
        new_v.append(vf.astype(dt))
        upd.append((mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps))
    updates = jax.tree_util.tree_unflatten(treedef, upd)
    if update_mask is not None:
        updates = update_mask(updates)
    flat_u = jax.tree_util.tree_leaves(updates)
    new_p = [
        (p.astype(jnp.float32)
         - lr * (u + cfg.weight_decay * p.astype(jnp.float32))
         ).astype(p.dtype)
        for p, u in zip(flat_p, flat_u)]
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            {"m": jax.tree_util.tree_unflatten(treedef, new_m),
             "v": jax.tree_util.tree_unflatten(treedef, new_v),
             "count": count},
            gnorm)


def opt_specs(param_spec_tree: Any, mesh) -> Any:
    """Moment specs mirror param specs; count is replicated."""
    from jax.sharding import NamedSharding, PartitionSpec
    return {
        "m": param_spec_tree,
        "v": param_spec_tree,
        "count": NamedSharding(mesh, PartitionSpec()),
    }
