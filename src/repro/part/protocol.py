"""The formal :class:`Partitioner` protocol (the partition-injection
contract).

The engine drives it host-side, at the ``plan.checkpoint_every`` chunk
boundaries of :meth:`repro.core.engine.StradsEngine.execute` — the one
place the state is already synced to the host, so a repartition needs no
XLA-program surgery (the compiled-program caches are keyed per
assignment instead):

    assignment = partitioner.init_assignment()          # once per run
    stats      = partitioner.init_stats()               # None if stateless
    # ... chunk of rounds executes ...
    stats      = partitioner.measure(stats, assignment, activity)
    if partitioner.should_rebalance(stats, assignment, t):
        assignment' = partitioner.propose_assignment(stats, assignment)

* ``init_assignment`` returns the initial variable→worker
  :class:`~repro.part.assignment.Assignment`.
* ``init_stats`` returns the partitioner's host-side activity state
  (e.g. the load-balancer's per-variable activity EMA) or ``None`` for
  stateless policies.  The engine owns it: it checkpoints alongside the
  assignment (the ``{"state", "carry", "assignment"}`` payload), so a
  resumed run reproduces the same rebalance decisions bit-exactly.
* ``measure`` folds one chunk's observed per-variable activity — the
  |Δsignal| the app's ``partition_signal`` exposes (Δx magnitude; the
  same quantity the dynamic scheduler's priorities track) — into the
  stats.  ``activity`` is a ``(J,)`` numpy array, or ``None`` when the
  app declares no signal.
* ``should_rebalance`` decides whether this chunk boundary moves
  variables (cadence + imbalance threshold for the load balancer;
  always ``False`` for the static kinds).
* ``propose_assignment`` returns the new assignment (``version`` bumped)
  — deterministic given (stats, assignment), which is what makes a
  mid-run rebalance resumable.

Everything is host-side numpy: partitioners never trace.  The chosen
assignment reaches devices only through
``StradsEngine.apply_assignment`` (KVStore replacement + app injection +
per-assignment compiled-program keys).
"""
from __future__ import annotations

from typing import Any, Optional, Protocol, runtime_checkable

import numpy as np

from .assignment import Assignment

Stats = Any     # partitioner activity state (host-side numpy, or None)


@runtime_checkable
class Partitioner(Protocol):
    """The pluggable partition policy (built from a
    :class:`~repro.part.spec.PartitionerSpec` by
    :func:`~repro.part.build_partitioner`)."""

    def init_assignment(self) -> Assignment: ...

    def init_stats(self) -> Stats: ...

    def measure(self, stats: Stats, assignment: Assignment,
                activity: Optional[np.ndarray]) -> Stats: ...

    def should_rebalance(self, stats: Stats, assignment: Assignment,
                         t: int) -> bool: ...

    def propose_assignment(self, stats: Stats,
                           assignment: Assignment) -> Assignment: ...


class PartitionerBase:
    """Stateless defaults: no stats, never rebalances, identity
    proposal."""

    def init_stats(self) -> Optional[Any]:
        return None

    def measure(self, stats, assignment, activity):
        return stats

    def should_rebalance(self, stats, assignment, t) -> bool:
        return False

    def propose_assignment(self, stats, assignment) -> Assignment:
        return assignment


def greedy_balance(weights: np.ndarray, num_workers: int,
                   version: int = 0) -> Assignment:
    """Greedy least-loaded bin-packing with balanced capacities — ONE
    implementation for both balancing kinds (sizes for
    ``size_balanced``, activity EMA for ``load_balanced``).

    Variables are placed heaviest-first onto the least-loaded worker
    that still has capacity; capacities are the balanced variable counts
    ``ceil``/``floor(J/U)``, so a load rebalance can never silently
    unbalance the per-worker variable (memory) counts.  Ties break by
    lowest index / lowest worker id — fully deterministic, which is what
    makes a mid-run rebalance checkpoint-resumable."""
    w = np.asarray(weights, np.float64)
    J = w.shape[0]
    if num_workers < 1:
        raise ValueError(f"num_workers must be >= 1; got {num_workers}")
    base, extra = divmod(J, num_workers)
    capacity = np.full((num_workers,), base, np.int64)
    capacity[:extra] += 1
    # stable heaviest-first: ties keep index order
    order = np.argsort(-w, kind="stable")
    owner = np.empty((J,), np.int64)
    loads = np.zeros((num_workers,), np.float64)
    filled = np.zeros((num_workers,), np.int64)
    for j in order:
        open_w = np.flatnonzero(filled < capacity)
        u = open_w[np.argmin(loads[open_w])]     # argmin ties → lowest id
        owner[j] = u
        loads[u] += w[j]
        filled[u] += 1
    return Assignment(owner=tuple(int(o) for o in owner),
                      num_workers=num_workers, version=version)
