"""Partitioners: the paper's variable-placement strategies behind one
protocol.

* :class:`StaticPartitioner` — the frozen contiguous partition (variable
  j on worker ``j·U//J``, the same block bounds the LDA rotation
  scheduler rotates over).  Bit-identical to the pre-subsystem behavior
  where ``place_state`` ran exactly once at init.
* :class:`SizeBalancedPartitioner` — greedy bin-packing on per-variable
  *bytes* once at init (1411.2305-style block ownership: even memory,
  never moves afterwards).
* :class:`LoadBalancedPartitioner` — tracks per-variable update activity
  (an EMA of the |Δx| magnitudes the app's ``partition_signal``
  exposes — the same signal family the dynamic scheduler's priorities
  use) and greedily re-bins variables to equalize per-worker load at
  chunk boundaries (1312.5766-style structure-aware placement).

All three implement the :class:`~repro.part.protocol.Partitioner`
protocol (``init_assignment`` / ``init_stats`` / ``measure`` /
``should_rebalance`` / ``propose_assignment``); the engine builds them
from a declarative :class:`~repro.part.spec.PartitionerSpec` via
:func:`build_partitioner`.  Everything runs host-side on numpy at chunk
boundaries — partitioners never trace, and both balancing kinds share
the ONE greedy bin-packer (:func:`~repro.part.protocol.greedy_balance`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .assignment import Assignment, contiguous_assignment
from .protocol import PartitionerBase, greedy_balance
from .spec import PartitionerSpec


@dataclasses.dataclass(frozen=True)
class StaticPartitioner(PartitionerBase):
    """The frozen contiguous partition — never measures, never moves."""
    num_vars: int
    num_workers: int

    def init_assignment(self) -> Assignment:
        return contiguous_assignment(self.num_vars, self.num_workers)


@dataclasses.dataclass(frozen=True)
class SizeBalancedPartitioner(PartitionerBase):
    """Greedy byte-balanced bins at init; static afterwards.  ``sizes``
    is the per-variable byte vector (the app's ``partition_sizes()``;
    ``None`` = uniform, which degenerates to balanced counts)."""
    num_vars: int
    num_workers: int
    sizes: Optional[tuple] = None

    def init_assignment(self) -> Assignment:
        sizes = (np.ones((self.num_vars,), np.float64)
                 if self.sizes is None
                 else np.asarray(self.sizes, np.float64))
        if sizes.shape != (self.num_vars,):
            raise ValueError(f"sizes must have shape ({self.num_vars},); "
                             f"got {sizes.shape}")
        return greedy_balance(sizes, self.num_workers)


@dataclasses.dataclass(frozen=True)
class LoadBalancedPartitioner(PartitionerBase):
    """Activity-EMA load balancing at chunk boundaries.

    Starts from the contiguous static assignment (so round 0 is
    bit-identical to ``kind="static"``); each chunk folds the observed
    per-variable activity into the EMA (``stats["ema"]``), and a chunk
    boundary at round t rebalances when the cadence admits it
    (``t % rebalance_every == 0``; 0 = every boundary) *and* the current
    assignment's relative load spread over the EMA exceeds
    ``imbalance_threshold``."""
    num_vars: int
    num_workers: int
    rebalance_every: int = 0
    ema: float = 0.0
    imbalance_threshold: float = 0.0

    def init_assignment(self) -> Assignment:
        return contiguous_assignment(self.num_vars, self.num_workers)

    def init_stats(self) -> dict:
        return {"ema": np.zeros((self.num_vars,), np.float64)}

    def measure(self, stats, assignment, activity):
        if activity is None:
            return stats
        a = np.asarray(activity, np.float64)
        if a.shape != (self.num_vars,):
            raise ValueError(f"activity must have shape "
                             f"({self.num_vars},); got {a.shape}")
        prev = stats["ema"]
        return {"ema": self.ema * prev + (1.0 - self.ema) * a}

    def should_rebalance(self, stats, assignment, t) -> bool:
        if self.rebalance_every and t % self.rebalance_every:
            return False
        if not float(stats["ema"].sum()):
            return False            # nothing measured yet
        return assignment.spread(stats["ema"]) > self.imbalance_threshold

    def propose_assignment(self, stats, assignment) -> Assignment:
        return greedy_balance(stats["ema"], self.num_workers,
                              version=assignment.version + 1)


# ---------------------------------------------------------------------------
# Spec → partitioner (the injection registry)
# ---------------------------------------------------------------------------

def build_partitioner(spec: PartitionerSpec, *, num_vars: int,
                      num_workers: int, sizes=None):
    """Materialize the policy a :class:`PartitionerSpec` declares for a
    concrete app: ``num_vars`` is the app's partitionable-variable count
    (``StradsAppBase.num_schedulable()`` — the schedule and the
    partition range over the same variables), ``num_workers`` the
    data-mesh width, ``sizes`` the optional per-variable byte vector
    (``partition_sizes()``).  The spec stays app-agnostic; this is the
    one place structure meets policy."""
    if not isinstance(spec, PartitionerSpec):
        raise TypeError(f"build_partitioner wants a PartitionerSpec; got "
                        f"{type(spec).__name__}")
    if not isinstance(num_vars, int) or num_vars < 1:
        raise ValueError(f"num_vars must be a positive int; got "
                         f"{num_vars!r}")
    if spec.kind == "static":
        return StaticPartitioner(num_vars, num_workers)
    if spec.kind == "size_balanced":
        return SizeBalancedPartitioner(
            num_vars, num_workers,
            sizes=None if sizes is None else tuple(float(s) for s in sizes))
    # "load_balanced" (spec validation admits nothing else)
    return LoadBalancedPartitioner(
        num_vars=num_vars, num_workers=num_workers,
        rebalance_every=spec.rebalance_every, ema=spec.ema,
        imbalance_threshold=spec.imbalance_threshold)
