"""The pluggable partitioning subsystem.

Partitioning is the paper's *other* headline primitive ("partitioning
and update scheduling of model variables"); this package makes partition
*policy* a first-class, declarative part of the execution surface,
mirroring :mod:`repro.sched` exactly:

* :class:`PartitionerSpec` (:mod:`repro.part.spec`) — the frozen,
  hashable, JSON-round-trippable policy value that rides
  ``ExecutionPlan.partitioner``;
* :class:`Assignment` (:mod:`repro.part.assignment`) — the hashable
  variable→worker ownership value the engine keys compiled-program
  caches on and checkpoints alongside the executor carry;
* :class:`Partitioner` (:mod:`repro.part.protocol`) — the formal
  ``init_assignment / init_stats / measure / should_rebalance /
  propose_assignment`` contract every policy implements;
* :mod:`repro.part.partitioners` — the three policies (static,
  size-balanced, load-balanced) sharing ONE greedy bin-packer
  (:func:`greedy_balance`).

The engine drives the protocol at ``plan.checkpoint_every`` chunk
boundaries (:meth:`repro.core.engine.StradsEngine.execute`) — state is
already host-synced there, so repartitioning is a host-side
re-placement, never XLA-program surgery.
"""
from .spec import PARTITIONER_KINDS, PartitionerSpec
from .assignment import Assignment, contiguous_assignment
from .protocol import Partitioner, PartitionerBase, greedy_balance
from .partitioners import (LoadBalancedPartitioner, SizeBalancedPartitioner,
                           StaticPartitioner, build_partitioner)

__all__ = [
    "PARTITIONER_KINDS", "PartitionerSpec", "Assignment",
    "contiguous_assignment", "Partitioner", "PartitionerBase",
    "greedy_balance", "LoadBalancedPartitioner",
    "SizeBalancedPartitioner", "StaticPartitioner", "build_partitioner",
]
