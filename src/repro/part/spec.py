"""The declarative partitioning surface: :class:`PartitionerSpec`.

Partitioning is the paper's *other* headline primitive — "partitioning
and update scheduling of model variables" — and the companion papers
make it dynamic: 1312.5766 balances per-worker work using variable
activity, 1411.2305 moves block ownership across workers.  A
:class:`PartitionerSpec` makes partition *policy* a declarative value on
the :class:`~repro.core.ExecutionPlan`, exactly like
:class:`~repro.sched.spec.SchedulerSpec` made scheduling policy one:

* **frozen + hashable** — a spec is a value, usable as a sweep key;
* **validated at construction** — every invalid kind/parameter
  combination raises here, at spec-build time, never at trace time;
* **JSON-round-trippable** — ``to_json``/``from_json`` are exact
  (defaults included), so specs live inside checked-in plan files
  (``examples/plans/lasso_loadbal.json``), benchmark records
  (``BENCH_part.json``) and CLI flags (``launch/dryrun.py
  --partitioner``).

The spec is policy only — it never names an app.  Structural dimensions
(how many partitionable variables, how many workers, per-variable sizes)
come from the app and mesh at injection time
(``repro.part.build_partitioner``), so one spec sweeps across
lasso/LDA/MF unchanged.
"""
from __future__ import annotations

import dataclasses
import json

PARTITIONER_KINDS = ("static", "size_balanced", "load_balanced")

_KIND_MSG = ("partitioner kind must be 'static', 'size_balanced' or "
             "'load_balanced'; got {!r}")

# Which fields each kind consumes; everything else must stay at its zero
# default (a spec never carries silently-ignored knobs — the same rule
# SchedulerSpec enforces).
_FIELDS_BY_KIND = {
    "static": (),
    "size_balanced": (),
    "load_balanced": ("rebalance_every", "ema", "imbalance_threshold"),
}


@dataclasses.dataclass(frozen=True)
class PartitionerSpec:
    """Everything the engine needs to know about *where* model variables
    live (and when they may move).

    Fields
    ------
    kind:           ``"static"`` (the frozen contiguous partition —
                    variable j lives on worker ``j·U//J`` forever; the
                    bit-identical pre-refactor behavior),
                    ``"size_balanced"`` (greedy bin-packing on
                    per-variable *bytes* once at init — 1411.2305-style
                    block ownership; never moves afterwards),
                    ``"load_balanced"`` (tracks per-variable update
                    activity and greedily re-bins variables to equalize
                    per-worker load at chunk boundaries — the
                    1312.5766-style dynamic placement).
    rebalance_every: minimum rounds between rebalances
                    (``load_balanced`` only; the engine only *checks* at
                    ``plan.checkpoint_every`` chunk boundaries, so a
                    nonzero cadence must be a multiple of the chunk
                    length; 0 = every chunk boundary is eligible).
    ema:            activity EMA decay (``load_balanced`` only;
                    0 ≤ ema < 1, 0 = no memory — each chunk's activity
                    replaces the last).
    imbalance_threshold: relative per-worker load spread
                    ``(max − min) / mean`` above which a rebalance fires
                    (``load_balanced`` only; ≥ 0, 0 = rebalance on any
                    imbalance).
    """

    kind: str
    rebalance_every: int = 0
    ema: float = 0.0
    imbalance_threshold: float = 0.0

    def __post_init__(self):
        if self.kind not in PARTITIONER_KINDS:
            raise ValueError(_KIND_MSG.format(self.kind))
        v = self.rebalance_every
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            raise ValueError(f"rebalance_every must be an int >= 0; "
                             f"got {v!r}")
        for field in ("ema", "imbalance_threshold"):
            v = getattr(self, field)
            if isinstance(v, bool) or not isinstance(v, (int, float)) \
                    or v < 0:
                raise ValueError(f"{field} must be a number >= 0; "
                                 f"got {v!r}")
        used = _FIELDS_BY_KIND[self.kind]
        for field in ("rebalance_every", "ema", "imbalance_threshold"):
            if field not in used and getattr(self, field):
                raise ValueError(
                    f"{field}={getattr(self, field)!r} does not apply to "
                    f"kind={self.kind!r} (leave it at its default)")
        if self.kind == "load_balanced" and not 0 <= self.ema < 1:
            raise ValueError(f"ema must be in [0, 1); got {self.ema!r}")

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        """A plain JSON-safe dict (every field, defaults included) —
        ``from_json(to_json(s)) == s`` exactly."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj) -> "PartitionerSpec":
        """Rebuild from ``to_json`` output, a JSON string, or a partial
        dict (missing fields take their defaults; unknown keys raise)."""
        if isinstance(obj, (str, bytes)):
            obj = json.loads(obj)
        if not isinstance(obj, dict):
            raise TypeError(f"PartitionerSpec.from_json wants a dict or "
                            f"JSON string; got {type(obj).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - known
        if unknown:
            raise ValueError(f"unknown PartitionerSpec field(s): "
                             f"{sorted(unknown)}")
        return cls(**obj)

    @classmethod
    def default_for(cls, kind: str, **overrides) -> "PartitionerSpec":
        """The conventional spec for a kind — the ONE defaults table the
        CLI surfaces (``dryrun --partitioner``) resolve flag-built specs
        from, so per-site copies cannot drift.  ``overrides`` replace
        individual fields on the conventional base."""
        if kind in ("static", "size_balanced"):
            base = dict(kind=kind)
        elif kind == "load_balanced":
            base = dict(kind=kind, ema=0.5, imbalance_threshold=0.1)
        else:
            raise ValueError(_KIND_MSG.format(kind))
        base.update(overrides)
        return cls(**base)
