"""The :class:`Assignment` value: which worker owns each model variable.

An assignment is the *output* of a partitioner — the paper's
variable→worker ownership map for the partitioned model store (1411.2305
calls these block owners; 1312.5766 rebalances them by load).  Like
:class:`~repro.part.spec.PartitionerSpec` it is a frozen, hashable value:
the engine keys its compiled-program caches on the active assignment, so
two runs (or two chunks of one run) under the same assignment share
programs and a rebalance is exactly one cache miss.

It round-trips two ways: ``to_json``/``from_json`` for artifacts
(``BENCH_part.json``, dry-run records) and ``payload``/``from_payload``
as a flat dict of numpy arrays for ``checkpoint/npz`` — the
``{"state", "carry", "assignment"}`` checkpoints
``StradsEngine.execute`` writes at chunk boundaries.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Assignment:
    """Variable→worker ownership: variable ``j`` lives on worker
    ``owner[j]``.

    ``version`` counts rebalances (0 = the initial assignment); it names
    artifacts and makes "did a rebalance happen?" a cheap question —
    equality/hashing still compare the full owner map, so two identical
    proposals at different versions never alias a compiled-program cache
    entry wrongly (equal owners at different versions are *different*
    keys, which only costs a recompile, never a wrong program).
    """
    owner: tuple
    num_workers: int
    version: int = 0

    def __post_init__(self):
        owner = tuple(int(o) for o in self.owner)
        object.__setattr__(self, "owner", owner)
        if not isinstance(self.num_workers, int) or self.num_workers < 1:
            raise ValueError(f"num_workers must be a positive int; got "
                             f"{self.num_workers!r}")
        bad = [o for o in owner if not 0 <= o < self.num_workers]
        if bad:
            raise ValueError(
                f"owner entries must be worker ids in [0, "
                f"{self.num_workers}); got {sorted(set(bad))}")
        if not isinstance(self.version, int) or self.version < 0:
            raise ValueError(f"version must be an int >= 0; got "
                             f"{self.version!r}")

    @property
    def num_vars(self) -> int:
        return len(self.owner)

    # -- accounting ----------------------------------------------------------

    def counts(self) -> np.ndarray:
        """(U,) variables owned per worker."""
        return np.bincount(np.asarray(self.owner, np.int64),
                           minlength=self.num_workers)

    def loads(self, weights) -> np.ndarray:
        """(U,) per-worker load: the sum of ``weights`` (per-variable
        activity, bytes, …) over each worker's owned variables."""
        w = np.asarray(weights, np.float64)
        if w.shape != (self.num_vars,):
            raise ValueError(f"weights must have shape ({self.num_vars},)"
                             f"; got {w.shape}")
        return np.bincount(np.asarray(self.owner, np.int64), weights=w,
                           minlength=self.num_workers)

    def spread(self, weights) -> float:
        """Relative per-worker load spread ``(max − min) / mean`` — the
        imbalance quantity ``PartitionerSpec.imbalance_threshold`` gates
        on and ``BENCH_part.json`` reports (0 = perfectly balanced)."""
        loads = self.loads(weights)
        mean = float(loads.mean())
        if mean == 0.0:
            return 0.0
        return float((loads.max() - loads.min()) / mean)

    # -- serialization (artifacts) -------------------------------------------

    def to_json(self) -> dict:
        return {"owner": list(self.owner),
                "num_workers": self.num_workers,
                "version": self.version}

    @classmethod
    def from_json(cls, obj) -> "Assignment":
        if isinstance(obj, (str, bytes)):
            obj = json.loads(obj)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - known
        if unknown:
            raise ValueError(f"unknown Assignment field(s): "
                             f"{sorted(unknown)}")
        return cls(**obj)

    # -- serialization (checkpoint/npz) --------------------------------------

    def payload(self) -> Dict[str, np.ndarray]:
        """Flat array dict for ``checkpoint/npz`` (the ``"assignment"``
        subtree of a chunked run's checkpoint)."""
        return {"owner": np.asarray(self.owner, np.int32),
                "num_workers": np.int32(self.num_workers),
                "version": np.int32(self.version)}

    @classmethod
    def from_payload(cls, payload: Dict[str, np.ndarray]
                     ) -> Optional["Assignment"]:
        if payload is None:
            return None
        return cls(owner=tuple(int(o) for o in
                               np.asarray(payload["owner"])),
                   num_workers=int(payload["num_workers"]),
                   version=int(payload["version"]))


def contiguous_assignment(num_vars: int, num_workers: int) -> Assignment:
    """The frozen contiguous partition: worker u owns
    ``[bounds[u], bounds[u+1])`` with ``bounds = round(linspace(0, J,
    U+1))`` — bit-identical to
    :attr:`repro.sched.schedulers.RotationScheduler.bounds`, so the
    static assignment and the rotation scheduler's variable→worker
    mapping can never disagree.  The edges are computed through the
    same jnp float32 linspace the rotation scheduler uses: a host-side
    float64 linspace rounds differently at vocab scale (J ≳ 10⁶), which
    would put boundary variables on the wrong worker."""
    import jax.numpy as jnp
    edges = np.asarray(
        jnp.round(jnp.linspace(0, num_vars, num_workers + 1))
        .astype(jnp.int32), np.int64)
    owner = np.searchsorted(edges[1:], np.arange(num_vars), side="right")
    return Assignment(owner=tuple(int(o) for o in owner),
                      num_workers=num_workers)
