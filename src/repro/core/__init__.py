"""STRADS core: primitives, schedulers, BSP engine, sharded KV store."""
from .primitives import (RoundResult, StradsApp, StradsAppBase, tree_psum)
from .schedulers import (DynamicPriorityScheduler, RandomScheduler,
                         RotationScheduler, RoundRobinScheduler,
                         dependency_filter, priority_weights,
                         sample_candidates)
from .engine import (EngineCarry, StradsEngine, single_device_mesh,
                     worker_mesh, DATA_AXIS)
from .kvstore import (KVStore, VarSpec, VarTable, is_replicated,
                      specs_from_tree, store_from_tree)
from .plan import EXECUTORS, ExecutionPlan, ExecutionReport
from . import block_scheduler

__all__ = [
    "RoundResult", "StradsApp", "StradsAppBase", "tree_psum",
    "DynamicPriorityScheduler", "RandomScheduler", "RotationScheduler",
    "RoundRobinScheduler", "dependency_filter", "priority_weights",
    "sample_candidates", "EngineCarry", "StradsEngine",
    "single_device_mesh", "worker_mesh", "DATA_AXIS", "KVStore",
    "VarSpec", "VarTable", "is_replicated", "specs_from_tree",
    "store_from_tree", "EXECUTORS", "ExecutionPlan", "ExecutionReport",
    "block_scheduler",
]
