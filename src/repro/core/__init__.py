"""STRADS core: primitives, schedulers, BSP engine, sharded KV store."""
from .primitives import (RoundResult, StradsApp, StradsAppBase, tree_psum)
from .schedulers import (DynamicPriorityScheduler, RandomScheduler,
                         RotationScheduler, RoundRobinScheduler,
                         dependency_filter, priority_weights,
                         sample_candidates)
from .engine import StradsEngine, single_device_mesh, worker_mesh, DATA_AXIS
from .kvstore import (KVStore, VarSpec, is_replicated, specs_from_tree,
                      store_from_tree)
from . import block_scheduler

__all__ = [
    "RoundResult", "StradsApp", "StradsAppBase", "tree_psum",
    "DynamicPriorityScheduler", "RandomScheduler", "RotationScheduler",
    "RoundRobinScheduler", "dependency_filter", "priority_weights",
    "sample_candidates", "StradsEngine", "single_device_mesh",
    "worker_mesh", "DATA_AXIS", "KVStore", "VarSpec", "is_replicated",
    "specs_from_tree", "store_from_tree", "block_scheduler",
]
