"""STRADS core: primitives, BSP engine, sharded KV store, execution plans.

Scheduling policy lives in its own subsystem, :mod:`repro.sched`
(``SchedulerSpec`` + the ``Scheduler`` protocol); the classic scheduler
names are re-exported here for compatibility, and the old
``repro.core.schedulers`` / ``repro.core.block_scheduler`` module paths
remain as deprecation shims.  Partition policy mirrors it in
:mod:`repro.part` (``PartitionerSpec`` + the ``Partitioner`` protocol +
the variable→worker ``Assignment``), completing the paper's primitive
pair: ``ExecutionPlan`` swaps both without touching app code.  Kernel
backends follow in :mod:`repro.kernels` (``KernelSpec`` +
``build_kernels``): the round body's compute hot-spots are the third
leg of the same declarative surface.
"""
from .primitives import (RoundResult, StradsApp, StradsAppBase, tree_psum)
from ..kernels import KERNEL_KINDS, KernelSpec, build_kernels
from ..part import (PARTITIONER_KINDS, Assignment, Partitioner,
                    PartitionerSpec, build_partitioner,
                    contiguous_assignment)
from ..sched import (SCHEDULER_KINDS, Scheduler, SchedulerSpec,
                     BlockStructuralScheduler, DynamicPriorityScheduler,
                     RandomScheduler, RotationScheduler,
                     RoundRobinScheduler, build_scheduler,
                     dependency_filter, priority_weights,
                     sample_candidates, structural_gram)
from .engine import (EngineCarry, StradsEngine, single_device_mesh,
                     worker_mesh, DATA_AXIS)
from .kvstore import (KVStore, VarSpec, VarTable, is_replicated,
                      specs_from_tree, store_from_tree)
from .plan import EXECUTORS, ExecutionPlan, ExecutionReport

__all__ = [
    "RoundResult", "StradsApp", "StradsAppBase", "tree_psum",
    "KERNEL_KINDS", "KernelSpec", "build_kernels",
    "PARTITIONER_KINDS", "Assignment", "Partitioner", "PartitionerSpec",
    "build_partitioner", "contiguous_assignment",
    "SCHEDULER_KINDS", "Scheduler", "SchedulerSpec",
    "BlockStructuralScheduler", "DynamicPriorityScheduler",
    "RandomScheduler", "RotationScheduler", "RoundRobinScheduler",
    "build_scheduler", "dependency_filter", "priority_weights",
    "sample_candidates", "structural_gram", "EngineCarry", "StradsEngine",
    "single_device_mesh", "worker_mesh", "DATA_AXIS", "KVStore",
    "VarSpec", "VarTable", "is_replicated", "specs_from_tree",
    "store_from_tree", "EXECUTORS", "ExecutionPlan", "ExecutionReport",
]
