"""Beyond-paper: STRADS block-coordinate scheduling for deep-net training.

The 2014 paper schedules *individual* model variables (Lasso coefficients,
word-topic rows).  A 2026 Big Model has billions of parameters organized
into natural blocks — transformer layers, MoE experts, embedding slices.
This module transplants the paper's DynamicPriority schedule to those
blocks:

* priority  c_b ∝ ‖Δθ_b‖ + η            (the Lasso f₁ rule, per block)
* dependency filter: adjacent layers are "correlated" (their gradients
  flow through each other); we avoid co-scheduling blocks closer than
  ``min_distance`` — the ρ filter with the graph distance standing in for
  |x_jᵀx_k| (for deep nets the Gram surrogate is structural, not data-
  dependent, so it costs nothing at runtime).
* push/pull: the optimizer update for unscheduled blocks is masked to
  zero, so per step only the scheduled blocks move — block-coordinate
  descent over the network.

The MoE router is the same idea executed at token granularity (router =
schedule, expert FFN = push, weighted combine = pull, all_to_all = sync);
see models/moe.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .schedulers import sample_candidates


@dataclasses.dataclass(frozen=True)
class BlockScheduleConfig:
    num_blocks: int
    blocks_per_step: int          # U
    candidates_per_step: int      # U' ≥ U
    min_distance: int = 2         # dependency filter radius (layers)
    eta: float = 1e-3             # exploration floor (paper's η)
    ema: float = 0.9              # priority EMA decay


def init_priority(cfg: BlockScheduleConfig) -> jax.Array:
    """Uniform initial priorities (all blocks equally urgent)."""
    return jnp.ones((cfg.num_blocks,), jnp.float32)


def select_blocks(cfg: BlockScheduleConfig, priority: jax.Array,
                  rng: jax.Array) -> jax.Array:
    """schedule(): returns a (num_blocks,) 0/1 mask of blocks to update."""
    cand = sample_candidates(rng, priority + cfg.eta, cfg.candidates_per_step)

    # Greedy distance filter over candidates (ρ-filter, structural form).
    def body(i, carry):
        mask, count = carry
        j = cand[i]
        pos = jnp.arange(cfg.num_blocks)
        near = (jnp.abs(pos - j) < cfg.min_distance) & (mask > 0)
        ok = (~jnp.any(near)) & (count < cfg.blocks_per_step)
        mask = mask.at[j].set(jnp.where(ok, 1.0, mask[j]))
        return mask, count + ok.astype(jnp.int32)

    mask0 = jnp.zeros((cfg.num_blocks,), jnp.float32)
    mask, _ = jax.lax.fori_loop(0, cfg.candidates_per_step, body,
                                (mask0, jnp.int32(0)))
    return mask


def update_priority(cfg: BlockScheduleConfig, priority: jax.Array,
                    block_update_norms: jax.Array,
                    scheduled: jax.Array) -> jax.Array:
    """pull-side bookkeeping: EMA of per-block update magnitude.

    Only scheduled blocks observed an update this step; unscheduled blocks
    keep their stale priority (they will decay toward rescheduling via η)."""
    new = cfg.ema * priority + (1 - cfg.ema) * block_update_norms
    return jnp.where(scheduled > 0, new, priority)


def mask_updates_by_block(updates: Any, block_of_param: Dict[str, int],
                          mask: jax.Array) -> Any:
    """Zero the optimizer update of every parameter whose block is
    unscheduled.  ``block_of_param`` maps flattened param path → block id."""
    flat = jax.tree_util.tree_flatten_with_path(updates)
    leaves, treedef = flat
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        b = block_of_param.get(name, None)
        out.append(leaf if b is None else leaf * mask[b])
    return jax.tree_util.tree_unflatten(treedef, out)


def block_norms(updates: Any, block_of_param: Dict[str, int],
                num_blocks: int) -> jax.Array:
    """Per-block L2 norm of the (pre-mask) updates — feeds priorities."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(updates)
    sq = jnp.zeros((num_blocks,), jnp.float32)
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        b = block_of_param.get(name, None)
        if b is not None:
            sq = sq.at[b].add(jnp.sum(jnp.square(leaf).astype(jnp.float32)))
    return jnp.sqrt(sq)
