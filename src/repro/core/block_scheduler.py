"""Deprecated shim: the block scheduler moved to :mod:`repro.sched.block`.

``repro.core.block_scheduler`` re-exports the same names so old imports
keep working (with a :class:`DeprecationWarning`, matching the PR 3 shim
pattern); new code should import from :mod:`repro.sched.block`, where the
structural distance filter is now a backend of the *same* greedy
ρ-dependency filter the Lasso scheduler uses.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.block_scheduler moved to repro.sched.block (the pluggable "
    "scheduler subsystem); import BlockScheduleConfig/select_blocks/"
    "update_priority/mask_updates_by_block/block_norms from "
    "repro.sched.block instead", DeprecationWarning, stacklevel=2)

from ..sched.block import (  # noqa: E402
    BlockScheduleConfig, block_norms, config_from_spec, init_priority,
    mask_updates_by_block, select_blocks, update_priority)

__all__ = [
    "BlockScheduleConfig", "block_norms", "config_from_spec",
    "init_priority", "mask_updates_by_block", "select_blocks",
    "update_priority",
]
