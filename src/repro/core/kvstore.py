"""Sharded model store — the paper's "distributed, partitioned key-value
store" holding the globally-accessible model variables x.

In 2014-STRADS this was a parameter server with an explicit BSP ``sync``.
Under SPMD the store is simply a pytree of ``jax.Array`` values placed with
``NamedSharding``; reads are RDMA-free (XLA inserts the collectives), and
BSP sync is program order.  This module keeps the *bookkeeping* value of
the KV store: named variables, their partition specs, byte accounting
(used by the Fig-3 memory benchmark), and (re)placement helpers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def path_name(path) -> str:
    """'/'-joined pytree key path (same convention as checkpoint/npz)."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def is_replicated(spec: P) -> bool:
    """True iff a PartitionSpec places the variable on every device whole
    — the paper's synced KV-store values (vs worker-local partitions)."""
    return all(axis is None for axis in spec)


@dataclasses.dataclass
class VarSpec:
    """Declared model variable: shape/dtype + how it shards."""
    shape: tuple
    dtype: Any
    spec: P = P()          # replicated by default (data-parallel style)

    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize

    def nbytes_per_device(self, mesh: Mesh) -> int:
        """Bytes a single device holds — the Fig-3 quantity."""
        shard = 1
        for axis_names in self.spec:
            if axis_names is None:
                continue
            names = axis_names if isinstance(axis_names, tuple) else (axis_names,)
            for n in names:
                shard *= mesh.shape[n]
        return self.nbytes() // max(shard, 1)


class KVStore:
    """A named, sharded model-variable store with BSP semantics."""

    def __init__(self, mesh: Mesh, specs: Mapping[str, VarSpec]):
        self.mesh = mesh
        self.specs = dict(specs)

    # -- placement ----------------------------------------------------------

    def sharding(self, name: str) -> NamedSharding:
        return NamedSharding(self.mesh, self.specs[name].spec)

    def init(self, rng: jax.Array, initializers: Mapping[str, Any]
             ) -> Dict[str, jax.Array]:
        """Materialize all variables, sharded.  ``initializers[name]`` is
        either a constant or a callable ``(rng, shape, dtype) -> array``."""
        out = {}
        keys = jax.random.split(rng, max(len(self.specs), 1))
        for k, (name, vs) in zip(keys, sorted(self.specs.items())):
            init = initializers.get(name, 0)
            if callable(init):
                arr = init(k, vs.shape, vs.dtype)
            else:
                arr = jax.numpy.full(vs.shape, init, vs.dtype)
            out[name] = jax.device_put(arr, self.sharding(name))
        return out

    def place(self, values: Mapping[str, Any]) -> Dict[str, jax.Array]:
        return {name: jax.device_put(v, self.sharding(name))
                for name, v in values.items()}

    def place_tree(self, tree: Any) -> Any:
        """Place an arbitrary state pytree: every leaf goes to the device
        placement its declared VarSpec mandates (leaves are matched by
        '/'-joined key path)."""
        return jax.tree_util.tree_map_with_path(
            lambda p, x: jax.device_put(x, self.sharding(path_name(p))),
            tree)

    # -- accounting (Fig 3) -------------------------------------------------

    def total_bytes(self) -> int:
        return sum(vs.nbytes() for vs in self.specs.values())

    def bytes_per_device(self) -> int:
        """Model-store bytes each device must hold.

        Model-parallel stores *shrink* per-device as the mesh grows;
        replicated (data-parallel) stores do not — the paper's central
        memory claim (Fig 3)."""
        return sum(vs.nbytes_per_device(self.mesh)
                   for vs in self.specs.values())

    def partition_specs(self) -> Dict[str, P]:
        return {name: vs.spec for name, vs in self.specs.items()}


# ---------------------------------------------------------------------------
# Pytree adapters — declare a store from a live state template
# ---------------------------------------------------------------------------

def specs_from_tree(tree: Any, spec_tree: Any) -> Dict[str, VarSpec]:
    """VarSpec per leaf of a state pytree (names are '/'-joined paths).

    ``spec_tree`` is the matching PartitionSpec pytree (PartitionSpecs are
    leaves), exactly what :class:`~repro.core.engine.StradsEngine` takes as
    ``state_specs``."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    sflat = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, P))[0]
    if len(flat) != len(sflat):
        raise ValueError(f"state has {len(flat)} leaves but the spec tree "
                         f"has {len(sflat)}")
    out = {}
    for (path, leaf), (spath, spec) in zip(flat, sflat):
        name = path_name(path)
        if name != path_name(spath):
            raise ValueError(f"state/spec tree mismatch: leaf {name!r} "
                             f"paired with spec {path_name(spath)!r}")
        out[name] = VarSpec(tuple(leaf.shape),
                            jax.numpy.asarray(leaf).dtype, spec)
    return out


def store_from_tree(mesh: Mesh, tree: Any, spec_tree: Any) -> KVStore:
    """A KVStore whose variables mirror a live state pytree."""
    return KVStore(mesh, specs_from_tree(tree, spec_tree))
