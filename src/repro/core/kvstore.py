"""Sharded model store — the paper's "distributed, partitioned key-value
store" holding the globally-accessible model variables x.

In 2014-STRADS this was a parameter server with an explicit BSP ``sync``.
Under SPMD the store is simply a pytree of ``jax.Array`` values placed with
``NamedSharding``; reads are RDMA-free (XLA inserts the collectives), and
BSP sync is program order.  This module keeps the *bookkeeping* value of
the KV store: named variables, their partition specs, byte accounting
(used by the Fig-3 memory benchmark), and (re)placement helpers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def path_name(path) -> str:
    """'/'-joined pytree key path (same convention as checkpoint/npz)."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def is_replicated(spec: P) -> bool:
    """True iff a PartitionSpec places the variable on every device whole
    — the paper's synced KV-store values (vs worker-local partitions)."""
    return all(axis is None for axis in spec)


@dataclasses.dataclass
class VarSpec:
    """Declared model variable: shape/dtype + how it shards + its role.

    ``role`` is a declarative tag the runtime derives behavior from
    (instead of per-app hook overrides — the v2 primitive protocol):

    * ``"model"`` (default) — an ordinary model variable; placement alone
      decides how executors treat it (replicated ⇒ server-resident,
      sharded ⇒ worker-resident).
    * ``"priority"`` — a scheduling-priority table indexed by variable id
      (e.g. Lasso's Δβ history).  The SSP window scheduler excludes
      in-flight candidates by zeroing their entries in every
      ``"priority"`` leaf of the *scheduling view* (the STRADS in-flight
      exclusion rule, generalized to ≤ s-stale windows).
    """
    shape: tuple
    dtype: Any
    spec: P = P()          # replicated by default (data-parallel style)
    role: str = "model"    # "model" | "priority"

    VALID_ROLES = ("model", "priority")

    def __post_init__(self):
        if self.role not in self.VALID_ROLES:
            raise ValueError(
                f"VarSpec.role must be one of {list(self.VALID_ROLES)} "
                f"('model' = ordinary variable, 'priority' = scheduling-"
                f"priority table masked for SSP in-flight exclusion); "
                f"got {self.role!r}")

    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize

    def nbytes_per_device(self, mesh: Mesh) -> int:
        """Bytes a single device holds — the Fig-3 quantity."""
        shard = 1
        for axis_names in self.spec:
            if axis_names is None:
                continue
            names = axis_names if isinstance(axis_names, tuple) else (axis_names,)
            for n in names:
                shard *= mesh.shape[n]
        return self.nbytes() // max(shard, 1)


class KVStore:
    """A named, sharded model-variable store with BSP semantics."""

    def __init__(self, mesh: Mesh, specs: Mapping[str, VarSpec]):
        self.mesh = mesh
        self.specs = dict(specs)
        #: the active variable→worker Assignment (repro.part) — None
        #: until the engine repartitions through this store
        self.assignment = None

    # -- placement ----------------------------------------------------------

    def sharding(self, name: str) -> NamedSharding:
        return NamedSharding(self.mesh, self.specs[name].spec)

    def init(self, rng: jax.Array, initializers: Mapping[str, Any]
             ) -> Dict[str, jax.Array]:
        """Materialize all variables, sharded.  ``initializers[name]`` is
        either a constant or a callable ``(rng, shape, dtype) -> array``."""
        out = {}
        keys = jax.random.split(rng, max(len(self.specs), 1))
        for k, (name, vs) in zip(keys, sorted(self.specs.items())):
            init = initializers.get(name, 0)
            if callable(init):
                arr = init(k, vs.shape, vs.dtype)
            else:
                arr = jax.numpy.full(vs.shape, init, vs.dtype)
            out[name] = jax.device_put(arr, self.sharding(name))
        return out

    def place(self, values: Mapping[str, Any]) -> Dict[str, jax.Array]:
        return {name: jax.device_put(v, self.sharding(name))
                for name, v in values.items()}

    def place_tree(self, tree: Any) -> Any:
        """Place an arbitrary state pytree: every leaf goes to the device
        placement its declared VarSpec mandates (leaves are matched by
        '/'-joined key path)."""
        return jax.tree_util.tree_map_with_path(
            lambda p, x: jax.device_put(x, self.sharding(path_name(p))),
            tree)

    def repartition(self, assignment, state: Any = None,
                    leaf_specs: Optional[Mapping[str, P]] = None) -> Any:
        """Adopt a new variable→worker
        :class:`~repro.part.assignment.Assignment` — the paper's dynamic
        partitioning move, applied where placement is owned.

        ``leaf_specs`` maps leaf names to new :class:`PartitionSpec`\\ s
        for leaves whose *device placement* the move changes (a
        replicated leaf becoming sharded, or vice versa); their VarSpecs
        are re-derived in place, so the Fig-3 byte accounting
        (:meth:`bytes_per_device`, :meth:`nbytes_per_device`) stays
        truthful after the move.  Built-in apps keep their leaf placement
        fixed (ownership moves are bookkeeping-level), so they pass no
        ``leaf_specs`` — the hook exists for stores whose physical layout
        follows ownership.

        With ``state``, every worker-resident leaf (and every leaf whose
        spec just changed) is re-placed through ``device_put`` and the
        re-placed pytree returned; without it, only the bookkeeping
        updates."""
        moved = set()
        for name, spec in dict(leaf_specs or {}).items():
            if name not in self.specs:
                raise ValueError(f"repartition names unknown variable "
                                 f"{name!r} (store has "
                                 f"{sorted(self.specs)})")
            self.specs[name] = dataclasses.replace(self.specs[name],
                                                   spec=spec)
            moved.add(name)
        self.assignment = assignment
        if state is None:
            return None
        return jax.tree_util.tree_map_with_path(
            lambda p, x: jax.device_put(x, self.sharding(path_name(p)))
            if (path_name(p) in moved
                or not is_replicated(self.specs[path_name(p)].spec))
            else x, state)

    # -- accounting (Fig 3) -------------------------------------------------

    def total_bytes(self) -> int:
        return sum(vs.nbytes() for vs in self.specs.values())

    def bytes_per_device(self) -> int:
        """Model-store bytes each device must hold.

        Model-parallel stores *shrink* per-device as the mesh grows;
        replicated (data-parallel) stores do not — the paper's central
        memory claim (Fig 3)."""
        return sum(vs.nbytes_per_device(self.mesh)
                   for vs in self.specs.values())

    def partition_specs(self) -> Dict[str, P]:
        return {name: vs.spec for name, vs in self.specs.items()}


# ---------------------------------------------------------------------------
# Pytree adapters — declare a store from a live state template
# ---------------------------------------------------------------------------

def specs_from_tree(tree: Any, spec_tree: Any,
                    roles: Optional[Mapping[str, str]] = None
                    ) -> Dict[str, VarSpec]:
    """VarSpec per leaf of a state pytree (names are '/'-joined paths).

    ``spec_tree`` is the matching PartitionSpec pytree (PartitionSpecs are
    leaves), exactly what :class:`~repro.core.engine.StradsEngine` takes as
    ``state_specs``.  ``roles`` maps leaf paths to VarSpec roles (apps
    declare them via ``var_roles()``; unknown paths raise)."""
    roles = dict(roles or {})
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    sflat = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, P))[0]
    if len(flat) != len(sflat):
        raise ValueError(f"state has {len(flat)} leaves but the spec tree "
                         f"has {len(sflat)}")
    out = {}
    for (path, leaf), (spath, spec) in zip(flat, sflat):
        name = path_name(path)
        if name != path_name(spath):
            raise ValueError(f"state/spec tree mismatch: leaf {name!r} "
                             f"paired with spec {path_name(spath)!r}")
        out[name] = VarSpec(tuple(leaf.shape),
                            jax.numpy.asarray(leaf).dtype, spec,
                            role=roles.pop(name, "model"))
    if roles:
        raise ValueError(f"var_roles names unknown state leaves: "
                         f"{sorted(roles)}")
    return out


def store_from_tree(mesh: Mesh, tree: Any, spec_tree: Any,
                    roles: Optional[Mapping[str, str]] = None) -> KVStore:
    """A KVStore whose variables mirror a live state pytree."""
    return KVStore(mesh, specs_from_tree(tree, spec_tree, roles=roles))


# ---------------------------------------------------------------------------
# VarTable — the v2 push/pull write contract, derived from placement
# ---------------------------------------------------------------------------

class VarTable:
    """Placement-aware view of the state for the v2 primitive protocol.

    The protocol (documented in :mod:`repro.core.primitives`): ``push``
    returns ``(z, local)``; any ``local`` leaf whose '/'-joined key path
    names a **worker-resident** state leaf (non-replicated VarSpec) *is*
    the committed new value of that leaf — the commit-through set.
    Executors that defer cross-worker aggregation (SSP) commit those
    leaves immediately every round (the read-my-writes guarantee) and
    buffer only the remaining ``local`` leaves until the flush, where the
    app's own ``pull`` is replayed with ``local`` reconstructed
    (commit-through entries read back from the live state, deferred
    entries from the buffer).

    This class derives all of that — plus the in-flight exclusion over
    ``role="priority"`` leaves — from the :class:`VarSpec` declarations,
    replacing the four per-app ``ssp_*`` hook overrides of the v1
    protocol.
    """

    def __init__(self, store: KVStore):
        self.store = store
        self.worker_resident = frozenset(
            n for n, vs in store.specs.items()
            if not is_replicated(vs.spec))
        self.priority_names = frozenset(
            n for n, vs in store.specs.items() if vs.role == "priority")
        # phase -> (local treedef, leaf paths, commit-through name set),
        # captured at defer time so flush-time rebuilds are structural.
        self._local_forms: Dict[int, tuple] = {}

    # -- classification ------------------------------------------------------

    def _local_form(self, local: Any, phase: int):
        flat, treedef = jax.tree_util.tree_flatten_with_path(local)
        names = [path_name(p) for p, _ in flat]
        commit = frozenset(n for n in names if n in self.worker_resident)
        form = (treedef, names, commit)
        prev = self._local_forms.setdefault(phase, form)
        if prev[1] != names:
            raise ValueError(
                f"push returned a different `local` structure for phase "
                f"{phase}: {prev[1]} vs {names}")
        return form

    def commit_names(self, local: Any, phase: int):
        """The commit-through subset of a ``local`` pytree's leaf paths."""
        return self._local_form(local, phase)[2]

    # -- the derived commit/defer/rebuild triple ----------------------------

    def commit_local(self, state: Any, local: Any, phase: int) -> Any:
        """Write the commit-through leaves into the state (runs every
        round, inside the worker's shard_map region)."""
        _, names, commit = self._local_form(local, phase)
        if not commit:
            return state
        vals = dict(zip(names, jax.tree_util.tree_leaves(local)))
        return jax.tree_util.tree_map_with_path(
            lambda p, x: vals[path_name(p)]
            if path_name(p) in commit else x, state)

    def defer_local(self, local: Any, phase: int) -> Dict[str, Any]:
        """The flat ``{path: leaf}`` dict of non-commit-through leaves —
        the only part of ``local`` the flush still needs to buffer."""
        _, names, commit = self._local_form(local, phase)
        return {n: leaf for n, leaf in
                zip(names, jax.tree_util.tree_leaves(local))
                if n not in commit}

    def rebuild_local(self, state: Any, deferred: Dict[str, Any],
                      phase: int) -> Any:
        """Reconstruct the round's ``local`` pytree at flush time:
        commit-through entries read back from the live state (their
        committed values), deferred entries from the buffer."""
        if phase not in self._local_forms:
            raise ValueError(f"no local structure recorded for phase "
                             f"{phase} (defer_local not called)")
        treedef, names, commit = self._local_forms[phase]
        svals = {path_name(p): leaf for p, leaf in
                 jax.tree_util.tree_flatten_with_path(state)[0]}
        leaves = [svals[n] if n in commit else deferred[n] for n in names]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # -- in-flight exclusion (role="priority") -------------------------------

    def mark_scheduled(self, view: Any, candidates: Any) -> Any:
        """Exclude in-flight candidates from later schedule proposals in
        the same SSP window: zero their entries in every
        ``role="priority"`` leaf of the scheduling view (pending updates
        are invisible until the flush, so rescheduling them would
        compound the same stale read).  ``candidates`` must be an integer
        index array when any priority leaf is declared."""
        if not self.priority_names or candidates is None:
            return view
        idx = jax.numpy.asarray(candidates)
        if not jax.numpy.issubdtype(idx.dtype, jax.numpy.integer):
            raise TypeError(
                f"role='priority' in-flight exclusion needs integer "
                f"candidate indices; got dtype {idx.dtype}")
        return jax.tree_util.tree_map_with_path(
            lambda p, x: x.at[idx].set(jax.numpy.zeros((), x.dtype))
            if path_name(p) in self.priority_names else x, view)
