"""Schedulers: the paper's three ``schedule`` strategies, jit-friendly.

* :class:`RoundRobinScheduler` — fixed cyclic blocks (STRADS MF; and the
  Lasso-RR baseline, which imitates Shotgun random scheduling).
* :class:`RotationScheduler` — word-rotation over U disjoint blocks
  (STRADS LDA): worker p owns block ``(p + t) mod U`` at round t, so every
  worker touches every block once per U rounds and concurrently-sampled
  variables stay disjoint.
* :class:`DynamicPriorityScheduler` — the STRADS Lasso strategy: sample U'
  candidates with probability c_j ∝ |x_j^(t-1) − x_j^(t-2)| + η, then
  greedily keep a subset of size ≤ U whose pairwise dependencies are below
  ρ (|x_jᵀx_k| < ρ), preventing the divergence of naive parallel CD on
  correlated designs (Bradley et al., 2011).

Everything is shape-static so it jits: candidate sets have fixed size U′,
the filtered schedule is a fixed-size index vector with a validity mask.

Scheduler state lives on-device as explicit *scan carries*, never
host-side: :class:`DynamicPriorityScheduler` owns its Δx history through
``init_carry``/``update_carry`` (the app threads the carry through its
state pytree, so the scanned executor in :mod:`repro.core.engine` rolls it
through ``lax.scan`` untouched); :class:`RotationScheduler`'s only state
is the round counter, which the engine carries as ``t``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Static schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundRobinScheduler:
    """Cyclic fixed-size blocks over J variables.

    Round t schedules indices ``[t*U, ..., (t+1)*U) mod J``.
    """
    num_vars: int
    block_size: int

    def __call__(self, t: jax.Array) -> jax.Array:
        start = (t * self.block_size) % self.num_vars
        idx = (start + jnp.arange(self.block_size)) % self.num_vars
        return idx


@dataclasses.dataclass(frozen=True)
class RandomScheduler:
    """Uniform random block (the Shotgun / Lasso-RR baseline)."""
    num_vars: int
    block_size: int

    def __call__(self, rng: jax.Array) -> jax.Array:
        return jax.random.choice(
            rng, self.num_vars, shape=(self.block_size,), replace=False)


@dataclasses.dataclass(frozen=True)
class RotationScheduler:
    """Word-rotation over U disjoint variable blocks (STRADS LDA).

    ``block_for_worker(p, t) = (p + t) mod U``.  Blocks are the contiguous
    partition of ``num_vars`` into U chunks; chunk u is
    ``[bounds[u], bounds[u+1])``.
    """
    num_vars: int
    num_workers: int

    @property
    def bounds(self) -> jnp.ndarray:
        edges = jnp.linspace(0, self.num_vars, self.num_workers + 1)
        return jnp.round(edges).astype(jnp.int32)

    def block_for_worker(self, p: jax.Array, t: jax.Array) -> jax.Array:
        return (p + t) % self.num_workers

    def block_mask(self, block: jax.Array) -> jax.Array:
        """Boolean mask of shape (num_vars,): which vars are in ``block``."""
        b = self.bounds
        j = jnp.arange(self.num_vars)
        return (j >= b[block]) & (j < b[block + 1])


# ---------------------------------------------------------------------------
# Dynamic priority + dependency filter (STRADS Lasso)
# ---------------------------------------------------------------------------

def priority_weights(delta: jax.Array, eta: float) -> jax.Array:
    """c_j ∝ |Δx_j| + η  (paper §3.3, f₁)."""
    return jnp.abs(delta) + eta


def sample_candidates(rng: jax.Array, weights: jax.Array,
                      num_candidates: int) -> jax.Array:
    """Draw U′ distinct candidates ∝ weights via Gumbel top-k.

    Gumbel-top-k gives exact sampling-without-replacement from the
    categorical distribution ∝ weights, fully vectorized (no rejection
    loop), which is what makes the dynamic schedule cheap on-device.
    """
    logits = jnp.log(jnp.maximum(weights, 1e-30))
    g = jax.random.gumbel(rng, weights.shape, dtype=logits.dtype)
    _, idx = jax.lax.top_k(logits + g, num_candidates)
    return idx


def dependency_filter(gram: jax.Array, rho: float,
                      max_select: int) -> jax.Array:
    """Greedy ρ-dependency filter (paper §3.3, f₂).

    ``gram`` is the U′×U′ candidate correlation block (|x_jᵀx_k|, columns
    standardized so the diagonal is 1).  Greedily admit candidates in
    order; candidate i joins iff its correlation with every admitted
    candidate is < ρ.  Returns a boolean keep-mask of shape (U′,) with at
    most ``max_select`` True entries.  O(U′²), matching the paper's cost
    argument (U′² ≪ J²).
    """
    u = gram.shape[0]
    absg = jnp.abs(gram)

    def body(i, carry):
        keep, count = carry
        # max correlation with already-kept candidates (exclude self)
        conflict = jnp.max(jnp.where(keep, absg[i], 0.0))
        ok = (conflict < rho) & (count < max_select)
        keep = keep.at[i].set(ok)
        return keep, count + ok.astype(jnp.int32)

    keep0 = jnp.zeros((u,), dtype=bool)
    # candidate 0 always admitted (count starts at 0, conflict max over
    # empty set = 0 < rho)
    keep, _ = jax.lax.fori_loop(0, u, body, (keep0, jnp.int32(0)))
    return keep


@dataclasses.dataclass(frozen=True)
class DynamicPriorityScheduler:
    """STRADS Lasso scheduler: priority sampling + dependency filtering.

    Usage: ``propose`` samples U′ candidates from c; the application
    computes the candidate Gram block (a distributed psum over data
    shards); ``finalize`` applies the ρ filter and returns
    ``(indices, mask)`` — a static-size schedule.
    """
    num_vars: int
    num_candidates: int      # U'
    block_size: int          # U  (≤ num_candidates)
    rho: float = 0.1
    eta: float = 1e-6

    # -- carry: the Δx history driving the priorities c_j -------------------
    # The carry is a plain (J,) array so it rides any pytree (app state,
    # scan carry) without wrappers.  Host code must never own it: the
    # scanned executor keeps it on-device across all R rounds.

    def init_carry(self) -> jax.Array:
        """Uniform priority at t=0 (every variable equally likely)."""
        return jnp.ones((self.num_vars,), jnp.float32)

    def update_carry(self, delta: jax.Array, idx: jax.Array,
                     mask: jax.Array, dx: jax.Array) -> jax.Array:
        """Fold round t's updates Δx into the history: scheduled-and-kept
        entries take |Δx|, everything else keeps its previous priority."""
        return delta.at[idx].set(
            jnp.where(mask, jnp.abs(dx), jnp.take(delta, idx)))

    def propose(self, delta: jax.Array, rng: jax.Array) -> jax.Array:
        c = priority_weights(delta, self.eta)
        return sample_candidates(rng, c, self.num_candidates)

    def finalize(self, candidates: jax.Array,
                 gram: jax.Array) -> tuple[jax.Array, jax.Array]:
        keep = dependency_filter(gram, self.rho, self.block_size)
        # Compact the kept candidates to the front; pad with the first
        # kept index (masked out downstream).
        order = jnp.argsort(~keep)          # kept first, stable
        idx = candidates[order][: self.block_size]
        mask = keep[order][: self.block_size]
        return idx, mask
