"""Deprecated shim: the schedulers moved to :mod:`repro.sched`.

``repro.core.schedulers`` re-exports the same names so old imports keep
working (with a :class:`DeprecationWarning`, matching the PR 3 shim
pattern); new code should import from :mod:`repro.sched` — the pluggable
scheduler subsystem that also carries the declarative
:class:`~repro.sched.spec.SchedulerSpec` / ``ExecutionPlan.scheduler``
surface.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.schedulers moved to repro.sched (the pluggable scheduler "
    "subsystem); import RoundRobinScheduler/RandomScheduler/"
    "RotationScheduler/DynamicPriorityScheduler and the filter helpers "
    "from repro.sched instead", DeprecationWarning, stacklevel=2)

from ..sched.schedulers import (  # noqa: E402
    BlockStructuralScheduler, DynamicPriorityScheduler, RandomScheduler,
    RotationScheduler, RoundRobinScheduler, dependency_filter,
    priority_weights, sample_candidates, structural_gram)

__all__ = [
    "BlockStructuralScheduler", "DynamicPriorityScheduler",
    "RandomScheduler", "RotationScheduler", "RoundRobinScheduler",
    "dependency_filter", "priority_weights", "sample_candidates",
    "structural_gram",
]
