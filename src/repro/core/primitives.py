"""STRADS primitives: ``schedule``, ``push``, ``pull`` (+ automatic ``sync``).

The paper (Lee et al., 2014) defines a model-parallel round as

    sched = schedule()                      # pick U variables
    z_p   = push(worker=p, vars=sched)      # partial update on worker p
    x     = pull(sched, [z_1 .. z_P])       # aggregate + commit
    sync()                                  # automatic BSP refresh

On TPU/JAX we realize this with SPMD: ``schedule`` is computed *replicated*
(every device runs the same deterministic program with the same PRNG key, so
there is no scheduler machine and no star-topology bottleneck — the paper's
own §5 future-work item), ``push`` runs under ``shard_map`` over the ``data``
mesh axis, ``pull`` aggregation is a ``jax.lax.psum`` over that axis, and
``sync`` is implicit in SPMD program order (BSP, exactly the consistency
model the paper uses).

Round anatomy (executed by :mod:`repro.core.engine`):

    cand  = propose(state, carry, rng, t)                    # replicated
    stats = psum_p( schedule_stats(D_p, state, cand) )       # sharded, opt.
    sched = schedule(state, carry, cand, stats, rng, t)      # replicated
    z, local_p = push(D_p, state, sched)                     # sharded
    state = pull(state, sched, psum_p(z), local_p, D_p)      # commit + sync
    carry = sched_update(carry, state_before, state, sched)  # replicated

``z`` is the paper's partial result (summed across workers exactly as the
paper's Σ_p z_j^p); ``local_p`` carries per-shard state updates that never
cross workers (e.g. LDA's topic-assignment vector or a maintained residual)
— in 2014-STRADS those simply lived in worker memory, here they are the
sharded leaves of the state pytree.

``phase`` is a *static* Python int (``app.static_phase(t)``) enabling
schedules whose communication pattern changes per round (LDA's rotation
``ppermute`` needs a static permutation); apps with a fixed pattern return 0.
Apps declare the cycle length as ``phase_period`` (``static_phase(t)`` must
equal ``t % phase_period``): the scanned executor unrolls one full phase
cycle per ``lax.scan`` step so every phase stays static inside the trace.

The v2 scheduler-injection contract
-----------------------------------

Scheduling *policy* is not part of the app: it is a declarative
:class:`~repro.sched.spec.SchedulerSpec` on the
:class:`~repro.core.plan.ExecutionPlan` (or the app's
``default_scheduler_spec()`` when the plan leaves it ``None``).  The
engine resolves the spec into a :class:`~repro.sched.protocol.Scheduler`
(``repro.sched.build_scheduler``, using the app's ``num_schedulable()``
count and the mesh width) and injects it via ``use_scheduler()`` before
tracing; apps *consume* ``self.scheduler`` inside ``propose`` /
``schedule`` instead of hardcoding a policy.

The scheduler's on-device state (e.g. the dynamic-priority Δx history)
is the **engine-owned scheduler carry**:

* ``scheduler.init_carry()`` creates it; the engine threads it through
  every executor (host loop, ``lax.scan``, pipelined prefetch, SSP
  windows) and returns it as ``EngineCarry.sched_carry`` /
  ``SSPCarry.sched_carry`` — so it checkpoints and resumes bit-exactly
  through ``checkpoint/npz`` like the PRNG stream and round counter;
* ``propose(state, carry, ...)`` / ``schedule(state, carry, ...)`` read
  it (apps usually just forward it to ``self.scheduler``);
* ``sched_update(carry, state_before, state_after, sched, phase)`` folds
  the committed round back into it — the app computes the policy's
  feedback signal (e.g. Δβ over the scheduled block) and delegates to
  ``scheduler.update_carry``; the default keeps the carry unchanged;
* under SSP, ``scheduler.mark_scheduled(carry, candidates)`` applies the
  in-flight exclusion between the window's stale proposals (replacing
  the state-leaf ``var_roles()``/``role="priority"`` mechanism for
  injected schedulers; the VarTable path remains for apps that keep a
  priority table in their state).

The partition-injection contract
--------------------------------

Partitioning — the paper's *other* headline primitive — is declarative
too: a :class:`~repro.part.spec.PartitionerSpec` on the
:class:`~repro.core.plan.ExecutionPlan` (or the app's
``default_partitioner_spec()`` when the plan leaves it ``None``).  The
engine resolves it into a :class:`~repro.part.protocol.Partitioner`
(``repro.part.build_partitioner``, using the app's ``num_schedulable()``
count, the mesh width, and the optional per-variable byte vector
``partition_sizes()``) and injects the resulting variable→worker
:class:`~repro.part.assignment.Assignment` via ``use_partition()``
before tracing; apps read ``self.assignment`` if their primitives
consume ownership (the built-in apps' math is ownership-agnostic — the
assignment governs the model store's placement bookkeeping and the
Fig-3 byte accounting).

The repartition loop is **engine-owned and host-side**, mirroring the
scheduler-carry pattern one level up:

* the engine checks for rebalances at the ``plan.checkpoint_every``
  chunk boundaries of ``StradsEngine.execute`` — the one place state is
  already synced to the host, so a move is a ``KVStore.repartition``
  re-placement, never XLA-program surgery;
* the activity signal feeding the load balancer is the |Δ| of the app's
  ``partition_signal(state)`` (a ``(J,)`` per-variable statistic, e.g.
  Lasso's β) across each chunk — the partition-level twin of the
  priority signal ``sched_update`` feeds the dynamic scheduler;
* compiled-program caches are keyed per assignment (a rebalance is one
  cache miss, a swap back is a hit), and the SSP server/cache split in
  :mod:`repro.ps` re-derives from the repartitioned KVStore specs;
* the assignment (+ the partitioner's activity stats) rides the
  ``{"state", "carry", "assignment"}`` checkpoint payload, so a resumed
  run replays the same rebalance decisions bit-exactly
  (``execute(..., partition=...)``);
* apps declare which kinds they can host via
  ``supported_partitioner_kinds`` (e.g. LDA's rotation owns a frozen
  contiguous block map, so only ``"static"`` applies) — the engine
  rejects a plan naming an unlisted kind at injection time, never at
  trace time, exactly like ``supported_scheduler_kinds``.

The kernel-injection contract
-----------------------------

The round body's compute hot-spots (the push partials, the dynamic
scheduler's Gram block) are served by an injected **kernel backend**,
declared as a :class:`~repro.kernels.spec.KernelSpec` on the
:class:`~repro.core.plan.ExecutionPlan` (or the app's
``default_kernel_spec()`` when the plan leaves it ``None``; the engine
falls back to ``kind="reference"`` — the pure-jnp oracles, bit-identical
to the pre-KernelSpec round body).  The engine resolves the spec into a
backend object (``repro.kernels.build_kernels`` — Pallas kernels
compiled for Mosaic on TPU, automatically interpret-mode elsewhere) and
injects it via ``use_kernels()`` before tracing; apps call
``self.kernels.lasso_partial(...)`` / ``self.kernels.gram_block(...)``
inside ``push``/``schedule_stats`` and never branch on the backend
themselves.

Unlike the scheduler and partitioner, a kernel backend is **stateless**
— no carry, no checkpoint payload; the injection only changes what the
traced round lowers to.  The discipline it shares with the other two:

* apps declare which kinds they can dispatch via
  ``supported_kernel_kinds`` (e.g. LDA/MF have no Pallas hot-spot
  kernels yet, so only ``"reference"`` applies) — the engine rejects a
  plan naming an unlisted kind at injection time, never at trace time;
* compiled-program caches are keyed per (SchedulerSpec, Assignment,
  KernelSpec), so a backend sweep — ``BENCH_kernels``'s reference vs
  pallas arms — reuses each configuration's programs instead of
  retracing on every swap.

The telemetry-injection contract
--------------------------------

Observability follows the same declarative shape: a
:class:`~repro.obs.spec.TelemetrySpec` on the
:class:`~repro.core.plan.ExecutionPlan` (``plan.telemetry``; the legacy
boolean form still parses — ``True`` means ``kind="counters"`` with a
``DeprecationWarning``).  Unlike the scheduler/partitioner/kernel
contracts, apps implement **nothing**: instrumentation is engine-owned
and rides *outside* the primitives, so it can never change what a round
computes.

* **Device counters** (any spec) are an extra pytree leaf threaded
  through every executor's carry (``EngineCarry.obs`` /
  ``SSPCarry.obs``; ``None`` when telemetry is off, so old checkpoints
  restore unchanged).  Counters are derived *only* from the already-
  computed schedule pytree — per-phase round counts, scheduled-block
  widths, and the ρ-filter ledger (``proposed = accepted + killed``
  from the keep-mask popcounts) — never from model state or the PRNG
  stream, which is what makes the instrumented run **bit-identical**
  to the uninstrumented one.
* **Host events** (``kind="trace"``) come from an engine-owned
  ``Recorder``: executor/chunk/checkpoint spans, compiled-program
  cache misses keyed by the (SchedulerSpec, Assignment, KernelSpec)
  triple, and rebalance decisions — all recorded at host phase
  boundaries, never inside a traced program.
* Every ``execute()`` returns the resolved telemetry as a uniform
  :class:`~repro.obs.report.RunReport` in
  ``ExecutionReport.telemetry`` (the SSP staleness summary becomes its
  ``.ssp`` section); ``repro.launch.trace`` validates and re-exports
  saved reports offline.

The serving-injection contract
------------------------------

Serving (:mod:`repro.serve`) is the read-only fifth leg of the same
declarative surface: a :class:`~repro.serve.spec.ServeSpec` declares the
consistency a read gets (``"stale"`` — the SSP mixed view, server-
resident leaves through a :class:`~repro.ps.cache.StaleCache` under the
gate ``clock − cache.clock ≤ max_staleness``; ``"snapshot"`` — the full
state pinned at flush/chunk boundaries) and the micro-batching policy
(``max_batch``, ``batch_window_ms``).  Apps opt in with **one**
primitive, declared alongside ``state_specs()``/``var_roles()``:

* ``query(state, batch) -> result`` — one *batched* inference request
  against a (possibly stale) state view: ``batch`` is a pytree whose
  leaves carry a leading request dimension (the frontend stacks queued
  per-example payloads), and the result's leaves carry the same leading
  dimension (the frontend slices per-request responses back out).
  Lasso serves ``predict`` (ŷ = Xβ), LDA serves ``infer_topics`` (a
  fixed-iteration fold-in over the topic tables), MF serves
  ``recommend`` (top-k item scores for a user row).
* ``query`` must be **pure and deterministic** — jit-traceable, no PRNG
  stream of its own, and it never writes: the serving subsystem reads
  through copies/boundary references only, which is what makes
  ``serve_while_training`` bit-identical to an unserved ``execute()``.
* unlike the other four contracts nothing is injected *into* the app:
  the engine side of the contract is the publish boundary —
  ``serve_while_training`` publishes committed state to the
  :class:`~repro.serve.view.ModelView` at the same host-synced chunk
  boundaries the partitioner and checkpointer already use, and the
  frontend's jitted query programs are cached per (Assignment,
  KernelSpec) exactly like the engine's round programs.

The ingest-injection contract
-----------------------------

Streaming ingest (:mod:`repro.stream`) is the *write* half of the
serving story — the sixth leg of the same declarative surface.  A
:class:`~repro.stream.spec.StreamSpec` declares how new data flows in
(``"replace"`` — each delta names the row slots it overwrites;
``"extend"`` — rows append into a capacity-padded ring buffer behind the
app's validity mask, so data shapes stay static and compiled round
programs are reused, never recompiled) and the cadence
(``ingest_every``, aligned to the executor's step length exactly like
``checkpoint_every``).  Like ``ServeSpec`` it rides the entry points
(``execute(..., stream=, source=)``), never the ExecutionPlan.  Apps opt
in with two primitives:

* ``ingest_specs() -> {"leaves": (...), "valid": fn | None}`` — which
  data leaves stream (all share the row axis; their leading dimension is
  the ring capacity) and, for ``"extend"``, a host-side
  ``valid(data) -> (rows,) bool`` mask deriving which slots hold real
  rows (MF reads it off ``mask``, LDA off ``words >= 0``; lasso has no
  validity channel and therefore declares ``supported_stream_kinds =
  ("replace",)`` — the same injection-time rejection rule as
  ``supported_scheduler_kinds``).
* ``ingest(data, state, rows, delta) -> (data, state)`` — overwrite the
  ``rows`` slots of the streamable leaves with ``delta["data"]`` and
  bring *derived* state up to date in the same step (lasso rewrites the
  replaced residuals ``r = y − Xβ``; MF the replaced rows of ``R``; LDA
  decrements the old token's collapsed counts and increments the new
  one's from the per-row ``delta["z"]`` draw).  Leaves the delta does
  not touch must come back as the **same objects** — the
  :class:`~repro.stream.ingest.Ingestor` re-places only changed leaves
  with per-leaf ``device_put``, never a full ``shard_data`` rebuild.
  With ``state=None`` only the data-leaf writes apply (the
  deterministic-source replay path after a cross-process resume).

The engine side is the boundary loop: deltas land at host-synced chunk
boundaries (where the partitioner already rebalances, checkpoints
already save, the serve loop already publishes), the stream cursor rides
the checkpoint payload as its ``"stream"`` subtree, and ingest
spans/row counts ride the :mod:`repro.obs` Recorder.  A round never
observes a half-applied delta, and an empty source is bit-identical to
an unstreamed run.

The v2 write contract (VarTable-mediated push/pull)
---------------------------------------------------

Apps implement exactly the primitives above, **once**, and get every
executor — including bounded staleness — without SSP-specific hooks.  The
executors derive deferred-commit behavior from the app's *placement
declarations* (``state_specs()`` → :class:`~repro.core.kvstore.VarSpec`,
mediated by :class:`~repro.core.kvstore.VarTable`):

* a ``local`` leaf whose '/'-joined key path names a **worker-resident**
  state leaf (non-replicated VarSpec) *is* the committed new value of
  that leaf.  ``pull`` must treat such leaves as write-through: it writes
  them back verbatim (``{"z": local["z"], ...}``) and never assumes the
  pre-push state value survives.  Under BSP this is invisible; under SSP
  the executor commits those leaves **every round** (a worker always
  reads its own writes fresh — the SSP read-my-writes guarantee) and
  buffers only the *remaining* ``local`` leaves until the flush, where
  ``pull`` is replayed per deferred round with ``local`` reconstructed
  (commit-through entries read back from the live state, the rest from
  the buffer) and ``z`` freshly aggregated in ONE batched collective.
* server-resident writes (replicated VarSpecs) always flow through
  ``pull``; under SSP they commit at the flush, up to ``s`` rounds late.
* apps that keep a scheduling-priority table in their *state* declare it
  via ``var_roles() -> {leaf_path: "priority"}`` and get the VarTable
  in-flight exclusion; apps using an injected scheduler need neither —
  the carry-based ``mark_scheduled`` above covers it.

The v1 protocol's four ``ssp_commit_local`` / ``ssp_defer_local`` /
``ssp_commit_shared`` / ``ssp_mark_scheduled`` hook overrides are
deprecated: :mod:`repro.ps.ssp` still honors them (with a
``DeprecationWarning``) when an app defines any, but the built-in apps
rely purely on the derived behavior.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Protocol, runtime_checkable

import jax

# Type aliases -----------------------------------------------------------
ModelState = Any     # pytree of model variables x (the paper's KV store)
DataShard = Any      # pytree: this worker's partition of the data D
Schedule = Any       # pytree describing the scheduled variable block
Partial = Any        # pytree of partial results z_j^p
Stats = Any          # pytree of distributed statistics used by schedule()
SchedCarry = Any     # scheduler scan carry (engine-owned; None if stateless)


@runtime_checkable
class StradsApp(Protocol):
    """User-defined STRADS application (the paper's Figure 2)."""

    def init_state(self, rng: jax.Array) -> ModelState: ...

    def static_phase(self, t: int) -> int: ...

    def propose(self, state: ModelState, carry: SchedCarry,
                rng: jax.Array, t: jax.Array, phase: int) -> Schedule: ...

    def schedule_stats(self, data: DataShard, state: ModelState,
                       candidates: Schedule, phase: int) -> Stats: ...

    def schedule(self, state: ModelState, carry: SchedCarry,
                 candidates: Schedule, stats: Stats, rng: jax.Array,
                 t: jax.Array, phase: int) -> Schedule: ...

    def push(self, data: DataShard, state: ModelState, sched: Schedule,
             phase: int) -> tuple[Partial, Any]: ...

    def pull(self, state: ModelState, sched: Schedule, z: Partial,
             local: Any, data: DataShard, phase: int) -> ModelState: ...

    def sched_update(self, carry: SchedCarry, before: ModelState,
                     after: ModelState, sched: Schedule,
                     phase: int) -> SchedCarry: ...


class StradsAppBase:
    """Convenience base with the common defaults.

    Subclasses override what they need; ``schedule_stats`` is only invoked
    by the engine when overridden (data-independent schedules skip the
    extra shard_map pass entirely).  Apps with phase-dependent rounds set
    ``phase_period`` to the cycle length and keep ``static_phase(t) ==
    t % phase_period``.

    Scheduling policy arrives by **injection** (the v2 scheduler-injection
    contract — see the module docstring): the engine resolves the plan's
    ``SchedulerSpec`` (or ``default_scheduler_spec()``) and calls
    ``use_scheduler``; ``propose``/``schedule``/``sched_update`` consume
    ``self.scheduler`` and the engine-owned carry.

    SSP behavior is **derived, not overridden** (the v2 write contract):
    commit-through and deferral follow from the placement declared in
    ``state_specs()``; in-flight exclusion follows from the injected
    scheduler's ``mark_scheduled`` (or, for state-resident priority
    tables, from ``var_roles()``).
    """

    phase_period: int = 1

    #: the injected Scheduler (set by the engine; None = app self-schedules)
    scheduler = None

    #: which SchedulerSpec kinds this app can consume (None = any; the
    #: engine rejects a plan naming an unlisted kind at injection time,
    #: never at trace time)
    supported_scheduler_kinds = None

    #: the injected variable→worker Assignment (set by the engine; None =
    #: no partitioner resolved — the pre-subsystem behavior)
    assignment = None

    #: which PartitionerSpec kinds this app can host (None = any; same
    #: injection-time rejection rule as supported_scheduler_kinds)
    supported_partitioner_kinds = None

    #: the injected kernel backend (set by the engine; None until an
    #: engine resolves a spec — apps with kernel hot-spots should fall
    #: back to the reference oracles for engine-less direct calls)
    kernels = None

    #: which KernelSpec kinds this app can dispatch (None = any; same
    #: injection-time rejection rule as supported_scheduler_kinds)
    supported_kernel_kinds = None

    def static_phase(self, t: int) -> int:
        return 0

    # -- scheduler injection -------------------------------------------------

    def default_scheduler_spec(self) -> Optional[Any]:
        """The policy this app runs when the plan names none (a
        :class:`~repro.sched.spec.SchedulerSpec` or ``None`` for apps
        that schedule themselves)."""
        return None

    def num_schedulable(self) -> int:
        """How many schedulable variables the injected policy ranges over
        (Lasso: J coefficients, MF: K ranks, LDA: the padded vocab).
        Required whenever a scheduler spec is resolved for this app."""
        raise NotImplementedError(
            f"{type(self).__name__} must define num_schedulable() to "
            f"accept an injected SchedulerSpec")

    def use_scheduler(self, scheduler) -> None:
        """Receive the engine-resolved :class:`~repro.sched.Scheduler`."""
        self.scheduler = scheduler

    # -- partition injection -------------------------------------------------

    def default_partitioner_spec(self) -> Optional[Any]:
        """The partition policy this app runs when the plan names none
        (a :class:`~repro.part.spec.PartitionerSpec` or ``None`` for
        apps that manage placement entirely through ``state_specs()``
        with no variable-ownership story)."""
        return None

    def use_partition(self, assignment) -> None:
        """Receive the engine-resolved variable→worker
        :class:`~repro.part.assignment.Assignment` (``None`` clears
        it)."""
        self.assignment = assignment

    # -- kernel injection ----------------------------------------------------

    def default_kernel_spec(self) -> Optional[Any]:
        """The kernel backend this app runs when the plan names none
        (a :class:`~repro.kernels.spec.KernelSpec` or ``None`` to take
        the engine fallback, ``kind="reference"``)."""
        return None

    def use_kernels(self, kernels) -> None:
        """Receive the engine-resolved kernel backend
        (``repro.kernels.build_kernels`` output; never ``None`` — the
        engine always resolves at least the reference backend)."""
        self.kernels = kernels

    def partition_signal(self, state):
        """A ``(num_schedulable(),)`` per-variable statistic whose |Δ|
        across a chunk is the load balancer's activity measure (e.g.
        Lasso's β — |Δβ| is exactly the dynamic scheduler's priority
        signal).  ``None`` (the default) means the app emits no
        activity signal and cannot host a ``load_balanced``
        partitioner."""
        return None

    def partition_sizes(self):
        """Per-variable byte sizes for the ``size_balanced`` kind
        (``None`` = uniform)."""
        return None

    def query(self, state, batch):
        """One batched inference request against a (possibly stale)
        state view — the serving-injection contract (see the module
        docstring).  ``batch`` leaves carry a leading request dimension;
        so must the result's.  Default: the app declares no query
        primitive and cannot be served."""
        raise NotImplementedError(
            f"{type(self).__name__} declares no query() primitive — "
            f"serving (repro.serve) needs one; see the serving-injection "
            f"contract in repro.core.primitives")

    #: which StreamSpec kinds this app can ingest (None = any; same
    #: injection-time rejection rule as supported_scheduler_kinds.
    #: Apps without a validity channel cannot host "extend")
    supported_stream_kinds = None

    def ingest_specs(self) -> dict:
        """``{"leaves": (...), "valid": fn | None}`` — which data leaves
        stream and how to derive the extend-kind validity mask; the
        ingest-injection contract (see the module docstring).  Default:
        the app declares no ingest primitives and cannot stream."""
        raise NotImplementedError(
            f"{type(self).__name__} declares no ingest_specs() primitive "
            f"— streaming (repro.stream) needs one; see the "
            f"ingest-injection contract in repro.core.primitives")

    def ingest(self, data, state, rows, delta):
        """Overwrite the ``rows`` slots of the streamable leaves with
        ``delta["data"]`` and bring derived state up to date — the
        ingest-injection contract (see the module docstring).  Unchanged
        leaves must come back as the same objects; ``state=None``
        applies the data-leaf writes only.  Default: the app declares
        no ingest primitive and cannot stream."""
        raise NotImplementedError(
            f"{type(self).__name__} declares no ingest() primitive — "
            f"streaming (repro.stream) needs one; see the "
            f"ingest-injection contract in repro.core.primitives")

    def var_roles(self) -> dict:
        """Leaf-path → :class:`~repro.core.kvstore.VarSpec` role
        declarations beyond placement (currently only ``"priority"``:
        scheduling-priority tables kept in app *state*, which the SSP
        window scheduler masks for in-flight exclusion via VarTable).
        Apps with injected schedulers keep priorities in the engine carry
        instead and need no roles.  Default: none."""
        return {}

    # -- the primitives ------------------------------------------------------

    def propose(self, state, carry, rng, t, phase):
        return None

    def schedule_stats(self, data, state, candidates, phase):
        return None

    def schedule(self, state, carry, candidates, stats, rng, t, phase):
        return candidates

    def push(self, data, state, sched, phase):
        raise NotImplementedError

    def pull(self, state, sched, z, local, data, phase):
        raise NotImplementedError

    def sched_update(self, carry, before, after, sched, phase):
        """Fold the committed round into the scheduler carry.  Default:
        unchanged (stateless policies)."""
        return carry


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoundResult:
    """Output of one BSP round (a pytree, so it can cross jit)."""
    state: ModelState
    sched: Schedule
    aux: Any = None
    sched_carry: SchedCarry = None   # post-round engine-owned carry


def tree_psum(tree: Any, axis_name: str) -> Any:
    """psum every leaf of a pytree (the pull aggregation)."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)
