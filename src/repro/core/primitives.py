"""STRADS primitives: ``schedule``, ``push``, ``pull`` (+ automatic ``sync``).

The paper (Lee et al., 2014) defines a model-parallel round as

    sched = schedule()                      # pick U variables
    z_p   = push(worker=p, vars=sched)      # partial update on worker p
    x     = pull(sched, [z_1 .. z_P])       # aggregate + commit
    sync()                                  # automatic BSP refresh

On TPU/JAX we realize this with SPMD: ``schedule`` is computed *replicated*
(every device runs the same deterministic program with the same PRNG key, so
there is no scheduler machine and no star-topology bottleneck — the paper's
own §5 future-work item), ``push`` runs under ``shard_map`` over the ``data``
mesh axis, ``pull`` aggregation is a ``jax.lax.psum`` over that axis, and
``sync`` is implicit in SPMD program order (BSP, exactly the consistency
model the paper uses).

Round anatomy (executed by :mod:`repro.core.engine`):

    cand  = propose(state, rng, t)                      # replicated
    stats = psum_p( schedule_stats(D_p, state, cand) )  # sharded, optional
    sched = schedule(state, cand, stats, rng, t)        # replicated
    z, local_p = push(D_p, state, sched)                # sharded
    state = pull(state, sched, psum_p(z), local_p, D_p) # commit + sync

``z`` is the paper's partial result (summed across workers exactly as the
paper's Σ_p z_j^p); ``local_p`` carries per-shard state updates that never
cross workers (e.g. LDA's topic-assignment vector or a maintained residual)
— in 2014-STRADS those simply lived in worker memory, here they are the
sharded leaves of the state pytree.

``phase`` is a *static* Python int (``app.static_phase(t)``) enabling
schedules whose communication pattern changes per round (LDA's rotation
``ppermute`` needs a static permutation); apps with a fixed pattern return 0.
Apps declare the cycle length as ``phase_period`` (``static_phase(t)`` must
equal ``t % phase_period``): the scanned executor unrolls one full phase
cycle per ``lax.scan`` step so every phase stays static inside the trace.

The v2 write contract (VarTable-mediated push/pull)
---------------------------------------------------

Apps implement exactly the primitives above, **once**, and get every
executor — including bounded staleness — without SSP-specific hooks.  The
executors derive deferred-commit behavior from the app's *placement
declarations* (``state_specs()`` → :class:`~repro.core.kvstore.VarSpec`,
mediated by :class:`~repro.core.kvstore.VarTable`):

* a ``local`` leaf whose '/'-joined key path names a **worker-resident**
  state leaf (non-replicated VarSpec) *is* the committed new value of
  that leaf.  ``pull`` must treat such leaves as write-through: it writes
  them back verbatim (``{"z": local["z"], ...}``) and never assumes the
  pre-push state value survives.  Under BSP this is invisible; under SSP
  the executor commits those leaves **every round** (a worker always
  reads its own writes fresh — the SSP read-my-writes guarantee) and
  buffers only the *remaining* ``local`` leaves until the flush, where
  ``pull`` is replayed per deferred round with ``local`` reconstructed
  (commit-through entries read back from the live state, the rest from
  the buffer) and ``z`` freshly aggregated in ONE batched collective.
* server-resident writes (replicated VarSpecs) always flow through
  ``pull``; under SSP they commit at the flush, up to ``s`` rounds late.
* apps with a dynamic scheduler declare the priority table via
  ``var_roles() -> {leaf_path: "priority"}``; the SSP window scheduler
  then excludes in-flight candidates by zeroing those entries in later
  proposals' scheduling views (the STRADS in-flight exclusion rule —
  no per-app override needed).

The v1 protocol's four ``ssp_commit_local`` / ``ssp_defer_local`` /
``ssp_commit_shared`` / ``ssp_mark_scheduled`` hook overrides are
deprecated: :mod:`repro.ps.ssp` still honors them (with a
``DeprecationWarning``) when an app defines any, but the built-in apps
rely purely on the derived behavior.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax

# Type aliases -----------------------------------------------------------
ModelState = Any     # pytree of model variables x (the paper's KV store)
DataShard = Any      # pytree: this worker's partition of the data D
Schedule = Any       # pytree describing the scheduled variable block
Partial = Any        # pytree of partial results z_j^p
Stats = Any          # pytree of distributed statistics used by schedule()


@runtime_checkable
class StradsApp(Protocol):
    """User-defined STRADS application (the paper's Figure 2)."""

    def init_state(self, rng: jax.Array) -> ModelState: ...

    def static_phase(self, t: int) -> int: ...

    def propose(self, state: ModelState, rng: jax.Array,
                t: jax.Array, phase: int) -> Schedule: ...

    def schedule_stats(self, data: DataShard, state: ModelState,
                       candidates: Schedule, phase: int) -> Stats: ...

    def schedule(self, state: ModelState, candidates: Schedule,
                 stats: Stats, rng: jax.Array, t: jax.Array,
                 phase: int) -> Schedule: ...

    def push(self, data: DataShard, state: ModelState, sched: Schedule,
             phase: int) -> tuple[Partial, Any]: ...

    def pull(self, state: ModelState, sched: Schedule, z: Partial,
             local: Any, data: DataShard, phase: int) -> ModelState: ...


class StradsAppBase:
    """Convenience base with the common defaults.

    Subclasses override what they need; ``schedule_stats`` is only invoked
    by the engine when overridden (data-independent schedules skip the
    extra shard_map pass entirely).  Apps with phase-dependent rounds set
    ``phase_period`` to the cycle length and keep ``static_phase(t) ==
    t % phase_period``.

    SSP behavior is **derived, not overridden** (the v2 write contract —
    see the module docstring): commit-through and deferral follow from the
    placement declared in ``state_specs()``; the only extra declaration an
    app can make is ``var_roles()``, marking scheduling-priority leaves
    for the SSP in-flight exclusion.
    """

    phase_period: int = 1

    def static_phase(self, t: int) -> int:
        return 0

    def var_roles(self) -> dict:
        """Leaf-path → :class:`~repro.core.kvstore.VarSpec` role
        declarations beyond placement (currently only ``"priority"``:
        scheduling-priority tables the SSP window scheduler masks for
        in-flight exclusion).  Default: none."""
        return {}

    def propose(self, state, rng, t, phase):
        return None

    def schedule_stats(self, data, state, candidates, phase):
        return None

    def schedule(self, state, candidates, stats, rng, t, phase):
        return candidates

    def push(self, data, state, sched, phase):
        raise NotImplementedError

    def pull(self, state, sched, z, local, data, phase):
        raise NotImplementedError


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoundResult:
    """Output of one BSP round (a pytree, so it can cross jit)."""
    state: ModelState
    sched: Schedule
    aux: Any = None


def tree_psum(tree: Any, axis_name: str) -> Any:
    """psum every leaf of a pytree (the pull aggregation)."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)
