"""STRADS primitives: ``schedule``, ``push``, ``pull`` (+ automatic ``sync``).

The paper (Lee et al., 2014) defines a model-parallel round as

    sched = schedule()                      # pick U variables
    z_p   = push(worker=p, vars=sched)      # partial update on worker p
    x     = pull(sched, [z_1 .. z_P])       # aggregate + commit
    sync()                                  # automatic BSP refresh

On TPU/JAX we realize this with SPMD: ``schedule`` is computed *replicated*
(every device runs the same deterministic program with the same PRNG key, so
there is no scheduler machine and no star-topology bottleneck — the paper's
own §5 future-work item), ``push`` runs under ``shard_map`` over the ``data``
mesh axis, ``pull`` aggregation is a ``jax.lax.psum`` over that axis, and
``sync`` is implicit in SPMD program order (BSP, exactly the consistency
model the paper uses).

Round anatomy (executed by :mod:`repro.core.engine`):

    cand  = propose(state, rng, t)                      # replicated
    stats = psum_p( schedule_stats(D_p, state, cand) )  # sharded, optional
    sched = schedule(state, cand, stats, rng, t)        # replicated
    z, local_p = push(D_p, state, sched)                # sharded
    state = pull(state, sched, psum_p(z), local_p, D_p) # commit + sync

``z`` is the paper's partial result (summed across workers exactly as the
paper's Σ_p z_j^p); ``local_p`` carries per-shard state updates that never
cross workers (e.g. LDA's topic-assignment vector or a maintained residual)
— in 2014-STRADS those simply lived in worker memory, here they are the
sharded leaves of the state pytree.

``phase`` is a *static* Python int (``app.static_phase(t)``) enabling
schedules whose communication pattern changes per round (LDA's rotation
``ppermute`` needs a static permutation); apps with a fixed pattern return 0.
Apps declare the cycle length as ``phase_period`` (``static_phase(t)`` must
equal ``t % phase_period``): the scanned executor unrolls one full phase
cycle per ``lax.scan`` step so every phase stays static inside the trace.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax

# Type aliases -----------------------------------------------------------
ModelState = Any     # pytree of model variables x (the paper's KV store)
DataShard = Any      # pytree: this worker's partition of the data D
Schedule = Any       # pytree describing the scheduled variable block
Partial = Any        # pytree of partial results z_j^p
Stats = Any          # pytree of distributed statistics used by schedule()


@runtime_checkable
class StradsApp(Protocol):
    """User-defined STRADS application (the paper's Figure 2)."""

    def init_state(self, rng: jax.Array) -> ModelState: ...

    def static_phase(self, t: int) -> int: ...

    def propose(self, state: ModelState, rng: jax.Array,
                t: jax.Array, phase: int) -> Schedule: ...

    def schedule_stats(self, data: DataShard, state: ModelState,
                       candidates: Schedule, phase: int) -> Stats: ...

    def schedule(self, state: ModelState, candidates: Schedule,
                 stats: Stats, rng: jax.Array, t: jax.Array,
                 phase: int) -> Schedule: ...

    def push(self, data: DataShard, state: ModelState, sched: Schedule,
             phase: int) -> tuple[Partial, Any]: ...

    def pull(self, state: ModelState, sched: Schedule, z: Partial,
             local: Any, data: DataShard, phase: int) -> ModelState: ...


class StradsAppBase:
    """Convenience base with the common defaults.

    Subclasses override what they need; ``schedule_stats`` is only invoked
    by the engine when overridden (data-independent schedules skip the
    extra shard_map pass entirely).  Apps with phase-dependent rounds set
    ``phase_period`` to the cycle length and keep ``static_phase(t) ==
    t % phase_period``.
    """

    phase_period: int = 1

    def static_phase(self, t: int) -> int:
        return 0

    def propose(self, state, rng, t, phase):
        return None

    def schedule_stats(self, data, state, candidates, phase):
        return None

    def schedule(self, state, candidates, stats, rng, t, phase):
        return candidates

    def push(self, data, state, sched, phase):
        raise NotImplementedError

    def pull(self, state, sched, z, local, data, phase):
        raise NotImplementedError

    # -- SSP (bounded-staleness) hooks — used by repro.ps.ssp ---------------
    # Under SSP the cross-worker aggregation of ``z`` is deferred: pushes
    # buffer their partials and a *flush* commits up to s+1 rounds at once.
    # The default hooks make any app SSP-runnable with fully deferred
    # commits (at staleness 0 they reduce exactly to ``pull``); apps whose
    # push mutates worker-local state (e.g. LDA's Gibbs tables) override
    # ``ssp_commit_local`` so their own writes stay immediately visible —
    # the SSP guarantee that a worker never reads its own updates stale.

    def ssp_commit_local(self, state, sched, local, data, phase):
        """Commit the worker-local part of a round immediately (called
        every round, before any cross-worker aggregation exists).  Must
        only modify worker-local (sharded) leaves.  Default: nothing —
        the whole commit waits for the flush."""
        return state

    def ssp_mark_scheduled(self, view, candidates, phase):
        """In-flight exclusion (the STRADS scheduler rule, extended to the
        SSP window): after round k's proposal is drawn, transform the
        *scheduling view* so later proposals in the same window avoid the
        variables already in flight — their pending updates are invisible
        until the flush, so rescheduling them would compound the same
        stale read up to s times.  Only the window's later schedule
        computations see the returned view; pushes and commits do not.
        Default: no exclusion (apps with disjoint-by-construction
        schedules, e.g. rotation or phase cycling, need none)."""
        return view

    def ssp_defer_local(self, local, phase):
        """The subset of ``local`` the flush-time commit still needs; it
        is buffered per round until the flush.  Override to shrink the
        pending-update buffer when ``ssp_commit_local`` already consumed
        most of ``local``.  Default: keep everything."""
        return local

    def ssp_commit_shared(self, state, sched, z, local, data, phase):
        """Deferred commit at the flush, with the aggregated ``z`` and
        whatever ``ssp_defer_local`` kept.  Default: the full ``pull``
        (correct whenever ``ssp_commit_local`` is the no-op default)."""
        return self.pull(state, sched, z, local, data, phase)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoundResult:
    """Output of one BSP round (a pytree, so it can cross jit)."""
    state: ModelState
    sched: Schedule
    aux: Any = None


def tree_psum(tree: Any, axis_name: str) -> Any:
    """psum every leaf of a pytree (the pull aggregation)."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)
