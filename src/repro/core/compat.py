"""Version-portable wrappers over the handful of jax APIs that moved.

The repo targets the modern API surface (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh`` with ``axis_types``) but must also run on
the jax 0.4.x line, where ``shard_map`` still lives in ``jax.experimental``
and takes ``check_rep``.  Everything else in the codebase imports the two
helpers below instead of touching the moving targets directly.
"""
from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):                          # jax >= 0.5
    _shard_map_impl = jax.shard_map
else:                                                  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SM_PARAMS = inspect.signature(_shard_map_impl).parameters
if "check_vma" in _SM_PARAMS:
    _SM_CHECK_KW = {"check_vma": False}
elif "check_rep" in _SM_PARAMS:
    _SM_CHECK_KW = {"check_rep": False}
else:                                                  # pragma: no cover
    _SM_CHECK_KW = {}


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version.

    Replication checking is disabled uniformly because several round
    bodies mix ``psum``-ed (replicated) and worker-local outputs in one
    pytree, which the static checker cannot always prove consistent.
    """
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **_SM_CHECK_KW)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` without the version-dependent ``axis_types``
    argument (newer jax defaults every axis to Auto anyway)."""
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
