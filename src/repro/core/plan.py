"""The declarative execution surface: :class:`ExecutionPlan`.

The paper's pitch is a *small, fixed* primitive set (``schedule`` /
``push`` / ``pull`` / ``sync``) that applications program against once
while the runtime freely swaps partitioning and update scheduling.  After
the executor zoo grew (host loop, scanned, pipelined, SSP) the call
surface no longer matched that pitch: every entry point had its own
kwargs, and validation ("staleness needs ssp", "pipeline_depth needs
num_rounds divisible by the phase period") was scattered across call
sites.

An :class:`ExecutionPlan` is the single declarative answer:

* **frozen + hashable** — a plan is a value, usable as a jit/cache key;
* **validated at construction** — every invalid executor/kwarg
  combination raises here, at plan-build time, never at trace time, and
  the error text lives in exactly one place;
* **JSON-round-trippable** — ``to_json``/``from_json`` are exact
  (defaults included), so plans live in checked-in files
  (``examples/plans/``), benchmark records (``BENCH_*.json``) and CLI
  flags (``launch/train.py --plan``, ``launch/dryrun.py --plan``).

One engine entry point consumes it — ``StradsEngine.execute(state, data,
rng, plan)`` — and returns a uniform :class:`ExecutionReport` (final
state, per-round trace, SSP telemetry, resumable carry) regardless of
which executor ran.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Any, Optional, Union

from ..kernels.spec import KernelSpec
from ..obs.spec import TelemetrySpec
from ..part.spec import PartitionerSpec
from ..sched.spec import SchedulerSpec

EXECUTORS = ("loop", "scan", "pipelined", "ssp")

# The one place the executor-name error is worded (apps/_exec.py used to
# carry a drifted copy that claimed 'loop' was acceptable but raised on
# it — see ISSUE 3).
_EXECUTOR_MSG = ("executor must be 'loop', 'scan', 'pipelined' or 'ssp'; "
                 "got {!r}")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Everything the engine needs to know about *how* to run R rounds.

    Fields
    ------
    executor:        ``"loop"`` (host loop, per-round dispatch),
                     ``"scan"`` (one ``lax.scan`` XLA program, BSP),
                     ``"pipelined"`` (scan + one-round-stale schedule
                     prefetch), ``"ssp"`` (bounded staleness, ``repro.ps``).
    rounds:          total BSP/SSP rounds the plan executes.
    staleness:       SSP bound ``s`` (reads ≤ s rounds stale); > 0 only
                     valid with ``executor="ssp"``.
    pipeline_depth:  explicit schedule-prefetch depth.  ``None`` derives
                     it from the executor (scan→0, pipelined→1); a
                     nonzero value requires ``executor="pipelined"``.
    phase_unroll:    rounds unrolled per scan step, as a multiple of the
                     app's ``phase_period`` (1 = one phase cycle per scan
                     step — the default and the bit-identical baseline).
                     Only meaningful for the scanned executors.
    telemetry:       the observability policy, as a declarative
                     :class:`~repro.obs.spec.TelemetrySpec` (kind ∈
                     counters | trace).  ``False`` (the default) runs
                     uninstrumented; a spec makes **every** executor
                     return a populated
                     :class:`~repro.obs.report.RunReport` as
                     ``ExecutionReport.telemetry`` (device counters,
                     host events under ``kind="trace"``, and the SSP
                     staleness/byte section for ssp plans) — final model
                     state stays bit-identical either way.  The
                     deprecated bool form still works: ``True`` warns
                     and normalizes to ``TelemetrySpec(kind="counters")``.
    checkpoint_every: checkpoint cadence in rounds for
                     ``StradsEngine.execute(..., ckpt_dir=...)`` (0 = no
                     checkpointing); must tile the executor's step length.
    collect_every:   trace cadence in rounds for the app-level ``fit``
                     adapters (0 = no trace).  ``execute`` itself collects
                     per round whenever a collect fn is passed; this field
                     records the decimation cadence consumers apply.
    donate:          donate the input state buffers to the XLA program.
    workers:         expected ``data``-mesh width (placement override).
                     ``None`` = whatever mesh the engine was built with;
                     a value is validated against the engine's mesh and
                     used by drivers (``dryrun --plan``) to *build* the
                     mesh.
    scheduler:       the scheduling policy, as a declarative
                     :class:`~repro.sched.spec.SchedulerSpec` (kind ∈
                     round_robin | random | rotation | dynamic_priority |
                     block_structural plus its parameters).  ``None`` =
                     the app's ``default_scheduler_spec()``; a value is
                     resolved and injected by ``StradsEngine.execute``,
                     so ``fit(plan=...)`` overrides policy without
                     touching app config.
    partitioner:     the partition policy, as a declarative
                     :class:`~repro.part.spec.PartitionerSpec` (kind ∈
                     static | size_balanced | load_balanced plus its
                     parameters).  ``None`` = the app's
                     ``default_partitioner_spec()``; the resolved
                     partitioner owns the variable→worker
                     :class:`~repro.part.assignment.Assignment`, and the
                     engine checks it for rebalances at the
                     ``checkpoint_every`` chunk boundaries — the other
                     half of the paper's primitive pair, swappable from
                     the plan exactly like the scheduler.
    kernels:         the compute backend serving the round body's
                     hot-spots, as a declarative
                     :class:`~repro.kernels.spec.KernelSpec` (kind ∈
                     reference | pallas plus tile knobs).  ``None`` =
                     the app's ``default_kernel_spec()`` (falling back
                     to ``reference`` — the bit-identical
                     pre-KernelSpec behavior); a value is resolved via
                     ``repro.kernels.build_kernels`` and injected by
                     ``StradsEngine.execute``, with the Pallas kind
                     automatically running in interpret mode off-TPU —
                     the third leg of the "everything is a plan edit"
                     surface.
    """

    executor: str = "scan"
    rounds: int = 1
    staleness: int = 0
    pipeline_depth: Optional[int] = None
    phase_unroll: int = 1
    telemetry: Union[bool, TelemetrySpec] = False
    checkpoint_every: int = 0
    collect_every: int = 0
    donate: bool = True
    workers: Optional[int] = None
    scheduler: Optional[SchedulerSpec] = None
    partitioner: Optional[PartitionerSpec] = None
    kernels: Optional[KernelSpec] = None

    def __post_init__(self):
        if self.executor not in EXECUTORS:
            raise ValueError(_EXECUTOR_MSG.format(self.executor))
        if not isinstance(self.rounds, int) or self.rounds < 1:
            raise ValueError(f"rounds must be a positive int; got "
                             f"{self.rounds!r}")
        if not isinstance(self.staleness, int) or self.staleness < 0:
            raise ValueError(f"staleness must be an int >= 0; got "
                             f"{self.staleness!r}")
        if self.staleness > 0 and self.executor != "ssp":
            raise ValueError(
                f"staleness={self.staleness} requires executor='ssp'; got "
                f"executor={self.executor!r}")
        if self.pipeline_depth is not None:
            if self.pipeline_depth not in (0, 1):
                raise ValueError(f"pipeline_depth must be 0 or 1, got "
                                 f"{self.pipeline_depth}")
            if self.pipeline_depth > 0 and self.executor != "pipelined":
                raise ValueError(
                    f"pipeline_depth={self.pipeline_depth} requires "
                    f"executor='pipelined'; got {self.executor!r}")
            if self.pipeline_depth == 0 and self.executor == "pipelined":
                raise ValueError("executor='pipelined' means "
                                 "pipeline_depth=1; leave it None or pass 1")
        if not isinstance(self.phase_unroll, int) or self.phase_unroll < 1:
            raise ValueError(f"phase_unroll must be a positive int; got "
                             f"{self.phase_unroll!r}")
        if self.phase_unroll > 1 and self.executor not in ("scan",
                                                           "pipelined"):
            raise ValueError(
                f"phase_unroll={self.phase_unroll} only applies to the "
                f"scanned executors; got executor={self.executor!r}")
        # telemetry graduated from a bool to a TelemetrySpec; True used
        # to raise off-ssp ("telemetry=True requires executor='ssp'") —
        # now every executor carries engine-wide counters, so the bool
        # form only warns and normalizes onto the spec it implies.
        if self.telemetry is None:
            object.__setattr__(self, "telemetry", False)
        if isinstance(self.telemetry, bool):
            if self.telemetry:
                warnings.warn(
                    "plan.telemetry=True (bool) is deprecated; pass a "
                    "repro.obs.TelemetrySpec — it no longer requires "
                    "executor='ssp' (True maps to kind='counters', the "
                    "engine-wide device counters, on every executor)",
                    DeprecationWarning, stacklevel=3)
                object.__setattr__(self, "telemetry",
                                   TelemetrySpec(kind="counters"))
        elif not isinstance(self.telemetry, TelemetrySpec):
            raise ValueError(
                f"telemetry must be a bool or a repro.obs.TelemetrySpec "
                f"(its own __post_init__ validates the kind); got "
                f"{type(self.telemetry).__name__}")
        for field in ("checkpoint_every", "collect_every"):
            v = getattr(self, field)
            if not isinstance(v, int) or v < 0:
                raise ValueError(f"{field} must be an int >= 0; got {v!r}")
        if not isinstance(self.donate, bool):
            raise ValueError(f"donate must be a bool; got {self.donate!r}")
        if self.workers is not None and (not isinstance(self.workers, int)
                                         or self.workers < 1):
            raise ValueError(f"workers must be None or a positive int; "
                             f"got {self.workers!r}")
        if self.scheduler is not None \
                and not isinstance(self.scheduler, SchedulerSpec):
            raise ValueError(
                f"scheduler must be None or a repro.sched.SchedulerSpec "
                f"(its own __post_init__ validates the policy); got "
                f"{type(self.scheduler).__name__}")
        if self.partitioner is not None \
                and not isinstance(self.partitioner, PartitionerSpec):
            raise ValueError(
                f"partitioner must be None or a repro.part.PartitionerSpec "
                f"(its own __post_init__ validates the policy); got "
                f"{type(self.partitioner).__name__}")
        if self.kernels is not None \
                and not isinstance(self.kernels, KernelSpec):
            raise ValueError(
                f"kernels must be None or a repro.kernels.KernelSpec "
                f"(its own __post_init__ validates the backend); got "
                f"{type(self.kernels).__name__}")

    # -- derived views -------------------------------------------------------

    @property
    def depth(self) -> int:
        """The schedule-prefetch depth this plan's executor runs at."""
        if self.pipeline_depth is not None:
            return self.pipeline_depth
        return 1 if self.executor == "pipelined" else 0

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        """A plain JSON-safe dict (every field, defaults included) —
        ``from_json(to_json(p)) == p`` exactly."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj) -> "ExecutionPlan":
        """Rebuild from ``to_json`` output, a JSON string, or a partial
        dict (missing fields take their defaults; unknown keys raise)."""
        if isinstance(obj, (str, bytes)):
            obj = json.loads(obj)
        if not isinstance(obj, dict):
            raise TypeError(f"ExecutionPlan.from_json wants a dict or JSON "
                            f"string; got {type(obj).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - known
        if unknown:
            raise ValueError(f"unknown ExecutionPlan field(s): "
                             f"{sorted(unknown)}")
        if isinstance(obj.get("scheduler"), dict):
            obj = dict(obj,
                       scheduler=SchedulerSpec.from_json(obj["scheduler"]))
        if isinstance(obj.get("partitioner"), dict):
            obj = dict(obj, partitioner=PartitionerSpec.from_json(
                obj["partitioner"]))
        if isinstance(obj.get("kernels"), dict):
            obj = dict(obj, kernels=KernelSpec.from_json(obj["kernels"]))
        if isinstance(obj.get("telemetry"), dict):
            obj = dict(obj, telemetry=TelemetrySpec.from_json(
                obj["telemetry"]))
        return cls(**obj)


@dataclasses.dataclass
class ExecutionReport:
    """Uniform result of ``StradsEngine.execute`` — every executor fills
    the same four slots (unused ones stay ``None``).

    state:      final model state pytree.
    trace:      stacked per-round ``collect`` outputs (leading axis =
                rounds executed this call), or ``None`` without a collect
                fn.
    telemetry:  :class:`repro.obs.report.RunReport` when the plan
                carries a :class:`~repro.obs.spec.TelemetrySpec` — the
                uniform per-run metrics object (device counters, host
                events, and the SSP staleness/byte section as its
                ``.ssp`` for ssp plans); ``None`` uninstrumented.
    carry:      resumable executor carry — :class:`repro.ps.ssp.SSPCarry`
                for SSP, :class:`repro.core.engine.EngineCarry` for the
                loop/scanned executors.  Round-trips through
                ``checkpoint/npz``; pass it back to ``execute`` to
                continue the same plan bit-exactly.
    plan:       the plan that produced this report.
    stream:     the final stream-cursor payload (flat numpy:
                ``cursor``/``rows_in``/``rows_dropped``/``fill0``) when
                the run streamed data in via ``execute(..., stream=,
                source=)``; ``None`` for unstreamed runs.  The same
                dict rides each checkpoint as its ``"stream"`` subtree.
    """
    state: Any
    trace: Any = None
    telemetry: Any = None
    carry: Any = None
    plan: Optional[ExecutionPlan] = None
    stream: Optional[dict] = None
