"""The STRADS round executors: host loop, scanned, and pipelined.

Turns a :class:`~repro.core.primitives.StradsApp` into jitted programs
executing

    propose → [schedule_stats → psum] → schedule → push → psum → pull

with ``push``/``schedule_stats`` running under ``shard_map`` over the
``data`` mesh axis and schedule decisions replicated.  ``sync`` is
automatic: SPMD program order is the BSP barrier (DESIGN.md §3).

Three execution paths share one traced round body:

* :meth:`StradsEngine.run` — the host loop: one jitted round per
  dispatch, a host↔device sync every round, arbitrary Python callbacks
  between rounds.  The debugging/metrics path.
* :meth:`StradsEngine.run_scanned` with ``pipeline_depth=0`` — rolls R
  rounds into a single ``jax.lax.scan`` (one XLA program, donated state
  buffers, zero per-round host round-trips).  Bit-identical to the host
  loop: same PRNG stream, same op order.
* ``pipeline_depth=1`` — the paper's pipelined scheduler: inside scan
  step t the schedule for round t+1 is computed from the state *before*
  round t's update, so it carries no data dependency on round t's
  push/pull and XLA is free to overlap the two (software pipelining).
  The schedule each round executes is therefore exactly one round stale
  — the STRADS stale-schedule guarantee (Lee et al. 2014 §pipelining;
  dynamic Lasso keeps converging because priorities c_j change slowly
  between adjacent rounds).
* :meth:`StradsEngine.run_ssp` — the bounded-staleness (SSP) executor,
  implemented by the parameter-server subsystem in :mod:`repro.ps`:
  reads of replicated state served from worker caches up to s rounds
  old, pushes aggregated lazily into one batched flush collective per
  s+1-round window.  ``staleness=0`` is bit-identical to
  ``run_scanned(pipeline_depth=0)``.

Apps whose communication pattern cycles with period L (``phase_period``,
e.g. LDA's rotation over U workers, MF's H/W alternation) get L rounds
unrolled per scan step so every ``phase`` stays a static Python int (the
LDA ``ppermute`` needs a static permutation).

Scheduler state (e.g. ``DynamicPriorityScheduler``'s Δx history) must
live in the state pytree / scan carry, never host-side — see
``schedulers.init_carry``/``update_carry``.

The engine runs identically on a single device (unit tests, laptop-scale
experiments) and on multi-chip meshes; the production 256/512-chip
lowering is exercised by ``launch/dryrun.py`` (``--engine`` mode for this
executor).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import make_mesh, shard_map
from .kvstore import KVStore, store_from_tree
from .primitives import RoundResult, StradsApp, StradsAppBase, tree_psum

DATA_AXIS = "data"


def _replicate_spec(tree: Any) -> Any:
    return jax.tree.map(lambda _: P(), tree)


class StradsEngine:
    """Compiles a StradsApp into BSP round programs on a device mesh.

    Parameters
    ----------
    app:         the STRADS application.
    mesh:        device mesh with a ``data`` axis (workers = shards).
    data_specs:  PartitionSpec pytree for the data (the paper's 1/P split).
    state_specs: PartitionSpec pytree for model state.  Replicated leaves
                 (``P()``) behave like the paper's synced KV-store values;
                 sharded leaves are worker-local model partitions (model
                 parallelism — the Fig-3 memory win).
    """

    def __init__(self, app: StradsApp, mesh: Mesh, data_specs: Any,
                 state_specs: Any = None):
        self.app = app
        self.mesh = mesh
        self.data_specs = data_specs
        self.state_specs = state_specs
        self._needs_stats = getattr(
            app, "needs_schedule_stats",
            type(app).schedule_stats is not StradsAppBase.schedule_stats)
        self._round = self._build_round()
        self._scan_cache: dict = {}
        self.kvstore: Optional[KVStore] = None   # built by place_state

    # -- traced round pieces (shared by every executor) ---------------------

    @property
    def phase_period(self) -> int:
        """Length of the app's static-phase cycle (1 = phaseless)."""
        return int(getattr(self.app, "phase_period", 1))

    def _sspec(self, state):
        return (_replicate_spec(state) if self.state_specs is None
                else self.state_specs)

    def _make_schedule(self, state, data, rng, t, phase):
        """propose → [schedule_stats → psum] → schedule (replicated)."""
        app = self.app
        r1, r2 = jax.random.split(rng)
        cand = app.propose(state, r1, t, phase)
        if self._needs_stats:
            def stats_fn(data, state, cand):
                s = app.schedule_stats(data, state, cand, phase)
                return tree_psum(s, DATA_AXIS)
            stats = shard_map(
                stats_fn, mesh=self.mesh,
                in_specs=(self.data_specs, self._sspec(state),
                          _replicate_spec(cand)),
                out_specs=P(),
            )(data, state, cand)
        else:
            stats = None
        return app.schedule(state, cand, stats, r2, t, phase)

    def _apply(self, state, data, sched, phase):
        """push → psum → pull under shard_map (the BSP update + sync)."""
        app = self.app
        sspec = self._sspec(state)

        def push_pull(data, state, sched):
            z, local = app.push(data, state, sched, phase)
            z = tree_psum(z, DATA_AXIS)      # pull aggregation Σ_p z^p
            return app.pull(state, sched, z, local, data, phase)

        return shard_map(
            push_pull, mesh=self.mesh,
            in_specs=(self.data_specs, sspec, _replicate_spec(sched)),
            out_specs=sspec,
        )(data, state, sched)

    def _build_round(self):
        @partial(jax.jit, static_argnums=(3,))
        def round_fn(state, data, rng, phase, t):
            sched = self._make_schedule(state, data, rng, t, phase)
            new_state = self._apply(state, data, sched, phase)
            return RoundResult(state=new_state, sched=sched)

        return round_fn

    # -- placement helpers ---------------------------------------------------

    def init_state(self, rng: jax.Array, **app_kwargs):
        """Initialize the app state and place it through the KV store
        (extra keyword args go to ``app.init_state`` — e.g. the Lasso
        residual seed ``y``)."""
        return self.place_state(self.app.init_state(rng, **app_kwargs))

    def place_state(self, state):
        """Place a state pytree via :class:`~repro.core.kvstore.KVStore`
        — the single source of variable placement and byte accounting
        (``self.kvstore`` afterwards answers Fig-3-style questions like
        ``bytes_per_device()``, and ``repro.ps`` derives the server-/
        worker-resident split from the same VarSpecs)."""
        self.kvstore = store_from_tree(self.mesh, state, self._sspec(state))
        return self.kvstore.place_tree(state)

    def shard_data(self, data):
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            data, self.data_specs)

    # -- execution: host loop ------------------------------------------------

    def run_round(self, state, data, rng, t: int = 0) -> RoundResult:
        phase = self.app.static_phase(t)
        return self._round(state, data, rng, phase, jnp.int32(t))

    def run(self, state, data, rng, num_rounds: int, callback=None):
        """Drive ``num_rounds`` BSP rounds (host loop; each round jitted).

        ``callback(t, state, result)`` runs between rounds (metrics, early
        stop by returning True)."""
        for t in range(num_rounds):
            rng, sub = jax.random.split(rng)
            out = self.run_round(state, data, sub, t)
            state = out.state
            if callback is not None and callback(t, state, out):
                break
        return state

    # -- execution: scanned / pipelined --------------------------------------

    def run_scanned(self, state, data, rng, num_rounds: int, *,
                    pipeline_depth: int = 0,
                    collect: Optional[Callable[[Any], Any]] = None,
                    donate: bool = True):
        """Execute ``num_rounds`` rounds as one XLA program.

        ``pipeline_depth=0`` reproduces :meth:`run` bit-for-bit (same PRNG
        stream, fresh schedules).  ``pipeline_depth=1`` software-pipelines
        the scheduler one round ahead (see module docstring); round t then
        executes the schedule computed from the state after round t−2 —
        the paper's one-round schedule staleness.  The round-t schedule
        uses the *same* PRNG key in both modes, so depth-1 differs from
        depth-0 only through staleness, never through a different random
        stream.

        ``collect(state) -> pytree`` is evaluated after every round inside
        the scan; the stacked results (leading axis ``num_rounds``) are
        returned as the trace without any per-round host sync.

        ``donate=True`` donates the state buffers to the XLA program (the
        caller's ``state`` is consumed); pass ``donate=False`` when the
        input state must stay alive (e.g. A/B comparisons in tests).

        Returns ``state`` when ``collect is None``, else
        ``(state, trace)``.
        """
        if pipeline_depth not in (0, 1):
            raise ValueError(f"pipeline_depth must be 0 or 1, got "
                             f"{pipeline_depth}")
        if num_rounds < 1:
            raise ValueError("run_scanned needs num_rounds >= 1 (use the "
                             "host loop `run` for zero-round calls)")
        period = self.phase_period
        num_steps, tail = divmod(num_rounds, period)
        if tail and pipeline_depth == 1:
            raise ValueError(
                f"pipeline_depth=1 needs num_rounds divisible by the app's "
                f"phase_period ({period}); got {num_rounds}")

        traces = []
        if num_steps:
            fn = self._get_scan_fn(num_steps, pipeline_depth,
                                   collect, donate)
            state, rng, ys = fn(state, data, rng)
            if collect is not None:
                traces.append(ys)

        # Remainder rounds (num_rounds % period) fall back to the host
        # loop with fresh schedules — only reachable at depth 0.
        for k in range(tail):
            t = num_steps * period + k
            rng, sub = jax.random.split(rng)
            out = self.run_round(state, data, sub, t)
            state = out.state
            if collect is not None:
                traces.append(jax.tree.map(
                    lambda x: jnp.asarray(x)[None], collect(state)))

        if collect is None:
            return state
        trace = (jax.tree.map(lambda *xs: jnp.concatenate(xs), *traces)
                 if len(traces) > 1 else traces[0])
        return state, trace

    def scanned_fn(self, num_rounds: int, *, pipeline_depth: int = 0,
                   collect: Optional[Callable] = None,
                   donate: bool = True):
        """The jitted ``(state, data, rng) → (state, rng, trace)`` multi-
        round program, exposed for AOT ``.lower().compile()`` (the
        production-mesh dry-run in ``launch/dryrun.py``).  ``num_rounds``
        must be a multiple of ``phase_period``."""
        num_steps, tail = divmod(num_rounds, self.phase_period)
        if tail or num_steps == 0:
            raise ValueError(
                f"num_rounds must be a positive multiple of phase_period "
                f"({self.phase_period}); got {num_rounds}")
        return self._get_scan_fn(num_steps, pipeline_depth, collect, donate)

    # -- execution: SSP (bounded staleness — repro.ps) -----------------------

    def run_ssp(self, state, data, rng, num_rounds: int, *,
                staleness: int = 0, **kw):
        """The bounded-staleness executor (see :mod:`repro.ps.ssp`):
        reads of replicated state served from worker caches up to
        ``staleness`` rounds old, pushes aggregated lazily at the flush.
        ``staleness=0`` is bit-identical to
        ``run_scanned(pipeline_depth=0)``."""
        from ..ps.ssp import run_ssp
        return run_ssp(self, state, data, rng, num_rounds,
                       staleness=staleness, **kw)

    def ssp_fn(self, num_rounds: int, *, staleness: int = 0,
               collect: Optional[Callable] = None, donate: bool = True):
        """The jitted multi-round SSP program, exposed for AOT
        ``.lower().compile()`` (``launch/dryrun.py --engine --staleness``).
        """
        from ..ps.ssp import ssp_fn
        return ssp_fn(self, num_rounds, staleness=staleness,
                      collect=collect, donate=donate)

    def _get_scan_fn(self, num_steps: int, depth: int,
                     collect: Optional[Callable], donate: bool):
        key = (num_steps, depth, collect, donate)
        fn = self._scan_cache.get(key)
        if fn is None:
            fn = self._build_scan(num_steps, depth, collect, donate)
            self._scan_cache[key] = fn
        return fn

    def _build_scan(self, num_steps: int, depth: int,
                    collect: Optional[Callable], donate: bool):
        period = self.phase_period

        def one_round(state, data, rng, t, phase, ys):
            # Depth-0 inner round: fresh schedule, then update — the exact
            # op/PRNG order of the host-loop round.
            sched = self._make_schedule(state, data, rng, t, phase)
            state = self._apply(state, data, sched, phase)
            if collect is not None:
                ys.append(collect(state))
            return state

        def scanned(state, data, rng):
            if depth == 0:
                def step(carry, _):
                    state, rng, t0 = carry
                    ys: list = []
                    for i in range(period):
                        rng, sub = jax.random.split(rng)
                        state = one_round(state, data, sub, t0 + i, i, ys)
                    return ((state, rng, t0 + period),
                            _stack_rounds(ys) if collect else None)

                (state, rng, _), ys = jax.lax.scan(
                    step, (state, rng, jnp.int32(0)), None,
                    length=num_steps)
            else:
                # Pipelined: carry the next round's schedule.  At the top
                # of step t we compute sched_{t+1} from the *pre-update*
                # state — it is independent of round t's push/pull, so the
                # two overlap; the executed schedule is one round stale.
                rng, sub = jax.random.split(rng)
                sched = self._make_schedule(state, data, sub,
                                            jnp.int32(0), 0)

                def step(carry, _):
                    state, rng, t0, sched = carry
                    ys: list = []
                    for i in range(period):
                        t = t0 + i
                        rng, sub = jax.random.split(rng)
                        sched_next = self._make_schedule(
                            state, data, sub, t + 1, (i + 1) % period)
                        state = self._apply(state, data, sched, i)
                        sched = sched_next
                        if collect is not None:
                            ys.append(collect(state))
                    return ((state, rng, t0 + period, sched),
                            _stack_rounds(ys) if collect else None)

                (state, rng, _, _), ys = jax.lax.scan(
                    step, (state, rng, jnp.int32(0), sched), None,
                    length=num_steps)

            if collect is not None:
                # (num_steps, period, ...) → (num_rounds, ...)
                ys = jax.tree.map(
                    lambda x: x.reshape((num_steps * period,)
                                        + x.shape[2:]), ys)
            return state, rng, ys

        return jax.jit(scanned, donate_argnums=(0,) if donate else ())


def _stack_rounds(ys: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ys)


def single_device_mesh() -> Mesh:
    """A 1-device ``data`` mesh for laptop-scale runs and unit tests."""
    return make_mesh((1,), (DATA_AXIS,))


def worker_mesh(num_workers: int) -> Mesh:
    devs = jax.devices()
    if len(devs) < num_workers:
        raise ValueError(
            f"mesh of {num_workers} workers needs ≥{num_workers} devices; "
            f"have {len(devs)} (set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=N before importing jax)")
    return make_mesh((num_workers,), (DATA_AXIS,))
