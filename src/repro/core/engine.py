"""The STRADS BSP round executor.

Turns a :class:`~repro.core.primitives.StradsApp` into a jitted function
executing

    propose → [schedule_stats → psum] → schedule → push → psum → pull

with ``push``/``schedule_stats`` running under ``shard_map`` over the
``data`` mesh axis and schedule decisions replicated.  ``sync`` is
automatic: SPMD program order is the BSP barrier (DESIGN.md §3).

The engine runs identically on a single device (unit tests, laptop-scale
experiments) and on multi-chip meshes; the production 256/512-chip lowering
is exercised by ``launch/dryrun.py``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .primitives import RoundResult, StradsApp, StradsAppBase, tree_psum

DATA_AXIS = "data"


def _replicate_spec(tree: Any) -> Any:
    return jax.tree.map(lambda _: P(), tree)


class StradsEngine:
    """Compiles a StradsApp into a BSP round on a device mesh.

    Parameters
    ----------
    app:         the STRADS application.
    mesh:        device mesh with a ``data`` axis (workers = shards).
    data_specs:  PartitionSpec pytree for the data (the paper's 1/P split).
    state_specs: PartitionSpec pytree for model state.  Replicated leaves
                 (``P()``) behave like the paper's synced KV-store values;
                 sharded leaves are worker-local model partitions (model
                 parallelism — the Fig-3 memory win).
    """

    def __init__(self, app: StradsApp, mesh: Mesh, data_specs: Any,
                 state_specs: Any = None):
        self.app = app
        self.mesh = mesh
        self.data_specs = data_specs
        self.state_specs = state_specs
        self._needs_stats = getattr(
            app, "needs_schedule_stats",
            type(app).schedule_stats is not StradsAppBase.schedule_stats)
        self._round = self._build_round()

    # -- construction ------------------------------------------------------

    def _build_round(self):
        app, mesh, data_specs = self.app, self.mesh, self.data_specs
        needs_stats = self._needs_stats
        state_specs = self.state_specs

        @partial(jax.jit, static_argnums=(3,))
        def round_fn(state, data, rng, phase, t):
            r1, r2 = jax.random.split(rng)
            sspec = (_replicate_spec(state) if state_specs is None
                     else state_specs)

            cand = app.propose(state, r1, t, phase)

            if needs_stats:
                def stats_fn(data, state, cand):
                    s = app.schedule_stats(data, state, cand, phase)
                    return tree_psum(s, DATA_AXIS)
                stats = jax.shard_map(
                    stats_fn, mesh=mesh,
                    in_specs=(data_specs, sspec, _replicate_spec(cand)),
                    out_specs=P(), check_vma=False,
                )(data, state, cand)
            else:
                stats = None

            sched = app.schedule(state, cand, stats, r2, t, phase)

            def push_pull(data, state, sched):
                z, local = app.push(data, state, sched, phase)
                z = tree_psum(z, DATA_AXIS)      # pull aggregation Σ_p z^p
                return app.pull(state, sched, z, local, data, phase)

            new_state = jax.shard_map(
                push_pull, mesh=mesh,
                in_specs=(data_specs, sspec, _replicate_spec(sched)),
                out_specs=sspec, check_vma=False,
            )(data, state, sched)
            return RoundResult(state=new_state, sched=sched)

        return round_fn

    # -- placement helpers ---------------------------------------------------

    def init_state(self, rng: jax.Array):
        state = self.app.init_state(rng)
        if self.state_specs is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
                state, self.state_specs)
        return state

    def shard_data(self, data):
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            data, self.data_specs)

    # -- execution -------------------------------------------------------------

    def run_round(self, state, data, rng, t: int = 0) -> RoundResult:
        phase = self.app.static_phase(t)
        import jax.numpy as jnp
        return self._round(state, data, rng, phase, jnp.int32(t))

    def run(self, state, data, rng, num_rounds: int, callback=None):
        """Drive ``num_rounds`` BSP rounds (host loop; each round jitted).

        ``callback(t, state, result)`` runs between rounds (metrics, early
        stop by returning True)."""
        for t in range(num_rounds):
            rng, sub = jax.random.split(rng)
            out = self.run_round(state, data, sub, t)
            state = out.state
            if callback is not None and callback(t, state, out):
                break
        return state


def single_device_mesh() -> Mesh:
    """A 1-device ``data`` mesh for laptop-scale runs and unit tests."""
    return jax.make_mesh((1,), (DATA_AXIS,),
                         axis_types=(jax.sharding.AxisType.Auto,))


def worker_mesh(num_workers: int) -> Mesh:
    devs = jax.devices()
    if len(devs) < num_workers:
        raise ValueError(
            f"mesh of {num_workers} workers needs ≥{num_workers} devices; "
            f"have {len(devs)} (set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=N before importing jax)")
    return jax.make_mesh((num_workers,), (DATA_AXIS,),
                         axis_types=(jax.sharding.AxisType.Auto,))
