"""The STRADS round executors: host loop, scanned, and pipelined.

Turns a :class:`~repro.core.primitives.StradsApp` into jitted programs
executing

    propose → [schedule_stats → psum] → schedule → push → psum → pull

with ``push``/``schedule_stats`` running under ``shard_map`` over the
``data`` mesh axis and schedule decisions replicated.  ``sync`` is
automatic: SPMD program order is the BSP barrier (DESIGN.md §3).

The one public entry point is :meth:`StradsEngine.execute`, driven by a
declarative :class:`~repro.core.plan.ExecutionPlan` (executor choice,
rounds, staleness, unrolling, checkpoint cadence, **scheduling policy**
— validated at plan construction) and returning a uniform
:class:`~repro.core.plan.ExecutionReport` (state, trace, telemetry,
resumable carry).  Under it, four execution paths share one traced round
body:

* :meth:`StradsEngine.run` — the host loop: one jitted round per
  dispatch, a host↔device sync every round, arbitrary Python callbacks
  between rounds.  The debugging/metrics path.
* :meth:`StradsEngine.run_scanned` with ``pipeline_depth=0`` — rolls R
  rounds into a single ``jax.lax.scan`` (one XLA program, donated state
  buffers, zero per-round host round-trips).  Bit-identical to the host
  loop: same PRNG stream, same op order.
* ``pipeline_depth=1`` — the paper's pipelined scheduler: inside scan
  step t the schedule for round t+1 is computed from the state *before*
  round t's update, so it carries no data dependency on round t's
  push/pull and XLA is free to overlap the two (software pipelining).
  The schedule each round executes is therefore exactly one round stale
  — the STRADS stale-schedule guarantee (Lee et al. 2014 §pipelining;
  dynamic Lasso keeps converging because priorities c_j change slowly
  between adjacent rounds).
* :meth:`StradsEngine.run_ssp` — the bounded-staleness (SSP) executor,
  implemented by the parameter-server subsystem in :mod:`repro.ps`:
  reads of replicated state served from worker caches up to s rounds
  old, pushes aggregated lazily into one batched flush collective per
  s+1-round window.  ``staleness=0`` is bit-identical to
  ``run_scanned(pipeline_depth=0)``.

Apps whose communication pattern cycles with period L (``phase_period``,
e.g. LDA's rotation over U workers, MF's H/W alternation) get L rounds
unrolled per scan step so every ``phase`` stays a static Python int (the
LDA ``ppermute`` needs a static permutation).

Scheduling policy is **injected** (the v2 scheduler-injection contract,
:mod:`repro.core.primitives`): the engine resolves
``plan.scheduler`` — or the app's ``default_scheduler_spec()`` — into a
:class:`~repro.sched.protocol.Scheduler` and hands it to the app before
tracing.  The scheduler's on-device state (e.g.
``DynamicPriorityScheduler``'s Δx history) is the engine-owned
**scheduler carry**: created by ``scheduler.init_carry()``, threaded
through every executor's scan carry, folded forward by the app's
``sched_update`` after each committed round, and returned (and resumed)
as :attr:`EngineCarry.sched_carry` — never an app-state stowaway, so it
checkpoints through ``checkpoint/npz`` with the PRNG stream and round
counter.

Partition policy is injected the same way (the partitioning contract,
:mod:`repro.core.primitives`): ``plan.partitioner`` — or the app's
``default_partitioner_spec()`` — resolves to a
:class:`~repro.part.protocol.Partitioner` whose variable→worker
:class:`~repro.part.assignment.Assignment` the engine owns.  Repartition
checks run host-side at the ``checkpoint_every`` chunk boundaries of
:meth:`StradsEngine.execute` (state is synced there, so a move is a
``KVStore.repartition`` re-placement); compiled-program caches are keyed
per (SchedulerSpec, Assignment, KernelSpec), and the assignment +
activity stats ride the ``{"state", "carry", "assignment"}`` checkpoint
payload (resumed via ``execute(..., partition=...)``).

Kernel backends complete the injection triple (the kernel-injection
contract, :mod:`repro.core.primitives`): ``plan.kernels`` — or the app's
``default_kernel_spec()``, falling back to ``kind="reference"`` —
resolves via ``repro.kernels.build_kernels`` into a backend object the
app's ``push``/``schedule_stats`` dispatch their hot-spots through
(``self.kernels.lasso_partial`` / ``.gram_block``).  The backend is
stateless (no carry, no checkpoint payload); it only changes what the
traced round lowers to — fused Pallas kernels on TPU, interpret-mode
automatically elsewhere, the pure-jnp oracles for ``"reference"``.

The engine runs identically on a single device (unit tests, laptop-scale
experiments) and on multi-chip meshes; the production 256/512-chip
lowering is exercised by ``launch/dryrun.py`` (``--engine`` mode for this
executor).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import warnings
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import KernelSpec, build_kernels
from ..obs import RunReport, counters as obs_counters
from ..obs.events import Recorder
from ..part import Assignment, PartitionerSpec, build_partitioner
from ..sched import SchedulerSpec, build_scheduler
from .compat import make_mesh, shard_map
from .kvstore import KVStore, store_from_tree
from .plan import ExecutionPlan, ExecutionReport
from .primitives import RoundResult, StradsApp, StradsAppBase, tree_psum

DATA_AXIS = "data"

_UNSET = object()
_NULL_CTX = contextlib.nullcontext()   # reusable no-op span


def _replicate_spec(tree: Any) -> Any:
    return jax.tree.map(lambda _: P(), tree)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EngineCarry:
    """Resumable carry of the loop/scanned executors: PRNG stream, next
    round index, the engine-owned scheduler carry (e.g. the Δx priority
    history; ``None`` for stateless policies), (pipelined only) the
    in-flight prefetched schedule, and — under a plan-level
    :class:`~repro.obs.spec.TelemetrySpec` — the device telemetry
    counters (:mod:`repro.obs.counters`; ``None`` uninstrumented, so an
    instrumented carry checkpoints/resumes the counters bit-exactly
    through ``checkpoint_every`` chunking while old checkpoints restore
    unchanged).  The SSP twin (with vector clocks) is
    :class:`repro.ps.ssp.SSPCarry`; both round-trip through
    ``checkpoint/npz``."""
    rng: jax.Array
    t: jax.Array                  # int32: next round index
    sched: Any = None             # depth-1 prefetched schedule (else None)
    sched_carry: Any = None       # scheduler carry (Δx history, …)
    obs: Any = None               # device telemetry counters (or None)


class StradsEngine:
    """Compiles a StradsApp into BSP round programs on a device mesh.

    Parameters
    ----------
    app:         the STRADS application.
    mesh:        device mesh with a ``data`` axis (workers = shards).
    data_specs:  PartitionSpec pytree for the data (the paper's 1/P split).
    state_specs: PartitionSpec pytree for model state.  Replicated leaves
                 (``P()``) behave like the paper's synced KV-store values;
                 sharded leaves are worker-local model partitions (model
                 parallelism — the Fig-3 memory win).
    scheduler:   optional :class:`~repro.sched.spec.SchedulerSpec`
                 overriding the app's ``default_scheduler_spec()`` from
                 construction time (``execute`` re-resolves per plan).
    partitioner: optional :class:`~repro.part.spec.PartitionerSpec`
                 overriding the app's ``default_partitioner_spec()``
                 the same way (plan > constructor > app).
    kernels:     optional :class:`~repro.kernels.spec.KernelSpec`
                 overriding the app's ``default_kernel_spec()`` the same
                 way (plan > constructor > app > ``reference``).
    """

    def __init__(self, app: StradsApp, mesh: Mesh, data_specs: Any,
                 state_specs: Any = None,
                 scheduler: Optional[SchedulerSpec] = None,
                 partitioner: Optional[PartitionerSpec] = None,
                 kernels: Optional[KernelSpec] = None):
        self.app = app
        self.mesh = mesh
        self.data_specs = data_specs
        self.state_specs = state_specs
        self._scan_cache: dict = {}
        self._active_spec: Optional[SchedulerSpec] = None
        self._round = None
        # a constructor spec outranks the app default whenever a plan
        # leaves its scheduler field None (plan > constructor > app)
        self._spec_override = scheduler
        self._part_override = partitioner
        self._kern_override = kernels
        self._active_part_spec: Optional[PartitionerSpec] = None
        self._active_kern_spec: Optional[KernelSpec] = None
        self.partitioner = None
        self._assignment: Optional[Assignment] = None
        self._part_stats = None
        self._recorder: Optional[Recorder] = None   # live during execute
        self.set_kernels(None)    # before set_scheduler's first round-bind
        self.set_scheduler(None)
        self.set_partitioner(None)
        self.kvstore: Optional[KVStore] = None   # built by place_state

    # -- observability hooks (the telemetry-injection contract) --------------

    def _obs_event(self, name: str, **args):
        """Record a host event when a Recorder is live (``kind="trace"``
        during ``execute``) — a no-op otherwise, so event sites cost
        nothing uninstrumented."""
        if self._recorder is not None:
            self._recorder.instant(name, **args)

    def _obs_span(self, name: str, **args):
        """A wall-clock phase span under a live Recorder, else a
        null context."""
        if self._recorder is not None:
            return self._recorder.span(name, **args)
        return _NULL_CTX

    def _obs_num_candidates(self) -> int:
        """The active scheduler's static proposal-pool size U′ (0 for
        policies without one) — the ρ-filter ledger's 'proposed' term."""
        return int(getattr(self.scheduler, "num_candidates", 0) or 0)

    # -- scheduler injection (the v2 contract) -------------------------------

    def set_scheduler(self, spec: Optional[SchedulerSpec] = None):
        """Resolve a :class:`~repro.sched.spec.SchedulerSpec` (``None`` →
        the engine's constructor spec, else the app's
        ``default_scheduler_spec()``) into a
        :class:`~repro.sched.protocol.Scheduler`, inject it into the app,
        and rebind the traced round programs.  Idempotent for an
        unchanged spec, and compiled programs are cached per spec, so
        swapping policies back and forth never recompiles.  Returns the
        active scheduler (or ``None`` for self-scheduling apps)."""
        if spec is None:
            spec = self._spec_override
        resolved = spec if spec is not None else self._default_spec()
        if resolved == self._active_spec and self._round is not None:
            return self.scheduler
        sched = None
        if resolved is not None:
            kinds = getattr(self.app, "supported_scheduler_kinds", None)
            if kinds is not None and resolved.kind not in kinds:
                raise ValueError(
                    f"{type(self.app).__name__} cannot consume a "
                    f"{resolved.kind!r} scheduler (it supports "
                    f"{sorted(kinds)}); fix the plan's SchedulerSpec")
            sched = build_scheduler(
                resolved, num_vars=self.app.num_schedulable(),
                num_workers=self.mesh.shape[DATA_AXIS])
        if hasattr(self.app, "use_scheduler"):
            self.app.use_scheduler(sched)
        else:
            # protocol-only apps: always (re)assign, so resolving back
            # to a spec-less policy actually clears the old scheduler
            self.app.scheduler = sched
        self._active_spec = resolved
        self._needs_stats = getattr(
            self.app, "needs_schedule_stats",
            type(self.app).schedule_stats
            is not StradsAppBase.schedule_stats)
        # Compiled programs are cached PER SPEC and PER ASSIGNMENT
        # (every _scan_cache key carries both), so swapping policies —
        # a plan sweep — or rebalancing the partition reuses each
        # configuration's compiled programs instead of recompiling on
        # every switch.
        self._rebind_round()
        return sched

    def _rebind_round(self):
        """(Re)fetch the traced round program for the active
        (SchedulerSpec, Assignment, KernelSpec) triple — called whenever
        any of them changes, so a stale program can never serve a new
        policy, a moved partition, or a swapped kernel backend."""
        key = ("round", self._active_spec, self._assignment,
               self._active_kern_spec)
        self._round = self._scan_cache.get(key)
        if self._round is None:
            self._obs_event("cache_miss", program="round",
                            **self._cache_key_args())
            self._round = self._build_round()
            self._scan_cache[key] = self._round

    def _cache_key_args(self) -> dict:
        """The (SchedulerSpec, Assignment, KernelSpec) compiled-program
        cache key, JSON-safe — what cache-miss events carry."""
        asgn = self._assignment
        return {
            "scheduler": (self._active_spec.kind
                          if self._active_spec is not None else None),
            "assignment_version": (asgn.version if asgn is not None
                                   else None),
            "kernels": (self._active_kern_spec.kind
                        if self._active_kern_spec is not None else None),
        }

    def _default_spec(self) -> Optional[SchedulerSpec]:
        fn = getattr(self.app, "default_scheduler_spec", None)
        return fn() if callable(fn) else None

    @property
    def scheduler(self):
        """The injected :class:`~repro.sched.protocol.Scheduler` (``None``
        for apps that schedule themselves)."""
        return getattr(self.app, "scheduler", None)

    @property
    def scheduler_spec(self) -> Optional[SchedulerSpec]:
        """The resolved spec of the active scheduler (for artifacts)."""
        return self._active_spec

    def init_sched_carry(self):
        """A fresh engine-owned scheduler carry (``None`` when the policy
        is stateless or the app self-schedules)."""
        sched = self.scheduler
        return sched.init_carry() if sched is not None else None

    def mark_sched_carry(self, carry, candidates):
        """The SSP in-flight exclusion over the scheduler carry (identity
        without an injected scheduler — state-resident priority tables go
        through :class:`~repro.core.kvstore.VarTable` instead)."""
        sched = self.scheduler
        return (sched.mark_scheduled(carry, candidates)
                if sched is not None else carry)

    # -- partition injection (the partitioning contract) ---------------------

    def set_partitioner(self, spec: Optional[PartitionerSpec] = None):
        """Resolve a :class:`~repro.part.spec.PartitionerSpec` (``None``
        → the engine's constructor spec, else the app's
        ``default_partitioner_spec()``) into a
        :class:`~repro.part.protocol.Partitioner`, inject its initial
        variable→worker assignment into the app, and rebind the traced
        round programs.  Idempotent for an unchanged spec — crucially,
        it then *keeps* the current assignment and activity stats, so a
        resumed run continues the partition trajectory instead of
        resetting it.  Returns the active partitioner (or ``None`` for
        apps with no partition story)."""
        if spec is None:
            spec = self._part_override
        resolved = spec if spec is not None else self._default_part_spec()
        if resolved == self._active_part_spec:
            return self.partitioner
        if resolved is None:
            self.partitioner = None
            self._active_part_spec = None
            self._part_stats = None
            self._install_assignment(None)
            return None
        kinds = getattr(self.app, "supported_partitioner_kinds", None)
        if kinds is not None and resolved.kind not in kinds:
            raise ValueError(
                f"{type(self.app).__name__} cannot host a "
                f"{resolved.kind!r} partitioner (it supports "
                f"{sorted(kinds)}); fix the plan's PartitionerSpec")
        if resolved.kind == "load_balanced" \
                and not self._has_partition_signal():
            raise ValueError(
                f"kind='load_balanced' needs a per-variable activity "
                f"signal, but {type(self.app).__name__} does not define "
                f"partition_signal(state); declare one (see "
                f"repro.core.primitives) or use a static kind")
        sizes_fn = getattr(self.app, "partition_sizes", None)
        part = build_partitioner(
            resolved, num_vars=self.app.num_schedulable(),
            num_workers=self.mesh.shape[DATA_AXIS],
            sizes=sizes_fn() if callable(sizes_fn) else None)
        self.partitioner = part
        self._active_part_spec = resolved
        self._part_stats = part.init_stats()
        self._install_assignment(part.init_assignment())
        return part

    def _default_part_spec(self) -> Optional[PartitionerSpec]:
        fn = getattr(self.app, "default_partitioner_spec", None)
        return fn() if callable(fn) else None

    def _has_partition_signal(self) -> bool:
        fn = getattr(type(self.app), "partition_signal", None)
        return (fn is not None
                and fn is not StradsAppBase.partition_signal)

    def _install_assignment(self, assignment: Optional[Assignment]):
        self._assignment = assignment
        if hasattr(self.app, "use_partition"):
            self.app.use_partition(assignment)
        else:
            self.app.assignment = assignment
        self._rebind_round()

    @property
    def partitioner_spec(self) -> Optional[PartitionerSpec]:
        """The resolved spec of the active partitioner (for artifacts)."""
        return self._active_part_spec

    @property
    def partition_assignment(self) -> Optional[Assignment]:
        """The active variable→worker assignment (``None`` without a
        partitioner)."""
        return self._assignment

    @property
    def partition_stats(self):
        """The partitioner's host-side activity state (the load
        balancer's per-variable EMA; ``None`` for stateless kinds)."""
        return self._part_stats

    def reset_partition(self):
        """Back to the partitioner's initial assignment and fresh stats
        — what a fresh (carry-less, payload-less) ``execute`` does, so
        rebalances from a previous run can never leak into a new one."""
        part = self.partitioner
        if part is None:
            return
        self._part_stats = part.init_stats()
        init = part.init_assignment()
        if init != self._assignment:
            self._install_assignment(init)

    def apply_assignment(self, assignment: Assignment, state: Any = None):
        """Adopt a new assignment mid-run: the KV store re-derives its
        VarSpecs and re-places the worker-resident leaves
        (:meth:`~repro.core.kvstore.KVStore.repartition` — byte
        accounting stays truthful), the app receives the move via
        ``use_partition``, and the traced-program binding is refreshed
        (compiled caches are keyed per assignment, so this is one cache
        miss the first time and a hit ever after).  Returns the
        re-placed state when one is passed."""
        out = None
        if self.kvstore is not None:
            out = self.kvstore.repartition(assignment, state)
        elif state is not None:
            out = state
        self._install_assignment(assignment)
        return out

    def partition_payload(self) -> Optional[dict]:
        """The ``"assignment"`` subtree of a chunked run's
        ``{"state", "carry", "assignment"}`` checkpoint: the assignment
        arrays plus the partitioner's activity stats, flat for
        ``checkpoint/npz``.  ``None`` without a partitioner."""
        if self._assignment is None:
            return None
        payload = dict(self._assignment.payload())
        if isinstance(self._part_stats, dict):
            for k, v in self._part_stats.items():
                payload[f"stats_{k}"] = np.asarray(v)
        return payload

    def restore_partition(self, payload: dict):
        """Resume the partition trajectory from a checkpoint's
        ``"assignment"`` payload (``execute(..., partition=...)``): the
        saved assignment is re-applied and the activity stats restored,
        so the resumed run replays the remaining rebalance decisions
        bit-exactly."""
        if self.partitioner is None:
            raise ValueError(
                "restore_partition needs an active partitioner (the "
                "plan/app resolved none) — was this checkpoint written "
                "under a different plan?")
        asgn = Assignment.from_payload(
            {k: payload[k] for k in ("owner", "num_workers", "version")})
        num_workers = self.mesh.shape[DATA_AXIS]
        if asgn.num_workers != num_workers:
            raise ValueError(
                f"checkpointed assignment spans {asgn.num_workers} "
                f"workers but the engine mesh has {num_workers}")
        num_vars = self.partitioner.num_vars
        if asgn.num_vars != num_vars:
            raise ValueError(
                f"checkpointed assignment covers {asgn.num_vars} "
                f"variables but this app partitions {num_vars} — was "
                f"this checkpoint written for a different model size?")
        stats = {k[len("stats_"):]: np.asarray(v)
                 for k, v in payload.items() if k.startswith("stats_")}
        fresh = self.partitioner.init_stats()
        if (stats or fresh is not None) and set(stats) != \
                set(fresh or {}):
            raise ValueError(
                f"checkpointed partition stats {sorted(stats)} do not "
                f"match the resolved {self._active_part_spec.kind!r} "
                f"partitioner's {sorted(fresh or {})} — the "
                f"PartitionerSpec must match across resume")
        if stats:
            self._part_stats = stats
        self.apply_assignment(asgn)

    def _partition_signal_snapshot(self, state) -> Optional[np.ndarray]:
        """Host copy of the app's per-variable partition signal (taken
        *before* a chunk runs — donation consumes the device buffers)."""
        if self.partitioner is None:
            return None
        fn = getattr(self.app, "partition_signal", None)
        sig = fn(state) if callable(fn) else None
        if sig is None:
            return None
        return np.array(jax.device_get(sig))

    def _partition_step(self, state, sig_before, t: int,
                        allow_move: bool = True):
        """One chunk-boundary partition check: fold the chunk's observed
        activity |Δsignal| into the partitioner's stats, and rebalance
        (re-place + rebind) when the policy says so.  Host-side — state
        is already synced here.  Returns ``(state, sig_after)`` so the
        caller reuses the chunk-end snapshot as the next chunk's
        baseline instead of re-fetching it (``sig_before=None`` — no
        stateful policy or no app signal — skips the snapshot
        entirely).  ``allow_move=False`` still measures but never
        rebalances — the final chunk boundary, where a move would
        produce an assignment no round ever runs under."""
        part = self.partitioner
        sig_after = (self._partition_signal_snapshot(state)
                     if sig_before is not None else None)
        activity = (np.abs(sig_after - sig_before)
                    if sig_after is not None else None)
        self._part_stats = part.measure(self._part_stats,
                                        self._assignment, activity)
        if allow_move and part.should_rebalance(
                self._part_stats, self._assignment, t):
            new = part.propose_assignment(self._part_stats,
                                          self._assignment)
            if new.owner != self._assignment.owner:
                # the rebalance event carries the measured before/after
                # load spreads (the imbalance the move was for)
                weights = (self._part_stats.get("ema")
                           if isinstance(self._part_stats, dict)
                           else None)
                if weights is not None:
                    self._obs_event(
                        "rebalance", t=t,
                        spread_before=self._assignment.spread(weights),
                        spread_after=new.spread(weights),
                        version=new.version)
                else:
                    self._obs_event("rebalance", t=t,
                                    version=new.version)
                # re-placement keeps leaf values, so sig_after stays a
                # valid baseline for the next chunk
                state = self.apply_assignment(new, state)
        return state, sig_after

    # -- kernel injection (the kernel-injection contract) --------------------

    def set_kernels(self, spec: Optional[KernelSpec] = None):
        """Resolve a :class:`~repro.kernels.spec.KernelSpec` (``None``
        → the engine's constructor spec, else the app's
        ``default_kernel_spec()``, else ``kind="reference"`` — the
        bit-identical pre-KernelSpec round body) into an executable
        backend (``repro.kernels.build_kernels``: Pallas for Mosaic on
        TPU, interpret-mode automatically elsewhere), inject it into the
        app, and rebind the traced round programs.  Idempotent for an
        unchanged spec, and compiled programs are cached per spec, so a
        reference↔pallas sweep never recompiles.  Returns the active
        backend."""
        if spec is None:
            spec = self._kern_override
        resolved = spec if spec is not None else self._default_kern_spec()
        if resolved is None:
            resolved = KernelSpec(kind="reference")
        if resolved == self._active_kern_spec and self._round is not None:
            return self.kernels
        kinds = getattr(self.app, "supported_kernel_kinds", None)
        if kinds is not None and resolved.kind not in kinds:
            raise ValueError(
                f"{type(self.app).__name__} cannot dispatch a "
                f"{resolved.kind!r} kernel backend (it supports "
                f"{sorted(kinds)}); fix the plan's KernelSpec")
        backend = build_kernels(resolved)
        if hasattr(self.app, "use_kernels"):
            self.app.use_kernels(backend)
        else:
            # protocol-only apps: assign directly, mirroring the
            # scheduler fallback
            self.app.kernels = backend
        self._active_kern_spec = resolved
        # The very first round-bind belongs to set_scheduler (it also
        # derives _needs_stats); during __init__ this runs before the
        # scheduler exists, so only REbind here.
        if self._round is not None:
            self._rebind_round()
        return backend

    def _default_kern_spec(self) -> Optional[KernelSpec]:
        fn = getattr(self.app, "default_kernel_spec", None)
        return fn() if callable(fn) else None

    @property
    def kernels(self):
        """The injected kernel backend (never ``None`` once the engine
        is constructed — ``reference`` is the floor)."""
        return getattr(self.app, "kernels", None)

    @property
    def kernel_spec(self) -> Optional[KernelSpec]:
        """The resolved spec of the active kernel backend (for
        artifacts)."""
        return self._active_kern_spec

    # -- traced round pieces (shared by every executor) ---------------------

    @property
    def phase_period(self) -> int:
        """Length of the app's static-phase cycle (1 = phaseless)."""
        return int(getattr(self.app, "phase_period", 1))

    def _sspec(self, state):
        return (_replicate_spec(state) if self.state_specs is None
                else self.state_specs)

    def _make_schedule(self, state, carry, data, rng, t, phase):
        """propose → [schedule_stats → psum] → schedule (replicated)."""
        app = self.app
        r1, r2 = jax.random.split(rng)
        cand = app.propose(state, carry, r1, t, phase)
        if self._needs_stats:
            def stats_fn(data, state, cand):
                s = app.schedule_stats(data, state, cand, phase)
                return tree_psum(s, DATA_AXIS)
            stats = shard_map(
                stats_fn, mesh=self.mesh,
                in_specs=(self.data_specs, self._sspec(state),
                          _replicate_spec(cand)),
                out_specs=P(),
            )(data, state, cand)
        else:
            stats = None
        return app.schedule(state, carry, cand, stats, r2, t, phase)

    def _apply(self, state, data, sched, phase):
        """push → psum → pull under shard_map (the BSP update + sync)."""
        app = self.app
        sspec = self._sspec(state)

        def push_pull(data, state, sched):
            z, local = app.push(data, state, sched, phase)
            z = tree_psum(z, DATA_AXIS)      # pull aggregation Σ_p z^p
            return app.pull(state, sched, z, local, data, phase)

        return shard_map(
            push_pull, mesh=self.mesh,
            in_specs=(self.data_specs, sspec, _replicate_spec(sched)),
            out_specs=sspec,
        )(data, state, sched)

    def _sched_update(self, carry, before, after, sched, phase):
        fn = getattr(self.app, "sched_update", None)
        return fn(carry, before, after, sched, phase) if fn else carry

    def _build_round(self):
        @partial(jax.jit, static_argnums=(4,))
        def round_fn(state, carry, data, rng, phase, t):
            sched = self._make_schedule(state, carry, data, rng, t, phase)
            new_state = self._apply(state, data, sched, phase)
            new_carry = self._sched_update(carry, state, new_state, sched,
                                           phase)
            return RoundResult(state=new_state, sched=sched,
                               sched_carry=new_carry)

        return round_fn

    # -- placement helpers ---------------------------------------------------

    def init_state(self, rng: jax.Array, **app_kwargs):
        """Initialize the app state and place it through the KV store
        (extra keyword args go to ``app.init_state`` — e.g. the Lasso
        residual seed ``y``)."""
        return self.place_state(self.app.init_state(rng, **app_kwargs))

    def app_roles(self) -> dict:
        """The app's declarative VarSpec role map (``var_roles()``; see
        :class:`~repro.core.kvstore.VarSpec` — ``"priority"`` leaves the
        SSP window scheduler masks for in-flight exclusion when an app
        keeps its priority table in state rather than the engine carry)."""
        fn = getattr(self.app, "var_roles", None)
        return dict(fn()) if callable(fn) else {}

    def place_state(self, state):
        """Place a state pytree via :class:`~repro.core.kvstore.KVStore`
        — the single source of variable placement and byte accounting
        (``self.kvstore`` afterwards answers Fig-3-style questions like
        ``bytes_per_device()``, and ``repro.ps`` derives the server-/
        worker-resident split from the same VarSpecs)."""
        self.kvstore = store_from_tree(self.mesh, state, self._sspec(state),
                                       roles=self.app_roles())
        return self.kvstore.place_tree(state)

    def shard_data(self, data):
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            data, self.data_specs)

    # -- execution: host loop ------------------------------------------------

    def run_round(self, state, data, rng, t: int = 0,
                  sched_carry: Any = _UNSET) -> RoundResult:
        """One jitted BSP round.  ``sched_carry`` defaults to a fresh
        ``scheduler.init_carry()``; thread ``result.sched_carry`` back in
        to keep a stateful policy's priorities evolving across rounds
        (omitting it at t > 0 warns — the priorities silently reset to
        uniform, which is almost never what a round loop wants; use
        :meth:`run`/:meth:`execute` for whole runs)."""
        phase = self.app.static_phase(t)
        if sched_carry is _UNSET:
            sched_carry = self.init_sched_carry()
            if t and sched_carry is not None:
                warnings.warn(
                    "run_round(t>0) without sched_carry reinitializes "
                    "the stateful scheduler's priorities every call; "
                    "thread result.sched_carry between rounds (or drive "
                    "the run through run/execute)", UserWarning,
                    stacklevel=2)
        return self._round(state, sched_carry, data, rng, phase,
                           jnp.int32(t))

    def run(self, state, data, rng, num_rounds: int, callback=None):
        """Drive ``num_rounds`` BSP rounds (host loop; each round jitted).

        ``callback(t, state, result)`` runs between rounds (metrics, early
        stop by returning True).  Zero (or negative) rounds are a no-op —
        the zero-round escape hatch ``run_scanned`` points callers at.
        One implementation with the plan path: this is exactly
        ``execute(plan(executor="loop"))``."""
        if num_rounds < 1:
            return state
        plan = ExecutionPlan(executor="loop", rounds=num_rounds)
        # execute-equivalence includes the policies: re-resolve the
        # default specs so a scheduler, partitioner, or kernel backend
        # swept in by a previous execute(plan.…=...) cannot leak into
        # this run
        self.set_scheduler(None)
        self.set_partitioner(None)
        self.set_kernels(None)
        self.reset_partition()
        return self._execute_span(state, data, rng, plan, num_rounds, 0,
                                  None, None, callback).state

    # -- execution: scanned / pipelined --------------------------------------

    def run_scanned(self, state, data, rng, num_rounds: int, *,
                    pipeline_depth: int = 0,
                    collect: Optional[Callable[[Any], Any]] = None,
                    donate: bool = True, unroll: int = 1,
                    t0: int = 0, sched0: Any = None,
                    sched_carry0: Any = _UNSET, obs0: Any = None,
                    return_carry: bool = False):
        """Execute ``num_rounds`` rounds as one XLA program.

        ``pipeline_depth=0`` reproduces :meth:`run` bit-for-bit (same PRNG
        stream, fresh schedules).  ``pipeline_depth=1`` software-pipelines
        the scheduler one round ahead (see module docstring); round t then
        executes the schedule computed from the state after round t−2 —
        the paper's one-round schedule staleness.  The round-t schedule
        uses the *same* PRNG key in both modes, so depth-1 differs from
        depth-0 only through staleness, never through a different random
        stream.

        ``collect(state) -> pytree`` is evaluated after every round inside
        the scan; the stacked results (leading axis ``num_rounds``) are
        returned as the trace without any per-round host sync.

        ``donate=True`` donates the state buffers to the XLA program (the
        caller's ``state`` is consumed); pass ``donate=False`` when the
        input state must stay alive (e.g. A/B comparisons in tests).

        ``unroll`` widens a scan step to ``unroll`` phase cycles
        (``ExecutionPlan.phase_unroll``): the same round sequence chunked
        ``unroll × phase_period`` rounds per step — bit-identical, fewer
        scan iterations.

        ``t0``/``sched0``/``sched_carry0`` resume a previous run (pass
        the values from an :class:`EngineCarry`; ``t0`` must be a
        multiple of the phase period, ``sched0`` is only meaningful at
        depth 1 where it is the prefetched in-flight schedule, and
        ``sched_carry0`` is the scheduler carry — omitted, a fresh
        ``scheduler.init_carry()`` is used, which is only correct at
        ``t0=0``).  ``obs0`` threads the device telemetry counters
        (:func:`repro.obs.counters.init_counters`, or the previous
        carry's ``obs``) through the scan; ``None`` runs
        uninstrumented.  ``return_carry=True`` appends the final carry
        to the return value.

        Returns ``state`` (plus ``trace`` when collecting, plus ``carry``
        when requested).
        """
        if pipeline_depth not in (0, 1):
            raise ValueError(f"pipeline_depth must be 0 or 1, got "
                             f"{pipeline_depth}")
        if num_rounds < 1:
            raise ValueError("run_scanned needs num_rounds >= 1 (use the "
                             "host loop `run` for zero-round calls)")
        if unroll < 1:
            raise ValueError(f"unroll must be >= 1, got {unroll}")
        period = self.phase_period
        if t0 % period:
            raise ValueError(f"t0 must be a multiple of the phase period "
                             f"({period}) so phases stay static; got {t0}")
        if sched0 is not None and pipeline_depth != 1:
            raise ValueError("sched0 only resumes the pipelined executor "
                             "(pipeline_depth=1)")
        if sched_carry0 is _UNSET:
            sched_carry0 = self.init_sched_carry()
            if t0 and sched_carry0 is not None:
                warnings.warn(
                    "run_scanned(t0>0) without sched_carry0 "
                    "reinitializes the stateful scheduler's priorities; "
                    "pass the EngineCarry.sched_carry a previous run "
                    "returned for a bit-exact resume", UserWarning,
                    stacklevel=2)
        L = period * unroll
        num_steps, tail = divmod(num_rounds, L)
        if tail and pipeline_depth == 1:
            raise ValueError(
                f"pipeline_depth=1 needs num_rounds divisible by the app's "
                f"phase_period ({period}) × unroll ({unroll}); got "
                f"{num_rounds}")

        traces = []
        sched_c = sched0
        sc = sched_carry0
        obs = obs0
        if num_steps:
            fn = self._get_scan_fn(num_steps, pipeline_depth, collect,
                                   donate, unroll, sched0 is not None)
            args = (state, data, rng, jnp.int32(t0), sc, obs)
            if sched0 is not None:
                args += (sched0,)
            state, rng, sched_c, sc, obs, ys = fn(*args)
            if collect is not None:
                traces.append(ys)

        # Remainder rounds (num_rounds % (period × unroll)) fall back to
        # the host loop with fresh schedules — only reachable at depth 0.
        num_cand = self._obs_num_candidates()
        for k in range(tail):
            t = t0 + num_steps * L + k
            rng, sub = jax.random.split(rng)
            out = self.run_round(state, data, sub, t, sched_carry=sc)
            state, sc = out.state, out.sched_carry
            if obs is not None:
                obs = obs_counters.observe_round(obs, out.sched,
                                                 t % period, num_cand)
            if collect is not None:
                traces.append(jax.tree.map(
                    lambda x: jnp.asarray(x)[None], collect(state)))

        ret = [state]
        if collect is not None:
            ret.append(jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                    *traces)
                       if len(traces) > 1 else traces[0])
        if return_carry:
            ret.append(EngineCarry(rng=rng, t=jnp.int32(t0 + num_rounds),
                                   sched=sched_c, sched_carry=sc,
                                   obs=obs))
        return ret[0] if len(ret) == 1 else tuple(ret)

    def scanned_fn(self, num_rounds: int, *, pipeline_depth: int = 0,
                   collect: Optional[Callable] = None,
                   donate: bool = True, unroll: int = 1):
        """The jitted ``(state, data, rng, t0, sched_carry, obs) →
        (state, rng, sched, sched_carry, obs, trace)`` multi-round
        program, exposed for AOT ``.lower().compile()`` (the
        production-mesh dry-run in ``launch/dryrun.py``; pass
        ``engine.init_sched_carry()`` for a fresh run and ``None`` —
        or ``repro.obs.init_counters(engine.phase_period)`` — for
        ``obs``).  ``num_rounds`` must be a multiple of ``phase_period
        × unroll``."""
        num_steps, tail = divmod(num_rounds, self.phase_period * unroll)
        if tail or num_steps == 0:
            raise ValueError(
                f"num_rounds must be a positive multiple of phase_period "
                f"× unroll ({self.phase_period * unroll}); got "
                f"{num_rounds}")
        # pin the handle to the active policy: it traces lazily, and a
        # set_scheduler swap between fetch and first call would
        # otherwise bake the wrong scheduler into the per-spec cache
        return _SpecBoundFn(self, self._active_spec,
                            self._get_scan_fn(num_steps, pipeline_depth,
                                              collect, donate, unroll,
                                              False))

    # -- execution: SSP (bounded staleness — repro.ps) -----------------------

    def run_ssp(self, state, data, rng, num_rounds: int, *,
                staleness: int = 0, **kw):
        """The bounded-staleness executor (see :mod:`repro.ps.ssp`):
        reads of replicated state served from worker caches up to
        ``staleness`` rounds old, pushes aggregated lazily at the flush.
        ``staleness=0`` is bit-identical to
        ``run_scanned(pipeline_depth=0)``."""
        from ..ps.ssp import run_ssp
        return run_ssp(self, state, data, rng, num_rounds,
                       staleness=staleness, **kw)

    def ssp_fn(self, num_rounds: int, *, staleness: int = 0,
               collect: Optional[Callable] = None, donate: bool = True):
        """The jitted multi-round SSP program, exposed for AOT
        ``.lower().compile()`` (``launch/dryrun.py --engine --staleness``).
        """
        from ..ps.ssp import ssp_fn
        return _SpecBoundFn(self, self._active_spec,
                            ssp_fn(self, num_rounds, staleness=staleness,
                                   collect=collect, donate=donate))

    # -- execution: the unified entry point ----------------------------------

    def execute(self, state, data, rng, plan: ExecutionPlan, *,
                collect: Optional[Callable[[Any], Any]] = None,
                callback=None, carry=None,
                ckpt_dir: Optional[str] = None,
                partition: Optional[dict] = None,
                stream=None, source=None,
                stream_state: Optional[dict] = None) -> ExecutionReport:
        """Run an :class:`~repro.core.plan.ExecutionPlan` — the one entry
        point that subsumes :meth:`run`, :meth:`run_scanned` and
        :meth:`run_ssp` and returns a uniform
        :class:`~repro.core.plan.ExecutionReport`.

        ``plan.scheduler`` (a :class:`~repro.sched.spec.SchedulerSpec`)
        selects the scheduling policy; ``None`` resolves to the app's
        ``default_scheduler_spec()``.  Either way the resolved scheduler
        is injected before tracing and its carry is threaded through the
        run (and the report's resumable ``carry``).

        ``collect(state) -> pytree`` is evaluated after every executed
        round (the report's ``trace`` stacks the results).  ``callback(t,
        state, round_result)`` is the host-loop hook and therefore
        requires ``executor="loop"`` (return True to stop early).

        ``carry`` resumes a previous report's run of the *same* plan:
        rounds ``carry.t .. plan.rounds`` execute with the carried PRNG
        stream/clocks/scheduler carry/prefetched schedule, so an
        interrupted run matches an uninterrupted one bit-for-bit (``rng``
        is taken from the carry and the argument is ignored).

        ``plan.partitioner`` (a :class:`~repro.part.spec.PartitionerSpec`)
        selects the partition policy the same way (``None`` resolves to
        the app's ``default_partitioner_spec()``).  The resolved
        partitioner owns the variable→worker assignment; repartition
        checks run at the chunk boundaries below (state is host-synced
        there — see the partitioning contract in
        :mod:`repro.core.primitives`).  A fresh run (no ``carry``)
        starts from the partitioner's initial assignment; resuming
        passes the checkpoint's ``"assignment"`` payload as
        ``partition=`` so the trajectory continues bit-exactly.

        ``ckpt_dir`` + ``plan.checkpoint_every`` chunk the run and save a
        ``{"state", "carry"}`` checkpoint (plus ``"assignment"`` when a
        partitioner is active, plus ``"stream"`` when streaming) via
        :mod:`repro.checkpoint` every ``checkpoint_every`` rounds (the
        cadence must tile the executor's step length; each chunk reuses
        one compiled program).

        ``stream`` (a :class:`~repro.stream.spec.StreamSpec`) +
        ``source`` (a :class:`~repro.stream.source.DataSource`) ingest
        data deltas at the host-synced boundaries ``t %
        stream.ingest_every == 0`` — the streaming-injection surface
        (see the ingest contract in :mod:`repro.core.primitives`).
        Like ``ServeSpec`` it rides this entry point, never the plan,
        so it can't be silently ignored.  An empty source is
        bit-identical to not passing ``stream`` at all.  ``stream_state``
        resumes the ring cursor from a checkpoint's ``"stream"``
        payload (pair it with :func:`repro.stream.replay_data` when the
        resumed process no longer holds the streamed data pytree).
        """
        if not isinstance(plan, ExecutionPlan):
            raise TypeError(f"execute() wants an ExecutionPlan; got "
                            f"{type(plan).__name__} (legacy executor= "
                            f"kwargs live behind the app-level fit shims)")
        num_workers = self.mesh.shape[DATA_AXIS]
        if plan.workers is not None and plan.workers != num_workers:
            raise ValueError(
                f"plan.workers={plan.workers} but the engine mesh has "
                f"{num_workers} '{DATA_AXIS}' shards")
        if callback is not None and plan.executor != "loop":
            raise ValueError("callback is a host-loop hook; it requires "
                             f"executor='loop' (got {plan.executor!r})")
        self.set_scheduler(plan.scheduler)
        self.set_partitioner(plan.partitioner)
        self.set_kernels(plan.kernels)
        if partition is not None:
            self.restore_partition(partition)
        elif carry is None:
            # fresh run: rebalances from a previous execute of the same
            # spec must not leak in (in-process resumes keep them)
            self.reset_partition()
        t_done = 0
        if carry is not None:
            if plan.executor == "ssp" and not hasattr(carry, "clocks"):
                raise ValueError("resuming an ssp plan needs the SSPCarry "
                                 "a previous ssp report returned")
            if plan.executor in ("scan", "pipelined") \
                    and not hasattr(carry, "sched"):
                raise ValueError("resuming a scanned plan needs the "
                                 "EngineCarry a previous scan/pipelined "
                                 "report returned")
            if plan.executor == "pipelined" and carry.sched is None:
                raise ValueError("resuming a pipelined plan needs the "
                                 "carried in-flight schedule (carry.sched "
                                 "is None — was this carry produced by a "
                                 "different executor?)")
            stateful = self.init_sched_carry() is not None
            prev_sc = getattr(carry, "sched_carry", None)
            if stateful and prev_sc is None:
                raise ValueError(
                    "resuming this plan needs the scheduler carry, but "
                    "carry.sched_carry is None — was this carry produced "
                    "under a different (stateless) SchedulerSpec?")
            if not stateful and prev_sc is not None:
                raise ValueError(
                    "carry.sched_carry holds a stateful scheduler's "
                    "history, but the plan's resolved policy is "
                    "stateless — the SchedulerSpec must match across "
                    "resume")
            t_done = int(carry.t)
            if not 0 <= t_done < plan.rounds:
                raise ValueError(f"carry.t={t_done} leaves no rounds of "
                                 f"the plan's {plan.rounds} to run")
            rng = carry.rng

        if ckpt_dir and not plan.checkpoint_every:
            raise ValueError("ckpt_dir was passed but plan.checkpoint_"
                             "every=0 — no checkpoint would ever be "
                             "written; set a cadence in the plan")
        if plan.checkpoint_every and not ckpt_dir:
            raise ValueError("plan.checkpoint_every="
                             f"{plan.checkpoint_every} but no ckpt_dir "
                             "was passed — the run would silently never "
                             "checkpoint")
        chunk = plan.checkpoint_every if ckpt_dir else 0
        if (stream is None) != (source is None):
            raise ValueError("stream= (a StreamSpec) and source= (a "
                             "DataSource) come as a pair — got only one")
        ingestor = None
        if stream is not None:
            from ..stream import Ingestor
            ingestor = Ingestor(stream, source)
            if stream_state is not None:
                ingestor.restore(stream_state)
            ingestor.bind(self, data)
        elif stream_state is not None:
            raise ValueError("stream_state resumes a streamed run; pass "
                             "the stream=/source= pair with it")
        pspec = self._active_part_spec
        if chunk and pspec is not None and pspec.rebalance_every \
                and pspec.rebalance_every % chunk:
            raise ValueError(
                f"partitioner.rebalance_every={pspec.rebalance_every} "
                f"must be a multiple of plan.checkpoint_every={chunk} — "
                f"repartition checks only run at chunk boundaries, so a "
                f"misaligned cadence would silently (almost) never fire")
        # telemetry (the telemetry-injection contract): the resolved
        # TelemetrySpec turns on device counters for every executor;
        # kind="trace" additionally opens a host Recorder for the span
        # of this execute (cache misses, rebalances, checkpoints, phase
        # spans).  The final report's .telemetry is a uniform RunReport.
        tspec = plan.telemetry or None
        rec = (Recorder(profiler=tspec.profiler)
               if tspec is not None and tspec.events else None)
        self._recorder = rec
        try:
            with (rec.span("execute", executor=plan.executor,
                           rounds=plan.rounds) if rec is not None
                  else _NULL_CTX):
                rep = self._execute_plan(state, data, rng, plan, t_done,
                                         carry, collect, callback, chunk,
                                         pspec, ckpt_dir, ingestor)
        finally:
            self._recorder = None
        if tspec is not None:
            ssp_parts = rep.telemetry if isinstance(rep.telemetry, list) \
                else ([rep.telemetry] if rep.telemetry is not None
                      else [])
            if len(ssp_parts) > 1:
                from ..ps.telemetry import merge_summaries
                ssp = merge_summaries(ssp_parts)
            else:
                ssp = ssp_parts[0] if ssp_parts else None
            rep.telemetry = RunReport.build(
                tspec, plan.executor, int(rep.carry.t),
                device_counters=getattr(rep.carry, "obs", None),
                recorder=rec, ssp=ssp)
        else:
            rep.telemetry = None
        return rep

    def _execute_plan(self, state, data, rng, plan: ExecutionPlan,
                      t_done: int, carry, collect, callback, chunk: int,
                      pspec, ckpt_dir, ingestor=None) -> ExecutionReport:
        """The executor dispatch of :meth:`execute` — whole-plan, or the
        boundary-chunked loop (checkpoint cadence, ingest cadence, or
        their gcd when both are active).  Under an ssp plan the
        returned report's ``telemetry`` holds the raw per-chunk
        :class:`~repro.ps.telemetry.SSPTelemetry` (a list when chunked);
        ``execute`` merges it into the final :class:`RunReport`."""
        ing_every = ingestor.spec.ingest_every if ingestor is not None \
            else 0
        if not chunk and not ing_every:
            if pspec is not None and pspec.kind == "load_balanced":
                warnings.warn(
                    "a load_balanced partitioner only rebalances at "
                    "checkpoint chunk boundaries; without plan."
                    "checkpoint_every + ckpt_dir the assignment stays "
                    "at its initial (static) value for the whole run",
                    UserWarning, stacklevel=3)
            return self._execute_span(state, data, rng, plan,
                                      plan.rounds - t_done, t_done, carry,
                                      collect, callback)
        step_len = self._step_length(plan)
        if chunk and chunk % step_len:
            raise ValueError(
                f"plan.checkpoint_every={chunk} must be a multiple of the "
                f"{plan.executor!r} executor's step length {step_len} "
                f"(phase/window alignment), so every chunk resumes on a "
                f"step boundary")
        if ing_every and ing_every % step_len:
            raise ValueError(
                f"stream.ingest_every={ing_every} must be a multiple of "
                f"the {plan.executor!r} executor's step length {step_len} "
                f"(phase/window alignment), so every ingest boundary is "
                f"host-synced")
        if plan.executor in ("pipelined", "ssp") and plan.rounds % step_len:
            # fail before any chunk runs — the same plan without ckpt_dir
            # is rejected upfront by the executor itself
            raise ValueError(
                f"plan.rounds={plan.rounds} must be a multiple of the "
                f"{plan.executor!r} executor's step length {step_len}; "
                f"the final checkpoint chunk would be unrunnable")
        # with both cadences active, spans run boundary to boundary; the
        # plain checkpointed run keeps span == chunk exactly as before
        span = (math.gcd(chunk, ing_every) if chunk and ing_every
                else (chunk or ing_every))
        from ..checkpoint import save_checkpoint
        stops: list = []                        # callback early-stop marker
        cb = callback
        if callback is not None:
            def cb(t, s, out, _orig=callback):
                r = _orig(t, s, out)
                if r:
                    stops.append(t)
                return r
        traces = []
        ssp_parts: list = []          # per-chunk SSPTelemetry summaries
        t = t_done
        # the activity baseline is only worth a host sync when a
        # stateful policy will consume it (static/size_balanced measure
        # nothing); one snapshot here, then each chunk reuses the
        # previous boundary's
        sig0 = (self._partition_signal_snapshot(state)
                if self._part_stats is not None else None)
        while t < plan.rounds:
            if ingestor is not None:
                # ingest-at-top / checkpoint-at-bottom: the checkpoint
                # at t precedes the ingest at t, so a resumed run
                # re-ingests boundary t exactly like the uninterrupted
                # one did
                state, data = ingestor.step(self, state, data, t)
            n = min(span, plan.rounds - t)
            rep = self._execute_span(state, data, rng, plan, n, t, carry,
                                     collect, cb)
            state, carry = rep.state, rep.carry
            rng = carry.rng
            if rep.trace is not None:
                traces.append(rep.trace)
            if rep.telemetry is not None:
                ssp_parts.append(rep.telemetry)
            t = int(carry.t)
            at_chunk = (not chunk or t % chunk == 0 or t >= plan.rounds
                        or bool(stops))
            if self.partitioner is not None and at_chunk:
                # the repartition check rides the chunk boundary: state
                # is host-synced here, so a move is a re-placement (the
                # next chunk fetches programs under the new assignment;
                # after the LAST chunk there is no next chunk, so only
                # measure — never move)
                state, sig0 = self._partition_step(
                    state, sig0, t, allow_move=t < plan.rounds)
            if ckpt_dir and at_chunk:
                payload = {"state": state, "carry": carry}
                if self.partitioner is not None:
                    payload["assignment"] = self.partition_payload()
                if ingestor is not None:
                    payload["stream"] = ingestor.payload()
                with self._obs_span("checkpoint", t=t):
                    save_checkpoint(ckpt_dir, t, payload)
            if stops:                           # honored across chunks
                break
        trace = (jax.tree.map(lambda *xs: jnp.concatenate(xs), *traces)
                 if traces else None)
        return ExecutionReport(state=state, trace=trace,
                               telemetry=ssp_parts or None,
                               carry=carry, plan=plan,
                               stream=(ingestor.payload()
                                       if ingestor is not None else None))

    def _step_length(self, plan: ExecutionPlan) -> int:
        """Rounds one compiled step of the plan's executor covers — the
        alignment unit for checkpoint chunking and resume points."""
        if plan.executor == "ssp":
            from ..ps.ssp import rounds_per_step
            return rounds_per_step(self, plan.staleness)
        if plan.executor in ("scan", "pipelined"):
            # chunks smaller than a full scan step would silently degrade
            # to per-round host-loop tails (scan tolerates a tail, but
            # 'each chunk reuses one compiled program' would be a lie)
            return self.phase_period * plan.phase_unroll
        return 1                                # loop: any round

    def _execute_span(self, state, data, rng, plan: ExecutionPlan,
                      rounds: int, t0: int, prev_carry, collect,
                      callback) -> ExecutionReport:
        """One contiguous span of a plan (the whole plan, or one
        checkpoint chunk), dispatched to the executor it names."""
        sc0 = (prev_carry.sched_carry if prev_carry is not None
               else self.init_sched_carry())
        # device counters: resume the previous chunk's (bit-exact through
        # checkpoint_every chunking), else start fresh when the plan is
        # instrumented; None runs uninstrumented
        obs0 = getattr(prev_carry, "obs", None)
        if obs0 is None and plan.telemetry:
            obs0 = obs_counters.init_counters(self.phase_period)
        if plan.executor == "loop":
            cfn = None
            if collect is not None:
                # cached so checkpoint-chunked loop runs compile it once
                key = ("loop_collect", collect)
                cfn = self._scan_cache.get(key)
                if cfn is None:
                    cfn = jax.jit(collect)
                    self._scan_cache[key] = cfn
            ys: list = []
            executed = 0
            sc = sc0
            obs = obs0
            num_cand = self._obs_num_candidates()
            period = self.phase_period
            with self._obs_span("loop", t0=t0, rounds=rounds):
                for k in range(rounds):
                    t = t0 + k
                    rng, sub = jax.random.split(rng)
                    out = self.run_round(state, data, sub, t,
                                         sched_carry=sc)
                    state, sc = out.state, out.sched_carry
                    if obs is not None:
                        obs = obs_counters.observe_round(
                            obs, out.sched, t % period, num_cand)
                    executed = k + 1
                    if cfn is not None:
                        ys.append(cfn(state))
                    if callback is not None and callback(t, state, out):
                        break
            trace = (jax.tree.map(lambda *xs: jnp.stack(xs), *ys)
                     if ys else None)
            carry = EngineCarry(rng=rng, t=jnp.int32(t0 + executed),
                                sched_carry=sc, obs=obs)
            return ExecutionReport(state=state, trace=trace,
                                   carry=carry, plan=plan)

        if plan.executor in ("scan", "pipelined"):
            sched0 = getattr(prev_carry, "sched", None)
            with self._obs_span(plan.executor, t0=t0, rounds=rounds):
                out = self.run_scanned(
                    state, data, rng, rounds, pipeline_depth=plan.depth,
                    collect=collect, donate=plan.donate,
                    unroll=plan.phase_unroll, t0=t0, sched0=sched0,
                    sched_carry0=sc0, obs0=obs0, return_carry=True)
            if collect is None:
                state, carry = out
                trace = None
            else:
                state, trace, carry = out
            return ExecutionReport(state=state, trace=trace,
                                   carry=carry, plan=plan)

        # executor == "ssp" (plan validation admits nothing else)
        clocks = getattr(prev_carry, "clocks", None)
        with self._obs_span("ssp", t0=t0, rounds=rounds,
                            staleness=plan.staleness):
            out = self.run_ssp(
                state, data, rng, rounds, staleness=plan.staleness,
                collect=collect, donate=plan.donate,
                with_telemetry=bool(plan.telemetry), t0=t0, clocks=clocks,
                sched_carry0=sc0, obs0=obs0, return_carry=True)
        parts = list(out if isinstance(out, tuple) else (out,))
        state = parts.pop(0)
        trace = parts.pop(0) if collect is not None else None
        telem = parts.pop(0) if plan.telemetry else None
        carry = parts.pop(0)
        return ExecutionReport(state=state, trace=trace, telemetry=telem,
                               carry=carry, plan=plan)

    def _get_scan_fn(self, num_steps: int, depth: int,
                     collect: Optional[Callable], donate: bool,
                     unroll: int = 1, with_sched0: bool = False):
        key = (self._active_spec, self._assignment,
               self._active_kern_spec, num_steps, depth,
               collect, donate, unroll, with_sched0)
        fn = self._scan_cache.get(key)
        if fn is None:
            self._obs_event("cache_miss", program="scan",
                            num_steps=num_steps, depth=depth,
                            **self._cache_key_args())
            fn = self._build_scan(num_steps, depth, collect, donate,
                                  unroll, with_sched0)
            self._scan_cache[key] = fn
        return fn

    def _build_scan(self, num_steps: int, depth: int,
                    collect: Optional[Callable], donate: bool,
                    unroll: int, with_sched0: bool):
        period = self.phase_period
        L = period * unroll           # rounds per scan step
        # telemetry is injected at trace time (the telemetry-injection
        # contract): counters observe only the schedule pytree, so the
        # state/PRNG stream is untouched — instrumented runs stay
        # bit-identical.  num_candidates is static per scheduler.
        num_cand = self._obs_num_candidates()

        def one_round(state, sc, data, rng, t, phase, obs, ys):
            # Depth-0 inner round: fresh schedule, then update — the exact
            # op/PRNG order of the host-loop round.
            sched = self._make_schedule(state, sc, data, rng, t, phase)
            if obs is not None:
                obs = obs_counters.observe_round(obs, sched, phase,
                                                 num_cand)
            new_state = self._apply(state, data, sched, phase)
            sc = self._sched_update(sc, state, new_state, sched, phase)
            if collect is not None:
                ys.append(collect(new_state))
            return new_state, sc, obs

        def scanned(state, data, rng, t0, sc0, obs0=None, *sched0):
            if depth == 0:
                def step(carry, _):
                    state, rng, tc, sc, obs = carry
                    ys: list = []
                    for i in range(L):
                        rng, sub = jax.random.split(rng)
                        state, sc, obs = one_round(state, sc, data, sub,
                                                   tc + i, i % period,
                                                   obs, ys)
                    return ((state, rng, tc + L, sc, obs),
                            _stack_rounds(ys) if collect else None)

                (state, rng, _, sc, obs), ys = jax.lax.scan(
                    step, (state, rng, t0, sc0, obs0), None,
                    length=num_steps)
                sched = None
            else:
                # Pipelined: carry the next round's schedule.  At the top
                # of step t we compute sched_{t+1} from the *pre-update*
                # state and scheduler carry — it is independent of round
                # t's push/pull, so the two overlap; the executed schedule
                # is one round stale.
                if with_sched0:
                    sched = sched0[0]       # resumed in-flight schedule
                else:
                    rng, sub = jax.random.split(rng)
                    sched = self._make_schedule(state, sc0, data, sub,
                                                t0, 0)

                def step(carry, _):
                    state, rng, tc, sc, sched, obs = carry
                    ys: list = []
                    for i in range(L):
                        t = tc + i
                        rng, sub = jax.random.split(rng)
                        sched_next = self._make_schedule(
                            state, sc, data, sub, t + 1, (i + 1) % period)
                        if obs is not None:
                            # count the schedule the round EXECUTES (the
                            # one-round-stale one), not the prefetch
                            obs = obs_counters.observe_round(
                                obs, sched, i % period, num_cand)
                        new_state = self._apply(state, data, sched,
                                                i % period)
                        sc = self._sched_update(sc, state, new_state,
                                                sched, i % period)
                        state = new_state
                        sched = sched_next
                        if collect is not None:
                            ys.append(collect(state))
                    return ((state, rng, tc + L, sc, sched, obs),
                            _stack_rounds(ys) if collect else None)

                (state, rng, _, sc, sched, obs), ys = jax.lax.scan(
                    step, (state, rng, t0, sc0, sched, obs0), None,
                    length=num_steps)

            if collect is not None:
                # (num_steps, L, ...) → (num_rounds, ...)
                ys = jax.tree.map(
                    lambda x: x.reshape((num_steps * L,) + x.shape[2:]),
                    ys)
            return state, rng, sched, sc, obs, ys

        return jax.jit(scanned, donate_argnums=(0,) if donate else ())


class _SpecBoundFn:
    """A compiled-program handle pinned to the (SchedulerSpec,
    Assignment, KernelSpec) triple it was requested under.  The
    underlying jit fn traces lazily (at first call/lower) against
    whatever scheduler, partition assignment, and kernel backend are
    then installed on the app, so a handle obtained before a
    ``set_scheduler``/``set_kernels`` swap or an ``apply_assignment``
    move would otherwise silently bake the *wrong* configuration into
    the per-key cache; this wrapper reinstalls its owning triple first
    (a cheap no-op when all are already active)."""

    def __init__(self, eng: "StradsEngine", spec, fn):
        self._eng, self._spec, self._fn = eng, spec, fn
        self._assignment = eng._assignment
        self._part_spec = eng._active_part_spec
        self._kern_spec = eng._active_kern_spec

    def _bind(self):
        self._eng.set_scheduler(self._spec)
        self._eng.set_kernels(self._kern_spec)
        if self._eng._active_part_spec != self._part_spec:
            # reinstalling the pinned assignment under a different
            # partitioner (or none) would desync assignment/stats/spec;
            # the handle is simply stale — refetch it
            raise RuntimeError(
                "this AOT handle was requested under PartitionerSpec "
                f"{self._part_spec!r} but the engine now runs "
                f"{self._eng._active_part_spec!r}; refetch scanned_fn/"
                f"ssp_fn after set_partitioner")
        if self._eng._assignment != self._assignment:
            self._eng.apply_assignment(self._assignment)

    def __call__(self, *args, **kw):
        self._bind()
        return self._fn(*args, **kw)

    def lower(self, *args, **kw):
        self._bind()
        return self._fn.lower(*args, **kw)


def _stack_rounds(ys: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ys)


def single_device_mesh() -> Mesh:
    """A 1-device ``data`` mesh for laptop-scale runs and unit tests."""
    return make_mesh((1,), (DATA_AXIS,))


def worker_mesh(num_workers: int) -> Mesh:
    devs = jax.devices()
    if len(devs) < num_workers:
        raise ValueError(
            f"mesh of {num_workers} workers needs ≥{num_workers} devices; "
            f"have {len(devs)} (set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=N before importing jax)")
    return make_mesh((num_workers,), (DATA_AXIS,))
