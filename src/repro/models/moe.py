"""Mixture-of-Experts layer: top-k router + expert-parallel FFN.

The router *is* the STRADS correspondence made concrete (DESIGN.md §4):
``schedule`` = top-k gating picks which variables (experts) each token
engages; ``push`` = per-expert FFN partial compute; ``pull`` = the
gate-weighted combine; ``sync`` = the all-to-all / collective traffic the
sharded einsums lower to.

Two dispatch implementations are provided:

* ``einsum`` — classic capacity-based one-hot dispatch (Switch/GShard
  style).  Baseline.  Its one-hot matmuls show up as real HLO FLOPs,
  which the roofline analysis quantifies.
* ``sort``  — beyond-paper optimization: tokens are sorted by expert id
  and moved with gathers/scatters, eliminating the dispatch-matmul FLOPs
  entirely (see EXPERIMENTS.md §Perf).

Experts are sharded over the ``model`` mesh axis (expert parallelism);
token groups over ``data``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..sharding import rules
from ..sharding.rules import constrain
from .params import ParamMeta
from .layers import apply_norm, norm_template, mlp_template, mlp_apply

# Token-group size for capacity accounting (tokens are dispatched within
# groups so the (g, E, C) one-hots stay small and data-sharded).
GROUP = 4096


def moe_template(cfg) -> Dict[str, Any]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    t = {
        "norm": norm_template(cfg),
        "router": ParamMeta((d, E), (rules.FSDP, None), scale=d ** -0.5),
        "wg": ParamMeta((E, d, f), (rules.EXPERT, rules.FSDP, None)),
        "wu": ParamMeta((E, d, f), (rules.EXPERT, rules.FSDP, None)),
        "wd": ParamMeta((E, f, d), (rules.EXPERT, None, rules.FSDP)),
    }
    if cfg.moe_shared_expert:
        t["shared"] = mlp_template(cfg)
    return t


def _capacity(g: int, k: int, E: int, factor: float) -> int:
    c = int(g * k / E * factor)
    return max(4, -(-c // 4) * 4)


def _router(p, h, cfg):
    """Common gating: returns (probs (T,k), idx (T,k), aux-loss scalar)."""
    logits = jnp.einsum("td,de->te", h, p["router"].astype(h.dtype))
    logits = logits.astype(jnp.float32)
    probs, idx = ops.topk_gating(logits, cfg.experts_per_token)
    # GShard load-balance loss: E * Σ_e (fraction_e · mean-prob_e)
    full = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(idx[:, 0], cfg.num_experts, dtype=jnp.float32)
    aux = cfg.num_experts * jnp.mean(
        jnp.mean(onehot, axis=0) * jnp.mean(full, axis=0))
    return probs, idx, aux


def _dispatch_einsum(p, h, cfg, probs, idx):
    """Capacity-based one-hot dispatch (GShard).  h (T, d) → y (T, d)."""
    T, d = h.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    g = min(GROUP, T)
    G = T // g
    C = _capacity(g, k, E, cfg.capacity_factor)
    hg = h.reshape(G, g, d)
    pg = probs.reshape(G, g, k)
    ig = idx.reshape(G, g, k)

    # Rank every (token, slot) within its expert queue without ever
    # materializing a (G,g,k,E,C) one-hot: int8 expert one-hot → int32
    # cumsum → gather own rank → single (E·C)-wide one-hot (sharded over
    # the expert/model axis).
    sel = jax.nn.one_hot(ig, E, dtype=jnp.int8)             # (G,g,k,E)
    selF = constrain(sel.reshape(G, g * k, E),
                     (rules.BATCH, None, rules.EXPERT))
    prio = jnp.cumsum(selF.astype(jnp.int32), axis=1).reshape(G, g, k, E)
    rank = jnp.take_along_axis(prio, ig[..., None].astype(jnp.int32),
                               axis=-1)[..., 0] - 1         # (G,g,k)
    keep = (rank >= 0) & (rank < C)
    comb_idx = jnp.where(keep, ig * C + rank, E * C)        # OOB → zeros
    disp_flat = jax.nn.one_hot(comb_idx, E * C, dtype=h.dtype)
    disp_flat = constrain(disp_flat, (rules.BATCH, None, None, rules.EXPERT))
    dispatch = jnp.sum(disp_flat, axis=2).reshape(G, g, E, C)
    combine = jnp.sum(pg[..., None].astype(h.dtype) * disp_flat,
                      axis=2).reshape(G, g, E, C)
    dispatch = constrain(dispatch, (rules.BATCH, None, rules.EXPERT, None))
    combine = constrain(combine, (rules.BATCH, None, rules.EXPERT, None))

    xin = jnp.einsum("gsec,gsd->gecd", dispatch.astype(h.dtype), hg)
    xin = constrain(xin, (rules.BATCH, rules.EXPERT, None, None))
    gate = jnp.einsum("gecd,edf->gecf", xin, p["wg"].astype(h.dtype))
    up = jnp.einsum("gecd,edf->gecf", xin, p["wu"].astype(h.dtype))
    hidden = jax.nn.silu(gate) * up
    hidden = constrain(hidden, (rules.BATCH, rules.EXPERT, None, None))
    out = jnp.einsum("gecf,efd->gecd", hidden, p["wd"].astype(h.dtype))
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(h.dtype), out)
    return y.reshape(T, d)


def _dispatch_sort(p, h, cfg, probs, idx):
    """Sort-based dispatch: argsort tokens by expert, gather → dense
    per-expert batches → scatter-add back.  No one-hot matmul FLOPs."""
    T, d = h.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = _capacity(T, k, E, cfg.capacity_factor)

    flat_e = idx.reshape(-1)                                 # (T*k,)
    flat_p = probs.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    p_sorted = flat_p[order]
    # rank of each entry within its expert run
    same = jnp.cumsum(jnp.ones_like(e_sorted))
    run_start = jnp.where(
        jnp.concatenate([jnp.array([True]), e_sorted[1:] != e_sorted[:-1]]),
        same - 1, 0)
    run_start = jax.lax.associative_scan(jnp.maximum, run_start)
    rank = (same - 1) - run_start
    keep = rank < C
    dest = e_sorted * C + rank.astype(jnp.int32)             # (T*k,) in [0,E*C)
    dest = jnp.where(keep, dest, E * C)                      # overflow bin

    xin = jnp.zeros((E * C + 1, d), h.dtype).at[dest].set(h[t_sorted])
    xin = xin[:-1].reshape(E, C, d)
    xin = constrain(xin, (rules.EXPERT, None, None))
    gate = jnp.einsum("ecd,edf->ecf", xin, p["wg"].astype(h.dtype))
    up = jnp.einsum("ecd,edf->ecf", xin, p["wu"].astype(h.dtype))
    hidden = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", hidden, p["wd"].astype(h.dtype))
    out = constrain(out, (rules.EXPERT, None, None))

    gathered = out.reshape(E * C, d)
    contrib = jnp.where(keep, p_sorted, 0.0)[:, None].astype(h.dtype)
    picked = jnp.take(gathered, jnp.minimum(dest, E * C - 1), axis=0)
    y = jnp.zeros((T, d), h.dtype).at[t_sorted].add(picked * contrib)
    return y


def moe_apply(p: Dict[str, Any], x: jax.Array, cfg,
              ) -> Tuple[jax.Array, jax.Array]:
    """Pre-norm MoE block (residual included).  Returns (y, aux_loss)."""
    B, S, d = x.shape
    h = apply_norm(p["norm"], x, cfg).reshape(B * S, d)
    probs, idx, aux = _router(p, h, cfg)
    if cfg.moe_impl == "sort":
        y = _dispatch_sort(p, h, cfg, probs, idx)
    else:
        y = _dispatch_einsum(p, h, cfg, probs, idx)
    y = y.reshape(B, S, d)
    if cfg.moe_shared_expert:
        # shared expert runs densely on every token (Llama-4 style);
        # mlp_apply adds its own residual, so feed x and take the delta.
        y = y + (mlp_apply(p["shared"], x, cfg) - x)
    return x + constrain(y, (rules.BATCH, rules.SEQ, None)), aux
