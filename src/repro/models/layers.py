"""Shared transformer layers: norms, RoPE, GQA attention (full / sliding-
window / decode-with-ring-buffer), SwiGLU MLP.

Attention dispatches between three execution paths:
  * the Pallas flash kernel (TPU target; ``kernels/flash_attention.py``),
  * a chunked-online-softmax XLA path for long sequences on CPU/compile
    (memory O(S·chunk) instead of O(S²)),
  * the plain reference einsum for short sequences.

All functions are pure; parameters arrive as dicts built from the
templates in :mod:`repro.models.model`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.ref import NEG_INF
from ..sharding import rules
from ..sharding.rules import constrain
from .params import ParamMeta

# Chunked attention kicks in above this query length (keeps the S×S score
# matrix out of the compiled memory footprint).
CHUNKED_ATTN_THRESHOLD = 2048
ATTN_CHUNK = 512


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def norm_template(cfg) -> Dict[str, ParamMeta]:
    t = {"scale": ParamMeta((cfg.d_model,), (None,), "ones")}
    if cfg.norm == "ln":
        t["bias"] = ParamMeta((cfg.d_model,), (None,), "zeros")
    return t


def apply_norm(p: Dict[str, jax.Array], x: jax.Array, cfg) -> jax.Array:
    if cfg.norm == "ln":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE (with partial-dim "2d" variant: rotary over a fraction of head_dim)
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float,
         fraction: float) -> jax.Array:
    """x (..., S, H, D); positions (..., S) int32 absolute positions."""
    D = x.shape[-1]
    rot = int(D * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq     # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:rot]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.concatenate([xr1, xr2, x[..., rot:]], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_template(cfg, d_in: Optional[int] = None) -> Dict[str, Any]:
    d = d_in if d_in is not None else cfg.d_model
    hq, hkv = rules.padded_heads(cfg.num_heads, cfg.num_kv_heads)
    hd = cfg.head_dim_
    kv_ax = rules.TENSOR if hkv % rules.MODEL_AXIS_SIZE == 0 else None
    return {
        "norm": norm_template(cfg),
        "wq": ParamMeta((d, hq, hd), (rules.FSDP, rules.TENSOR, None)),
        "wk": ParamMeta((d, hkv, hd), (rules.FSDP, kv_ax, None)),
        "wv": ParamMeta((d, hkv, hd), (rules.FSDP, kv_ax, None)),
        "wo": ParamMeta((hq, hd, cfg.d_model), (rules.TENSOR, None, rules.FSDP)),
    }


def _attn_mask(Sq, Skv, q_offset, causal, window):
    q_ids = jnp.arange(Sq)[:, None] + q_offset
    k_ids = jnp.arange(Skv)[None, :]
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m &= q_ids >= k_ids
    if window is not None:
        m &= (q_ids - k_ids) < window
    return m


def _use_flat_heads(Hq: int, Hkv: int) -> bool:
    """Flat-head (repeated-KV) attention when KV heads can't shard over
    the model axis but query heads can: the grouped (K, G) layout would
    otherwise make GSPMD partition the score contraction over head_dim
    (grp-8 all-reduces inside the chunk loop — found in the llama4 §Perf
    iteration).  The KV repeat is collective-free (each chip slices its
    own q-heads' copy) and small (Hkv ≤ 8 here by construction)."""
    m = rules.MODEL_AXIS_SIZE
    return Hkv % m != 0 and Hq % m == 0


def _sdpa(q, k, v, *, causal: bool, window: Optional[int],
          q_offset) -> jax.Array:
    """GQA attention, f32 math, returns q.dtype.  Two layouts:
    grouped (no KV materialization) when KV heads shard; flat repeated-KV
    (q-head-sharded scores) otherwise.  ``q_offset``: absolute position
    of q[0] (int or traced scalar)."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    m = _attn_mask(Sq, Skv, q_offset, causal, window)
    if _use_flat_heads(H, Hkv):
        qf = q.astype(jnp.float32) * D ** -0.5
        kf = jnp.repeat(k, G, axis=2).astype(jnp.float32)
        vf = jnp.repeat(v, G, axis=2).astype(jnp.float32)
        kf = constrain(kf, (rules.BATCH, None, rules.TENSOR, None))
        vf = constrain(vf, (rules.BATCH, None, rules.TENSOR, None))
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
        s = jnp.where(m[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
        return o.astype(q.dtype)
    qg = (q.astype(jnp.float32) * D ** -0.5).reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


_sdpa_grouped = lambda q, k, v, *, causal, window, q_offset: _sdpa(
    q, k, v, causal=causal, window=window, q_offset=q_offset)


def _chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                       chunk: int = ATTN_CHUNK) -> jax.Array:
    """Online attention scanned over query chunks (XLA flash analogue).

    Memory O(chunk·Skv) per step instead of O(Sq·Skv); the Pallas kernel
    is the TPU equivalent with explicit VMEM tiles."""
    B, Sq, H, D = q.shape
    _, Skv, _, _ = k.shape
    nq = -(-Sq // chunk)
    pad = nq * chunk - Sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qs = jnp.moveaxis(qp.reshape(B, nq, chunk, H, D), 1, 0)
    off = Skv - Sq

    def step(_, args):
        i, qc = args
        o = _sdpa(qc, k, v, causal=causal, window=window,
                  q_offset=i * chunk + off)
        return None, o

    _, outs = jax.lax.scan(step, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * chunk, H, D)
    return out[:, :Sq]


def attend(q, k, v, *, causal: bool, window: Optional[int]) -> jax.Array:
    if q.shape[1] > CHUNKED_ATTN_THRESHOLD:
        return _chunked_attention(q, k, v, causal=causal, window=window)
    if jax.default_backend() == "tpu":
        return ops.attention(q, k, v, causal=causal, window=window)
    return _sdpa(q, k, v, causal=causal, window=window,
                 q_offset=k.shape[1] - q.shape[1])


def _decode_attend(q, ck, cv, kpos, pos, window: Optional[int]) -> jax.Array:
    """Single-token attention against a (ring-buffer) cache.

    q (B,1,H,D); ck/cv (B,Sc,Hkv,D); kpos (Sc,) absolute position of each
    cache slot (−1 = empty); pos () current absolute position."""
    B, _, H, D = q.shape
    _, Sc, Hkv, _ = ck.shape
    G = H // Hkv
    qg = (q.astype(jnp.float32) * D ** -0.5).reshape(B, 1, Hkv, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck.astype(jnp.float32))
    valid = (kpos >= 0) & (kpos <= pos)
    if window is not None:
        valid &= (pos - kpos) < window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jnp.exp(s - jax.scipy.special.logsumexp(s, axis=-1, keepdims=True))
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, cv.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


def attention_apply(p: Dict[str, Any], x: jax.Array, cfg, *,
                    positions: jax.Array,
                    cache: Optional[Dict[str, jax.Array]] = None,
                    kpos: Optional[jax.Array] = None,
                    slot: Optional[jax.Array] = None,
                    causal: bool = True,
                    window: Optional[int] = None,
                    ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Pre-norm GQA attention block (residual included).

    Train/prefill: ``cache=None`` → full self-attention over ``x``.
    Prefill-with-cache: pass a zeroed cache dict → it is filled and
    returned.  Decode: ``x`` is (B,1,d); ``cache`` holds keys/values,
    ``kpos`` their absolute positions, ``slot`` the ring-buffer index to
    write; returns the updated cache.
    """
    h = apply_norm(p["norm"], x, cfg)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(h.dtype))
    q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    q = constrain(q, (rules.BATCH, None, rules.TENSOR, None))

    new_cache = None
    if cache is None:
        out = attend(q, k, v, causal=causal, window=window)
    elif x.shape[1] == 1:                                   # decode step
        ck = jax.lax.dynamic_update_index_in_dim(cache["k"], k[:, 0], slot, 1)
        cv = jax.lax.dynamic_update_index_in_dim(cache["v"], v[:, 0], slot, 1)
        out = _decode_attend(q, ck, cv, kpos, positions[0], window)
        new_cache = {"k": ck, "v": cv}
    else:                                                   # prefill, fill cache
        out = attend(q, k, v, causal=causal, window=window)
        Sc = cache["k"].shape[1]
        S = k.shape[1]
        if Sc >= S:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)
        else:
            # ring buffer keeps the tail, rolled so slot j holds the key
            # of absolute position p ≡ j (mod Sc) — the same invariant
            # decode writes with (slot = pos % Sc).
            shift = (S - Sc) % Sc
            ck = jnp.roll(k[:, S - Sc:], shift, axis=1)
            cv = jnp.roll(v[:, S - Sc:], shift, axis=1)
        new_cache = {"k": ck, "v": cv}
    out = constrain(out, (rules.BATCH, None, rules.TENSOR, None))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    y = constrain(y, (rules.BATCH, rules.SEQ, None))
    return x + y, new_cache


def attention_cache_template(cfg, batch: int, cache_len: int, dtype):
    hq, hkv = rules.padded_heads(cfg.num_heads, cfg.num_kv_heads)
    hd = cfg.head_dim_
    kv_ax = rules.TENSOR if hkv % rules.MODEL_AXIS_SIZE == 0 else None
    seq_ax = rules.CACHE_SEQ if kv_ax is None else None
    batch_ax = rules.BATCH
    return {
        "k": ParamMeta((batch, cache_len, hkv, hd),
                       (batch_ax, seq_ax, kv_ax, None), "zeros"),
        "v": ParamMeta((batch, cache_len, hkv, hd),
                       (batch_ax, seq_ax, kv_ax, None), "zeros"),
    }


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_template(cfg, d_ff: Optional[int] = None) -> Dict[str, Any]:
    f = d_ff if d_ff is not None else cfg.d_ff
    d = cfg.d_model
    return {
        "norm": norm_template(cfg),
        "wg": ParamMeta((d, f), (rules.FSDP, rules.TENSOR)),
        "wu": ParamMeta((d, f), (rules.FSDP, rules.TENSOR)),
        "wd": ParamMeta((f, d), (rules.TENSOR, rules.FSDP)),
    }


def mlp_apply(p: Dict[str, Any], x: jax.Array, cfg) -> jax.Array:
    h = apply_norm(p["norm"], x, cfg)
    g = jnp.einsum("bsd,df->bsf", h, p["wg"].astype(h.dtype))
    u = jnp.einsum("bsd,df->bsf", h, p["wu"].astype(h.dtype))
    g = constrain(g, (rules.BATCH, None, rules.TENSOR))
    y = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                   p["wd"].astype(h.dtype))
    y = constrain(y, (rules.BATCH, rules.SEQ, None))
    return x + y
