"""xLSTM blocks (sLSTM + mLSTM) per arXiv:2405.04517 (simplified but
faithful recurrences; exponential gating with stabilizer state).

* mLSTM — matrix memory C ∈ R^{H×hd×hd} updated with outer products
  k vᵀ, queried with q; parallel over heads; ``proj_factor`` up-projection
  wraps the cell (the xlstm-125m config has d_ff=0 because the FFN lives
  here).
* sLSTM — scalar memory per (head, dim) with recurrent input from the
  previous hidden state.

Both run as a ``lax.scan`` over the sequence for train/prefill and expose
an O(1)-state single step for decode — xLSTM is sub-quadratic by
construction, so ``long_500k`` runs the recurrent state, no KV cache.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import rules
from ..sharding.rules import constrain
from .params import ParamMeta
from .layers import apply_norm, norm_template
from .scan_utils import chunked_scan


def _dims(cfg):
    d_inner = int(cfg.xlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    hd = d_inner // H
    return d_inner, H, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_template(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    d_inner, H, hd = _dims(cfg)
    return {
        "norm": norm_template(cfg),
        "wup": ParamMeta((d, d_inner), (rules.FSDP, rules.TENSOR)),
        "wgate": ParamMeta((d, d_inner), (rules.FSDP, rules.TENSOR)),
        "wq": ParamMeta((d_inner, d_inner), (rules.FSDP, rules.TENSOR)),
        "wk": ParamMeta((d_inner, d_inner), (rules.FSDP, rules.TENSOR)),
        "wv": ParamMeta((d_inner, d_inner), (rules.FSDP, rules.TENSOR)),
        "wif": ParamMeta((d_inner, 2 * H), (rules.FSDP, None),
                         scale=1e-2),
        "if_bias": ParamMeta((2 * H,), (None,), "zeros"),
        "onorm": ParamMeta((d_inner,), (rules.TENSOR,), "ones"),
        "wdown": ParamMeta((d_inner, d), (rules.TENSOR, rules.FSDP)),
    }


def _mlstm_cell(q, k, v, i_gate, f_gate, state):
    """One recurrent step.  q,k,v (B,H,hd); gates (B,H) pre-activation.
    state = (C (B,H,hd,hd), n (B,H,hd), m (B,H))."""
    C, n, m = state
    logf = -jax.nn.softplus(-f_gate)                      # log σ(f)
    m_new = jnp.maximum(logf + m, i_gate)
    fa = jnp.exp(logf + m - m_new)
    ia = jnp.exp(i_gate - m_new)
    C = fa[..., None, None] * C + ia[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = fa[..., None] * n + ia[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q)
    # xLSTM eq. (21): max(|ñᵀq|, e^{−m}) in stabilized units — this is
    # max(|nᵀq|, 1) in actual units
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return h, (C, n, m_new)


def mlstm_chunkwise(qf, kf, vf, ig, fg, state, chunk: int = 256):
    """Chunkwise-parallel mLSTM (TFLA-style): matmul form of the matrix-
    memory recurrence with exp-gating stabilization, numerically matching
    the sequential cell.  qf/kf/vf (B,S,H,hd) f32; ig/fg (B,S,H) f32
    pre-activations; state = (C (B,H,hd,hd), n (B,H,hd), m (B,H)).

    Per chunk, in stabilized units (actual = tilde · e^m):
        F_t  = Σ_{r≤t} log σ(f_r)       (cumulative log-forget)
        g_r  = i_r − F_r
        m_t  = max(F_t + m_prev, F_t + cummax_r≤t g_r)
        D_tr = exp(F_t − F_r + i_r − m_t) · [r ≤ t]
        h̃_t = (D ∘ qkᵀ) v + e^{F_t + m_prev − m_t} q C_prev
        ñ_t = D k + e^{F_t + m_prev − m_t} n_prev
        h_t  = h̃_t / max(|ñ_tᵀq̂_t|, e^{−m_t})
    Converts O(S) sequential HBM round-trips into S/Lc chunk matmuls
    (§Perf xlstm iteration; ~same trick as Mamba2 SSD)."""
    B, S, H, hd = qf.shape
    Lc = min(chunk, S)
    if S % Lc:
        return None                                     # caller falls back
    nc = S // Lc
    resh = lambda a: a.reshape((B, nc, Lc) + a.shape[2:])
    q_c, k_c, v_c = resh(qf), resh(kf), resh(vf)
    i_c = resh(ig)                                      # (B,nc,Lc,H)
    logf = -jax.nn.softplus(-resh(fg))                  # log σ(f)
    F = jnp.cumsum(logf, axis=2)                        # (B,nc,Lc,H)
    g = i_c - F
    gmax = jax.lax.cummax(g, axis=2)                    # (B,nc,Lc,H)
    F_last = F[:, :, -1]                                # (B,nc,H)

    def outer(carry, xs):
        C, n, m = carry                                 # stabilized units
        qg, kg, vg, ic, Fc, gc, gmx, Flast = xs
        m_new = jnp.maximum(Fc + m[:, None], Fc + gmx)  # (B,Lc,H)
        a = jnp.exp(Fc + m[:, None] - m_new)            # inter scale
        # D matrix (B,H,Lc,Lc)
        Ft = jnp.moveaxis(Fc, -1, 1)                    # (B,H,Lc)
        it = jnp.moveaxis(ic, -1, 1)
        mt = jnp.moveaxis(m_new, -1, 1)
        d = Ft[:, :, :, None] - Ft[:, :, None, :] \
            + it[:, :, None, :] - mt[:, :, :, None]     # (B,H,t,r)
        mask = jnp.tril(jnp.ones((Lc, Lc), bool))
        D = jnp.exp(jnp.where(mask[None, None], d, -1e30))
        qh = jnp.moveaxis(qg, 2, 1)                     # (B,H,Lc,hd)
        kh = jnp.moveaxis(kg, 2, 1)
        vh = jnp.moveaxis(vg, 2, 1)
        s_qk = jnp.einsum("bhtd,bhrd->bhtr", qh, kh)
        intra_h = jnp.einsum("bhtr,bhrd->bhtd", D * s_qk, vh)
        intra_n = jnp.einsum("bhtr,bhrd->bhtd", D, kh)
        ah = jnp.moveaxis(a, -1, 1)[..., None]          # (B,H,Lc,1)
        inter_h = ah * jnp.einsum("bhtd,bhdv->bhtv", qh, C)
        inter_n = ah * n[:, :, None, :]
        num = intra_h + inter_h                         # (B,H,Lc,hd)
        ntot = intra_n + inter_n
        den = jnp.maximum(jnp.abs(jnp.einsum("bhtd,bhtd->bht", ntot, qh)),
                          jnp.exp(-mt))
        h = num / den[..., None]                        # (B,H,Lc,hd)
        # chunk-end state
        m_end = m_new[:, -1]                            # (B,H)
        a_end = jnp.exp(Flast + m - m_end)              # (B,H)
        w = jnp.exp(Flast[:, None, :] - Fc + ic - m_end[:, None, :])
        wh = jnp.moveaxis(w, -1, 1)                     # (B,H,Lc)
        C_new = a_end[..., None, None] * C \
            + jnp.einsum("bhrd,bhrv->bhdv", wh[..., None] * kh, vh)
        n_new = a_end[..., None] * n \
            + jnp.einsum("bhr,bhrd->bhd", wh, kh)
        return (C_new, n_new, m_end), jnp.moveaxis(h, 1, 2)  # (B,Lc,H,hd)

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in
               (q_c, k_c, v_c, i_c, F, g, gmax, F_last))
    inner = jax.checkpoint(outer)
    (C, n, m), hs = jax.lax.scan(inner, state, xs)
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd), (C, n, m)


def mlstm_apply(p: Dict[str, Any], x: jax.Array, cfg, *,
                state: Optional[Tuple] = None, return_state: bool = False,
                ) -> Tuple[jax.Array, Optional[Tuple]]:
    B, S, d = x.shape
    d_inner, H, hd = _dims(cfg)
    hin = apply_norm(p["norm"], x, cfg)
    up = jnp.einsum("bsd,di->bsi", hin, p["wup"].astype(hin.dtype))
    gate = jnp.einsum("bsd,di->bsi", hin, p["wgate"].astype(hin.dtype))
    q = jnp.einsum("bsi,ij->bsj", up, p["wq"].astype(up.dtype))
    k = jnp.einsum("bsi,ij->bsj", up, p["wk"].astype(up.dtype)) * hd ** -0.5
    v = jnp.einsum("bsi,ij->bsj", up, p["wv"].astype(up.dtype))
    gf = jnp.einsum("bsi,ig->bsg", up, p["wif"].astype(up.dtype)
                    ).astype(jnp.float32) + p["if_bias"]
    shape_h = (B, S, H, hd)
    qf = q.reshape(shape_h).astype(jnp.float32)
    kf = k.reshape(shape_h).astype(jnp.float32)
    vf = v.reshape(shape_h).astype(jnp.float32)
    ig, fg = gf[..., :H], gf[..., H:]

    if state is None:
        state = (jnp.zeros((B, H, hd, hd), jnp.float32),
                 jnp.zeros((B, H, hd), jnp.float32),
                 jnp.full((B, H), -jnp.inf, jnp.float32))
    if S == 1:
        h, state = _mlstm_cell(qf[:, 0], kf[:, 0], vf[:, 0],
                               ig[:, 0], fg[:, 0], state)
        hs = h[:, None]
    else:
        ck = mlstm_chunkwise(qf, kf, vf, ig, fg, state)
        if ck is not None:                                 # matmul form
            hs, state = ck
        else:                                              # tiny/ragged S
            def step(carry, x):
                qt, kt, vt, it, ft = x
                h, carry = _mlstm_cell(qt, kt, vt, it, ft, carry)
                return carry, h
            xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qf, kf, vf, ig, fg))
            state, hs = chunked_scan(step, state, xs)
            hs = jnp.moveaxis(hs, 0, 1)                    # (B,S,H,hd)
    hflat = hs.reshape(B, -1, d_inner).astype(x.dtype)
    from .ssm import rms_gnorm
    hflat = rms_gnorm(hflat, p["onorm"], cfg.norm_eps)
    out = hflat * jax.nn.silu(gate)
    y = jnp.einsum("bsi,id->bsd", out, p["wdown"].astype(out.dtype))
    y = constrain(y, (rules.BATCH, rules.SEQ, None))
    return x + y, (state if return_state else None)


def mlstm_state_template(cfg, batch: int) -> Dict[str, ParamMeta]:
    _, H, hd = _dims(cfg)
    return {
        "C": ParamMeta((batch, H, hd, hd), (rules.BATCH, None, None, None),
                       "zeros"),
        "n": ParamMeta((batch, H, hd), (rules.BATCH, None, None), "zeros"),
        "m": ParamMeta((batch, H), (rules.BATCH, None), "zeros"),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_template(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "norm": norm_template(cfg),
        "wx": ParamMeta((d, 4 * d), (rules.FSDP, rules.TENSOR)),
        "wr": ParamMeta((d, 4 * d), (rules.FSDP, rules.TENSOR), scale=1e-2),
        "bias": ParamMeta((4 * d,), (None,), "zeros"),
        "wdown": ParamMeta((d, d), (rules.TENSOR, rules.FSDP)),
    }


def _slstm_cell(gx, wr, bias, state, d):
    """gx (B,4d) input contribution; state = (c, n, m, h) each (B,d)."""
    c, n, m, h = state
    g = gx + h @ wr + bias                                 # (B,4d)
    zi, ii, fi, oi = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    logf = -jax.nn.softplus(-fi)
    m_new = jnp.maximum(logf + m, ii)
    fa = jnp.exp(logf + m - m_new)
    ia = jnp.exp(ii - m_new)
    c = fa * c + ia * z
    n = fa * n + ia
    h_new = o * c / jnp.maximum(n, 1.0)
    return h_new, (c, n, m_new, h_new)


def slstm_apply(p: Dict[str, Any], x: jax.Array, cfg, *,
                state: Optional[Tuple] = None, return_state: bool = False,
                ) -> Tuple[jax.Array, Optional[Tuple]]:
    B, S, d = x.shape
    hin = apply_norm(p["norm"], x, cfg)
    gx = jnp.einsum("bsd,dg->bsg", hin, p["wx"].astype(hin.dtype)
                    ).astype(jnp.float32)
    wr = p["wr"].astype(jnp.float32)
    bias = p["bias"].astype(jnp.float32)
    if state is None:
        state = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(3)) \
            + (jnp.zeros((B, d), jnp.float32),)
        state = (state[0], state[1],
                 jnp.full((B, d), -jnp.inf, jnp.float32), state[3])
    if S == 1:
        h, state = _slstm_cell(gx[:, 0], wr, bias, state, d)
        hs = h[:, None]
    else:
        def step(carry, gt):
            h, carry = _slstm_cell(gt, wr, bias, carry, d)
            return carry, h
        state, hs = chunked_scan(step, state, jnp.moveaxis(gx, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1)
    y = jnp.einsum("bsd,de->bse", hs.astype(x.dtype),
                   p["wdown"].astype(x.dtype))
    y = constrain(y, (rules.BATCH, rules.SEQ, None))
    return x + y, (state if return_state else None)


def slstm_state_template(cfg, batch: int) -> Dict[str, ParamMeta]:
    d = cfg.d_model
    return {k: ParamMeta((batch, d), (rules.BATCH, None), "zeros")
            for k in ("c", "n", "m", "h")}
