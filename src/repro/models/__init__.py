from .model import (  # noqa: F401
    init_params, forward, prefill, decode_step, encode_step,
    param_template, param_specs, abstract_params,
    init_cache, abstract_cache, cache_spec_tree,
)
