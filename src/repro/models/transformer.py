"""Composable decoder/encoder stack for all six assigned families.

Layers are organized into *groups* that repeat down the stack; the stack
is a ``lax.scan`` over stacked group parameters (fast compiles at 48–54
layers, clean stacked sharding specs).  Group contents per family:

  dense / vlm / audio : [attn, mlp]                       × num_layers
  moe (moe_every=g)   : [attn, mlp] × (g−1) + [attn, moe] × (layers / g)
  hybrid (attn_every=g): [mamba] × g + shared-attn(+mlp)  × (layers / g)
                         — the attention block params are SHARED (one set,
                         applied every g layers; Zamba2 style)
  ssm (xlstm)         : unrolled per-layer (12 layers; sLSTM at
                        ``slstm_layers`` indices, mLSTM elsewhere)

Caches/states mirror the group structure and are threaded through the
same scan (xs → updated ys), so decode is a single fused program.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..sharding import rules
from ..sharding.rules import constrain as rules_constrain
from . import params as P
from .layers import (attention_template, attention_apply,
                     attention_cache_template, mlp_template, mlp_apply,
                     norm_template)
from .moe import moe_template, moe_apply
from .ssm import ssm_template, ssm_apply, ssm_state_template
from .xlstm import (mlstm_template, mlstm_apply, mlstm_state_template,
                    slstm_template, slstm_apply, slstm_state_template)

ParamMeta = P.ParamMeta


# ---------------------------------------------------------------------------
# Stack layout
# ---------------------------------------------------------------------------

def group_layout(cfg) -> Tuple[int, List[Tuple[str, str]]]:
    """Returns (num_scan_steps, [(sub_name, kind), ...]) for scanned
    families; xlstm is unrolled and handled separately."""
    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        return cfg.num_layers, [("attn0", "attn"), ("ffn0", "mlp")]
    if fam == "moe":
        g = max(1, cfg.moe_every)
        subs = []
        for i in range(g):
            subs.append((f"attn{i}", "attn"))
            subs.append((f"ffn{i}", "moe" if i == g - 1 else "mlp"))
        return cfg.num_layers // g, subs
    if fam == "hybrid":
        g = max(1, cfg.attn_every)
        return cfg.num_layers // g, [(f"mamba{i}", "mamba") for i in range(g)]
    if fam == "ssm":
        raise ValueError("xlstm stack is unrolled; no group layout")
    raise ValueError(fam)


_SUB_TEMPLATE = {
    "attn": attention_template,
    "mlp": mlp_template,
    "moe": moe_template,
    "mamba": ssm_template,
    "mlstm": mlstm_template,
    "slstm": slstm_template,
}


def _xlstm_kinds(cfg) -> List[str]:
    return ["slstm" if i in cfg.slstm_layers else "mlstm"
            for i in range(cfg.num_layers)]


def stack_template(cfg) -> Dict[str, Any]:
    """Template for the full parameter tree."""
    d = cfg.d_model
    vp = rules.padded_vocab(cfg.vocab_size)
    t: Dict[str, Any] = {}
    if cfg.frontend != "audio":
        t["tok_embed"] = ParamMeta((vp, d), (rules.VOCAB, rules.FSDP),
                                   scale=0.02)
    if cfg.family == "ssm":                                  # xlstm: unrolled
        layers = {}
        for i, kind in enumerate(_xlstm_kinds(cfg)):
            layers[f"layer_{i:02d}"] = _SUB_TEMPLATE[kind](cfg)
        t["layers"] = layers
    else:
        steps, subs = group_layout(cfg)
        group = {name: _SUB_TEMPLATE[kind](cfg) for name, kind in subs}
        t["layers"] = P.stack(group, steps)
        if cfg.family == "hybrid":                           # shared block
            t["shared_attn"] = attention_template(cfg)
            t["shared_mlp"] = mlp_template(cfg)
    t["final_norm"] = norm_template(cfg)
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamMeta((d, vp), (rules.FSDP, rules.VOCAB))
    return t


# ---------------------------------------------------------------------------
# Cache / recurrent-state templates
# ---------------------------------------------------------------------------

def cache_template(cfg, batch: int, cache_len: int, dtype) -> Dict[str, Any]:
    """Abstract layout of the decode cache (mirrors the layer groups)."""
    t: Dict[str, Any] = {}
    if cfg.family == "ssm":
        layers = {}
        for i, kind in enumerate(_xlstm_kinds(cfg)):
            layers[f"layer_{i:02d}"] = (mlstm_state_template(cfg, batch)
                                        if kind == "mlstm"
                                        else slstm_state_template(cfg, batch))
        t["layers"] = layers
        return t
    steps, subs = group_layout(cfg)
    group: Dict[str, Any] = {}
    for name, kind in subs:
        if kind == "attn":
            group[name] = attention_cache_template(cfg, batch, cache_len,
                                                   dtype)
        elif kind == "mamba":
            group[name] = ssm_state_template(cfg, batch, dtype)
    t["layers"] = P.stack(group, steps)
    if cfg.family == "hybrid":
        t["shared_attn"] = P.stack(
            attention_cache_template(cfg, batch, cache_len, dtype), steps)
    if _has_attention(cfg):
        t["kpos"] = ParamMeta((cache_len,), (None,), "zeros")  # int32 − 1
    return t


def _has_attention(cfg) -> bool:
    return cfg.family != "ssm"


# ---------------------------------------------------------------------------
# Sub-layer application
# ---------------------------------------------------------------------------

def _apply_sub(kind: str, p, x, cfg, ctx) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x, new_cache_or_None, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if kind == "attn":
        x, new_cache = attention_apply(
            p, x, cfg, positions=ctx["positions"], cache=ctx.get("cache"),
            kpos=ctx.get("kpos"), slot=ctx.get("slot"),
            causal=cfg.causal, window=ctx["window"])
        return x, new_cache, zero
    if kind == "mlp":
        return mlp_apply(p, x, cfg), None, zero
    if kind == "moe":
        x, aux = moe_apply(p, x, cfg)
        return x, None, aux
    if kind == "mamba":
        x, new_state = ssm_apply(p, x, cfg, state=ctx.get("cache"))
        return x, new_state, zero
    if kind == "mlstm":
        st = ctx.get("cache")
        st_t = None if st is None else (st["C"], st["n"], st["m"])
        x, new = mlstm_apply(p, x, cfg, state=st_t, return_state=True)
        new_d = None if new is None else {"C": new[0], "n": new[1],
                                          "m": new[2]}
        return x, new_d, zero
    if kind == "slstm":
        st = ctx.get("cache")
        st_t = None if st is None else (st["c"], st["n"], st["m"], st["h"])
        x, new = slstm_apply(p, x, cfg, state=st_t, return_state=True)
        new_d = None if new is None else dict(zip("cnmh", new))
        return x, new_d, zero
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stack application (shared by train forward / prefill / decode)
# ---------------------------------------------------------------------------

def apply_stack(cfg, prm, x, *, positions, cache=None, kpos=None, slot=None,
                window=None, train=False):
    """Runs the layer stack.  Returns (x, new_cache_tree, aux_loss)."""
    base_ctx = {"positions": positions, "kpos": kpos, "slot": slot,
                "window": window}

    if cfg.family == "ssm":                                  # unrolled xlstm
        aux = jnp.zeros((), jnp.float32)
        new_layers = {}
        for i, kind in enumerate(_xlstm_kinds(cfg)):
            name = f"layer_{i:02d}"
            ctx = dict(base_ctx)
            ctx["cache"] = None if cache is None else cache["layers"][name]
            fn = _apply_sub
            if train:
                fn = jax.checkpoint(
                    _apply_sub, static_argnums=(0, 3),
                    policy=jax.checkpoint_policies.nothing_saveable)
            x, new_c, a = fn(kind, prm["layers"][name], x, cfg, ctx)
            aux += a
            if new_c is not None:
                new_layers[name] = new_c
        new_cache = {"layers": new_layers} if cache is not None else None
        return x, new_cache, aux

    steps, subs = group_layout(cfg)
    decode_or_prefill = cache is not None

    def body(carry, xs):
        x, aux = carry
        # Sequence-shard the inter-layer activation (it is what the scan
        # saves for backward): (batch@data, seq@model, d).  Dropped
        # automatically when seq doesn't divide (decode S=1).
        x = rules_constrain(x, (rules.BATCH, rules.SEQ, None))
        layer_p, layer_cache = xs
        new_cache_slices = {}
        for name, kind in subs:
            ctx = dict(base_ctx)
            ctx["cache"] = None if layer_cache is None \
                else layer_cache.get(name)
            x, new_c, a = _apply_sub(kind, layer_p[name], x, cfg, ctx)
            aux += a
            if kind in ("attn", "mamba"):
                new_cache_slices[name] = new_c if new_c is not None else 0
        if cfg.family == "hybrid":
            ctx = dict(base_ctx)
            ctx["cache"] = None if layer_cache is None \
                else layer_cache.get("__shared_attn")
            x, new_c, _ = _apply_sub("attn", shared_p, x, cfg, ctx)
            if new_c is not None:
                new_cache_slices["__shared_attn"] = new_c
            x = mlp_apply(shared_mlp_p, x, cfg)
        return (x, aux), (new_cache_slices if decode_or_prefill else 0)

    shared_p = prm.get("shared_attn")
    shared_mlp_p = prm.get("shared_mlp")

    layer_xs = prm["layers"]
    if decode_or_prefill:
        lc = dict(cache["layers"])
        if cfg.family == "hybrid":
            lc["__shared_attn"] = cache["shared_attn"]
        cache_xs = lc
    else:
        cache_xs = None

    fn = body
    if train:
        fn = jax.checkpoint(body,
                            policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), ys = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                (layer_xs, cache_xs))
    new_cache = None
    if decode_or_prefill:
        ys = dict(ys)
        shared = ys.pop("__shared_attn", None)
        new_cache = {"layers": ys}
        if shared is not None:
            new_cache["shared_attn"] = shared
    return x, new_cache, aux
