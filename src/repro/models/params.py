"""Parameter-template machinery: one source of truth for initialization,
sharding specs and abstract (ShapeDtypeStruct) trees.

A model is described as a nested dict of :class:`ParamMeta` leaves; the
same template then produces
  * ``init(template, rng, dtype)``      — materialized params,
  * ``specs(template, mesh)``           — NamedSharding tree for pjit,
  * ``abstract(template, mesh, dtype)`` — ShapeDtypeStructs for .lower().

Logical sharding axes come from :mod:`repro.sharding.rules`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..sharding import rules


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis per dim
    init: str = "normal"                     # normal|zeros|ones|ssm_a|ssm_dt
    scale: Optional[float] = None            # stddev; default fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Template = Dict[str, Any]                    # nested dict of ParamMeta


def _leaf_init(meta: ParamMeta, rng: jax.Array, dtype) -> jax.Array:
    if meta.init == "zeros":
        return jnp.zeros(meta.shape, dtype)
    if meta.init == "ones":
        return jnp.ones(meta.shape, dtype)
    if meta.init == "ssm_a":                 # A_log: log of Uniform[1, 16]
        u = jax.random.uniform(rng, meta.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(jnp.float32)          # keep A in f32
    if meta.init == "ssm_dt":                # dt_bias: softplus^-1(U[1e-3, .1])
        u = jax.random.uniform(rng, meta.shape, jnp.float32, 1e-3, 0.1)
        return jnp.log(jnp.expm1(u)).astype(jnp.float32)
    fan_in = meta.shape[0] if len(meta.shape) > 1 else meta.shape[-1]
    std = meta.scale if meta.scale is not None else fan_in ** -0.5
    return (jax.random.normal(rng, meta.shape, jnp.float32) * std
            ).astype(dtype)


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def init(template: Template, rng: jax.Array, dtype) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(template, is_leaf=is_meta)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [_leaf_init(m, k, dtype) for m, k in zip(leaves, keys)])


def specs(template: Template, mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda m: NamedSharding(mesh, rules.resolve(mesh, m.axes, m.shape)),
        template, is_leaf=is_meta)


def abstract(template: Template, dtype, mesh=None) -> Any:
    def leaf(m: ParamMeta):
        dt = jnp.float32 if m.init in ("ssm_a", "ssm_dt") else dtype
        if mesh is None:
            return jax.ShapeDtypeStruct(m.shape, dt)
        return jax.ShapeDtypeStruct(
            m.shape, dt,
            sharding=NamedSharding(mesh, rules.resolve(mesh, m.axes, m.shape)))
    return jax.tree_util.tree_map(leaf, template, is_leaf=is_meta)


def param_count(template: Template) -> int:
    import math
    leaves, _ = jax.tree_util.tree_flatten(template, is_leaf=is_meta)
    return sum(math.prod(m.shape) for m in leaves)


def stack(template: Template, n: int, axis_name: Optional[str] = None
          ) -> Template:
    """Prepend a length-``n`` layer dim to every leaf (scan-over-layers)."""
    return jax.tree_util.tree_map(
        lambda m: ParamMeta((n,) + m.shape, (axis_name,) + m.axes,
                            m.init, m.scale),
        template, is_leaf=is_meta)
