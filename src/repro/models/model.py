"""Public model API: ``init_params`` / ``forward`` / ``prefill`` /
``decode_step`` / ``encode_step`` plus the template/spec/abstract helpers
the launcher uses for pjit and the multi-pod dry-run.

All functions are pure and take the :class:`repro.configs.base.ModelConfig`
explicitly; parameters are nested dicts built from
:func:`transformer.stack_template`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..sharding import rules
from ..sharding.rules import constrain
from . import params as P
from .transformer import apply_stack, cache_template, stack_template
from .layers import apply_norm


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def param_template(cfg):
    return stack_template(cfg)


def init_params(cfg, rng: jax.Array):
    return P.init(stack_template(cfg), rng, _dtype(cfg))


def param_specs(cfg, mesh):
    return P.specs(stack_template(cfg), mesh)


def abstract_params(cfg, mesh=None):
    return P.abstract(stack_template(cfg), _dtype(cfg), mesh)


def num_params(cfg) -> int:
    return P.param_count(stack_template(cfg))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def _embed(cfg, prm, tokens: jax.Array) -> jax.Array:
    emb = prm["tok_embed"]
    x = jnp.take(emb, tokens, axis=0).astype(_dtype(cfg))
    return x * cfg.d_model ** 0.5 if cfg.scale_embed else x


def _logits(cfg, prm, x: jax.Array) -> jax.Array:
    x = apply_norm(prm["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            prm["tok_embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x,
                            prm["lm_head"].astype(x.dtype))
    return constrain(logits, (rules.BATCH, None, rules.VOCAB))


def _inputs(cfg, prm, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, int]:
    """Token/frontend embeddings.  Returns (x (B,S_total,d), n_frontend)."""
    if cfg.frontend == "audio":
        return batch["frames"].astype(_dtype(cfg)), 0
    x = _embed(cfg, prm, batch["tokens"])
    if cfg.frontend == "vision":
        fe = batch["frontend"].astype(_dtype(cfg))
        return jnp.concatenate([fe, x], axis=1), fe.shape[1]
    return x, 0


# ---------------------------------------------------------------------------
# Forward (train / eval / encode)
# ---------------------------------------------------------------------------

def forward(cfg, prm, batch: Dict[str, jax.Array], *, train: bool = False,
            window: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits (B, S_text, Vp), aux_loss)."""
    x, n_front = _inputs(cfg, prm, batch)
    x = constrain(x, (rules.BATCH, None, None))
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _, aux = apply_stack(cfg, prm, x, positions=positions,
                            window=window if window is not None
                            else cfg.window,
                            train=train)
    logits = _logits(cfg, prm, x)
    if n_front:
        logits = logits[:, n_front:]
    return logits, aux


def encode_step(cfg, prm, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, jax.Array]:
    """Encoder-only forward (hubert): bidirectional, no cache."""
    return forward(cfg, prm, batch, train=False)


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def _leaf_name(path) -> str:
    return str(getattr(path[-1], "key", path[-1]))


def _cache_leaf_dtype(cfg, path):
    name = _leaf_name(path)
    if name == "kpos":
        return jnp.int32
    if name in ("k", "v"):
        return _dtype(cfg)
    return jnp.float32                       # recurrent states ride in f32


def init_cache(cfg, batch: int, cache_len: int):
    t = cache_template(cfg, batch, cache_len, _dtype(cfg))
    def leaf(path, m):
        dt = _cache_leaf_dtype(cfg, path)
        if _leaf_name(path) == "kpos":
            return jnp.full(m.shape, -1, dt)
        if _leaf_name(path) == "m":          # exp-gating stabilizer floor
            return jnp.full(m.shape, -1e30, dt)
        return jnp.zeros(m.shape, dt)
    return jax.tree_util.tree_map_with_path(leaf, t, is_leaf=P.is_meta)


def abstract_cache(cfg, batch: int, cache_len: int, mesh=None):
    t = cache_template(cfg, batch, cache_len, _dtype(cfg))
    def leaf(path, m):
        dt = _cache_leaf_dtype(cfg, path)
        if mesh is None:
            return jax.ShapeDtypeStruct(m.shape, dt)
        return jax.ShapeDtypeStruct(
            m.shape, dt,
            sharding=NamedSharding(mesh, rules.resolve(mesh, m.axes, m.shape)))
    return jax.tree_util.tree_map_with_path(leaf, t, is_leaf=P.is_meta)


def cache_spec_tree(cfg, batch: int, cache_len: int, mesh):
    t = cache_template(cfg, batch, cache_len, _dtype(cfg))
    return jax.tree_util.tree_map(
        lambda m: NamedSharding(mesh, rules.resolve(mesh, m.axes, m.shape)),
        t, is_leaf=P.is_meta)


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

def prefill(cfg, prm, batch: Dict[str, jax.Array], *, cache_len: int,
            window: Optional[int] = None
            ) -> Tuple[jax.Array, Any]:
    """Process a prompt, build the decode cache.  Returns
    (last-token logits (B, Vp), cache)."""
    assert not cfg.encoder_only, "encoder-only archs have no decode path"
    x, n_front = _inputs(cfg, prm, batch)
    x = constrain(x, (rules.BATCH, None, None))
    B, S = x.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)
    cache = init_cache(cfg, B, cache_len)
    kpos = cache.pop("kpos", None)
    x, new_cache, _ = apply_stack(cfg, prm, x, positions=positions,
                                  cache=cache,
                                  window=window if window is not None
                                  else cfg.window)
    if kpos is not None:
        sc = kpos.shape[0]
        if sc >= S:
            kpos = jnp.where(jnp.arange(sc) < S, jnp.arange(sc), -1
                             ).astype(jnp.int32)
        else:                                # ring holds the tail, rolled
            kpos = jnp.roll(jnp.arange(S - sc, S, dtype=jnp.int32),
                            (S - sc) % sc)
        new_cache["kpos"] = kpos
    logits = _logits(cfg, prm, x[:, -1:])[:, 0]
    return logits, new_cache


def decode_step(cfg, prm, cache, token: jax.Array, pos: jax.Array, *,
                window: Optional[int] = None
                ) -> Tuple[jax.Array, Any]:
    """One autoregressive step.  token (B,) int32; pos () int32 absolute
    position of this token.  Returns (logits (B, Vp), updated cache)."""
    assert not cfg.encoder_only, "encoder-only archs have no decode path"
    if cfg.frontend == "audio":
        raise ValueError("audio arch is encoder-only")
    x = _embed(cfg, prm, token[:, None])
    x = constrain(x, (rules.BATCH, None, None))
    kpos = cache.get("kpos")
    slot = None
    cache_in = dict(cache)
    if kpos is not None:
        cache_in.pop("kpos")
        sc = kpos.shape[0]
        slot = pos % sc
        kpos = kpos.at[slot].set(pos)
    positions = jnp.full((1,), pos, jnp.int32)
    x, new_cache, _ = apply_stack(cfg, prm, x, positions=positions,
                                  cache=cache_in, kpos=kpos, slot=slot,
                                  window=window if window is not None
                                  else cfg.window)
    if kpos is not None:
        new_cache["kpos"] = kpos
    logits = _logits(cfg, prm, x)[:, 0]
    return logits, new_cache
