"""Mamba2-style selective-state-space block (SSD), built on the
``kernels/ssm_scan`` Pallas kernel (ref path on CPU).

Block layout (simplified Mamba2, n_groups=1):
    in_proj: d → [z (d_inner), x (d_inner), B (N), C (N), dt (n_heads)]
    depthwise causal conv (width ssm_conv) over [x, B, C]
    selective scan: h_t = exp(dt·A)·h_{t−1} + (dt·x_t)⊗B_t ; y_t = ⟨h_t,C_t⟩
    gate: y · silu(z), RMS-normed, out_proj d_inner → d

Decode keeps O(1) state per token: the scan state (B, d_inner, N) plus a
(width−1) conv window — this is what makes ``long_500k`` sub-quadratic
for the ssm/hybrid architectures.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..sharding import rules
from ..sharding.rules import constrain
from .params import ParamMeta
from .layers import apply_norm, norm_template
from .scan_utils import default_chunk

SSM_HEAD_DIM = 64


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // SSM_HEAD_DIM
    conv_ch = d_inner + 2 * cfg.ssm_state
    return d_inner, n_heads, conv_ch


def ssm_template(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    N = cfg.ssm_state
    d_inner, n_heads, conv_ch = _dims(cfg)
    return {
        "norm": norm_template(cfg),
        "wz": ParamMeta((d, d_inner), (rules.FSDP, rules.TENSOR)),
        "wx": ParamMeta((d, d_inner), (rules.FSDP, rules.TENSOR)),
        "wB": ParamMeta((d, N), (rules.FSDP, None)),
        "wC": ParamMeta((d, N), (rules.FSDP, None)),
        "wdt": ParamMeta((d, n_heads), (rules.FSDP, rules.TENSOR)),
        "dt_bias": ParamMeta((n_heads,), (rules.TENSOR,), "ssm_dt"),
        "A_log": ParamMeta((n_heads,), (rules.TENSOR,), "ssm_a"),
        "conv_w": ParamMeta((cfg.ssm_conv, conv_ch), (None, None),
                            scale=cfg.ssm_conv ** -0.5),
        "conv_b": ParamMeta((conv_ch,), (None,), "zeros"),
        "gnorm": ParamMeta((d_inner,), (rules.TENSOR,), "ones"),
        "wo": ParamMeta((d_inner, d), (rules.TENSOR, rules.FSDP)),
    }


def _proj(p, h, cfg):
    """Shared projections.  h (B,S,d) → z, xc (pre-conv [x,B,C]), dt."""
    z = jnp.einsum("bsd,di->bsi", h, p["wz"].astype(h.dtype))
    x = jnp.einsum("bsd,di->bsi", h, p["wx"].astype(h.dtype))
    Bm = jnp.einsum("bsd,dn->bsn", h, p["wB"].astype(h.dtype))
    Cm = jnp.einsum("bsd,dn->bsn", h, p["wC"].astype(h.dtype))
    dt = jnp.einsum("bsd,dh->bsh", h, p["wdt"].astype(h.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    xc = jnp.concatenate([x, Bm, Cm], axis=-1)
    return z, xc, dt


def _split_conv(xc, cfg, d_inner):
    N = cfg.ssm_state
    return (xc[..., :d_inner], xc[..., d_inner:d_inner + N],
            xc[..., d_inner + N:])


def _causal_conv(xc, w, b, conv_state: Optional[jax.Array]):
    """Depthwise causal conv.  xc (B,S,C); w (W,C).  conv_state (B,W−1,C)
    is the trailing window from the previous segment (zeros at start)."""
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xc.shape[0], W - 1, xc.shape[-1]), xc.dtype)
    else:
        pad = conv_state.astype(xc.dtype)
    full = jnp.concatenate([pad, xc], axis=1)
    out = sum(full[:, i:i + xc.shape[1]] * w[i].astype(xc.dtype)
              for i in range(W))
    out = jax.nn.silu(out + b.astype(xc.dtype))
    new_state = full[:, full.shape[1] - (W - 1):]
    return out, new_state


def _expand_heads(v, n_heads):
    """(..., n_heads) → (..., d_inner) by per-head broadcast."""
    return jnp.repeat(v, SSM_HEAD_DIM, axis=-1)


def ssm_apply(p: Dict[str, Any], x: jax.Array, cfg, *,
              state: Optional[Dict[str, jax.Array]] = None,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Pre-norm Mamba2 block (residual included).

    Train/prefill: ``state=None`` → zero-initialized scan (returns the
    final state so prefill can seed decode).  Decode: ``x`` is (B,1,d);
    pass the carried ``state`` dict {"h": (B,C,N), "conv": (B,W−1,Ch)}.
    """
    d_inner, n_heads, _ = _dims(cfg)
    h_res = x
    hin = apply_norm(p["norm"], x, cfg)
    z, xc, dt = _proj(p, hin, cfg)
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(xc, p["conv_w"], p["conv_b"], conv_state)
    xs, Bm, Cm = _split_conv(xc, cfg, d_inner)
    xs = constrain(xs, (rules.BATCH, None, rules.TENSOR))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (n_heads,) < 0
    ssd = cfg.ssm_impl == "ssd" and not (x.shape[1] == 1
                                         and state is not None)
    if not ssd:
        A_full = _expand_heads(A, n_heads)
        dt_full = _expand_heads(dt, n_heads)

    h0 = None if state is None else state["h"]
    if x.shape[1] == 1 and state is not None:               # decode: 1 step
        a = jnp.exp(dt_full[:, 0] * A_full[None, :])        # (B,C)
        inp = (dt_full[:, 0] * xs[:, 0].astype(jnp.float32))[:, :, None] \
            * Bm[:, 0].astype(jnp.float32)[:, None, :]
        h_new = a[:, :, None] * h0 + inp                    # (B,C,N)
        y = jnp.einsum("bcn,bn->bc", h_new,
                       Cm[:, 0].astype(jnp.float32))[:, None]
        y = y.astype(x.dtype)
    elif ssd:
        y, h_new = ssd_chunked(xs, dt, A, Bm, Cm, h0)
    else:
        y, h_new = _chunked_ssm_scan(xs, dt_full.astype(xs.dtype), A_full,
                                     Bm, Cm, h0)
    y = y * jax.nn.silu(z)
    y = rms_gnorm(y, p["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"].astype(y.dtype))
    out = constrain(out, (rules.BATCH, rules.SEQ, None))
    new_state = {"h": h_new, "conv": new_conv}
    return h_res + out, new_state


def ssd_chunked(xs, dt, A, Bm, Cm, h0, head_dim: int = SSM_HEAD_DIM,
                chunk: int = 128):
    """Mamba2 SSD: the chunked *matmul* form of the diagonal selective
    scan (arXiv:2405.21060 §6).  Replaces S sequential elementwise steps
    with S/Lc chunk matmuls — MXU-friendly and O(S/Lc) HBM round-trips
    instead of O(S) (the jnp analogue of the Pallas kernel's tiling; used
    by the ``ssm_impl="ssd"`` §Perf variant).

    Exploits decay being per-head (A/dt broadcast across each head's
    channels): per chunk, per head,
        y_intra = (mask ∘ exp(L_t − L_r) ∘ (C_t·B_r)) @ u
        y_inter = exp(L_t) · (C_t · h_prev)
        h_next  = exp(L_last − L_r) weighted Σ u_r ⊗ B_r + exp(L_last)·h_prev
    Shapes as in ``ref.ssm_scan_ref``; returns (y (B,S,C), h_final)."""
    B, S, C = xs.shape
    N = Bm.shape[-1]
    H = C // head_dim
    Lc = min(chunk, S)
    f32 = jnp.float32
    # dt/A may arrive per-channel (broadcast) or per-head; normalize to
    # per-head WITHOUT materializing the (B,S,d_inner) expansion (§Perf
    # zamba2 iteration 3 — the channel broadcast was pure HBM waste).
    if dt.shape[-1] == C:
        dt_h = dt.astype(f32).reshape(B, S, H, head_dim)[..., 0]
    else:
        dt_h = dt.astype(f32)                                    # (B,S,H)
    A_h = (A.astype(f32).reshape(H, head_dim)[:, 0]
           if A.shape[-1] == C else A.astype(f32))               # (H,)
    if S % Lc:
        dt_c = jnp.repeat(dt_h, head_dim, axis=-1).astype(xs.dtype)
        A_c = jnp.repeat(A_h, head_dim)
        return _chunked_ssm_scan(xs, dt_c, A_c, Bm, Cm, h0)
    nc = S // Lc
    loga = dt_h * A_h                                            # (B,S,H) <0
    u = (dt_h.astype(f32)[..., None]
         * xs.astype(f32).reshape(B, S, H, head_dim)
         ).reshape(B, nc, Lc, H, head_dim)
    Bc = Bm.astype(f32).reshape(B, nc, Lc, N)
    Cc = Cm.astype(f32).reshape(B, nc, Lc, N)
    la = loga.reshape(B, nc, Lc, H)
    Lcum = jnp.cumsum(la, axis=2)                                # (B,nc,Lc,H)

    # intra-chunk: M[t,r] = exp(Lcum_t − Lcum_r) · (C_t·B_r) · mask(r ≤ t)
    cb = jnp.einsum("bgtn,bgrn->bgtr", Cc, Bc)                   # (B,nc,t,r)
    ldiff = Lcum[:, :, :, None, :] - Lcum[:, :, None, :, :]      # (B,nc,t,r,H)
    mask = jnp.tril(jnp.ones((Lc, Lc), bool))[None, None, :, :, None]
    # mask the EXPONENT before exp: the upper triangle has ldiff > 0 →
    # exp → inf, and where-gradients through inf are NaN
    M = jnp.exp(jnp.where(mask, ldiff, -1e30)) * cb[..., None]   # (B,nc,t,r,H)
    y_intra = jnp.einsum("bgtrh,bgrhd->bgthd", M, u)

    # inter-chunk: sequential (tiny: nc steps) state recurrence
    decay_tail = jnp.exp(Lcum[:, :, -1:, :] - Lcum)              # (B,nc,Lc,H)
    uB = jnp.einsum("bgrhd,bgrn,bgrh->bghdn", u, Bc, decay_tail)
    chunk_decay = jnp.exp(Lcum[:, :, -1, :])                     # (B,nc,H)

    h0f = (jnp.zeros((B, H, head_dim, N), f32) if h0 is None
           else h0.astype(f32).reshape(B, H, head_dim, N))

    def step(h, xsg):
        uBg, dg = xsg                       # (B,H,hd,N), (B,H)
        h_new = dg[..., None, None] * h + uBg
        return h_new, h
    hs_in = (jnp.moveaxis(uB, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    h_last, h_prevs = jax.lax.scan(step, h0f, hs_in)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                        # (B,nc,...)

    y_inter = jnp.einsum("bgtn,bghdn,bgth->bgthd",
                         Cc, h_prevs, jnp.exp(Lcum))
    y = (y_intra + y_inter).reshape(B, S, C).astype(xs.dtype)
    return y, h_last.reshape(B, C, N)


def _chunked_ssm_scan(xs, dt, A, Bm, Cm, h0):
    """ssm_scan with chunk-boundary gradient checkpointing (sqrt-remat over
    the sequence — see scan_utils).  The Pallas kernel does its own VMEM
    chunking on TPU; this wrapper bounds the *autodiff* memory."""
    B, S, C = xs.shape
    if h0 is None:
        h0 = jnp.zeros((B, C, Bm.shape[-1]), jnp.float32)
    k = default_chunk(S)
    if S % k or S <= k:
        return ops.ssm_scan(xs, dt, A, Bm, Cm, h0)
    nc = S // k
    resh = lambda a: jnp.moveaxis(
        a.reshape((B, nc, k) + a.shape[2:]), 1, 0)

    inner = jax.checkpoint(
        lambda h, x: _swap(ops.ssm_scan(x[0], x[1], A, x[2], x[3], h)))

    def outer(h, x):
        return inner(h, x)

    h, ys = jax.lax.scan(outer, h0, (resh(xs), resh(dt), resh(Bm),
                                     resh(Cm)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, C)
    return y, h


def _swap(t):
    return t[1], t[0]


def rms_gnorm(y: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps)).astype(y.dtype) * scale


def ssm_state_template(cfg, batch: int, dtype) -> Dict[str, ParamMeta]:
    d_inner, _, conv_ch = _dims(cfg)
    return {
        "h": ParamMeta((batch, d_inner, cfg.ssm_state),
                       (rules.BATCH, rules.TENSOR, None), "zeros"),
        "conv": ParamMeta((batch, cfg.ssm_conv - 1, conv_ch),
                          (rules.BATCH, None, None), "zeros"),
    }
