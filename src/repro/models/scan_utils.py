"""Sequence-scan utilities: chunked gradient checkpointing.

Backprop through a ``lax.scan`` over S timesteps stores the carry at every
step — for recurrent blocks with matrix state (mLSTM's C, Mamba's h) that
is O(S·state) and explodes the training memory footprint.  ``chunked_scan``
recomputes inside √S-ish chunks so only chunk-boundary carries are saved:
memory drops from O(S) to O(S/K + K) states (classic sqrt-remat).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Tuple

import jax


def default_chunk(S: int) -> int:
    """√S rounded down to a divisor of S (powers of two divide cleanly)."""
    k = max(16, int(math.sqrt(S)))
    while S % k:
        k -= 1
    return max(k, 1)


def chunked_scan(step_fn: Callable, carry: Any, xs: Any,
                 chunk: int = 0) -> Tuple[Any, Any]:
    """``lax.scan(step_fn, carry, xs)`` with chunk-boundary checkpointing.

    ``xs`` leaves have leading dim S.  Falls back to a single
    checkpointed scan when S doesn't split (tiny test sizes)."""
    S = jax.tree_util.tree_leaves(xs)[0].shape[0]
    k = chunk or default_chunk(S)
    if S % k or S <= k:
        return jax.checkpoint(
            lambda c, x: jax.lax.scan(step_fn, c, x))(carry, xs)
    nc = S // k
    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape((nc, k) + a.shape[1:]), xs)

    inner = jax.checkpoint(lambda c, x: jax.lax.scan(step_fn, c, x))

    def outer(c, x):
        return inner(c, x)

    carry, ys_c = jax.lax.scan(outer, carry, xs_c)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((S,) + a.shape[2:]), ys_c)
    return carry, ys
