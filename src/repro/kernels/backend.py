"""Kernel backends: what a resolved :class:`~repro.kernels.spec.KernelSpec`
actually executes.

A backend is a frozen value object exposing the round body's two
compute hot-spots with oracle-identical signatures:

    lasso_partial(Xb, r)  ->  (U,)  f32     z_j = x_jᵀ r    (push, f₃)
    gram_block(Xc)        ->  (U′,U′) f32   G = X_CᵀX_C     (ρ-filter)

``build_kernels(spec)`` is the registry entry point — the kernel-side
twin of ``repro.sched.build_scheduler`` / ``repro.part.
build_partitioner``.  The engine calls it at injection time
(``StradsEngine.set_kernels``) and hands the result to the app via
``use_kernels``; apps call ``self.kernels.lasso_partial(...)`` inside
their traced primitives and never branch on the backend themselves.

Platform resolution happens HERE, not in the spec: ``kind="pallas"``
lowers ``pl.pallas_call`` for Mosaic when the live jax platform is TPU
and automatically flips to interpret mode elsewhere (the CPU CI
container), so one plan file drives both targets and tier-1 stays green
on forced host devices.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax

from . import lasso_cd as _lc
from . import ref
from .spec import _KIND_MSG, KernelSpec


@dataclasses.dataclass(frozen=True)
class ReferenceKernels:
    """The pure-jnp oracle path (``repro.kernels.ref``) — the semantics
    contract and the bit-identical pre-KernelSpec behavior."""

    spec: KernelSpec

    def lasso_partial(self, Xb: jax.Array, r: jax.Array) -> jax.Array:
        return ref.lasso_partial_ref(Xb, r)

    def gram_block(self, Xc: jax.Array) -> jax.Array:
        return ref.gram_ref(Xc)


@dataclasses.dataclass(frozen=True)
class PallasKernels:
    """The fused VMEM-tiled kernels (``repro.kernels.lasso_cd``),
    row-tiled at ``spec.block_n``.  ``interpret=True`` executes the same
    grid program with lax ops — the automatic CPU fallback."""

    spec: KernelSpec
    interpret: bool

    def lasso_partial(self, Xb: jax.Array, r: jax.Array) -> jax.Array:
        return _lc.lasso_partial(Xb, r, block_n=self.spec.block_n,
                                 interpret=self.interpret)

    def gram_block(self, Xc: jax.Array) -> jax.Array:
        return _lc.gram_block(Xc, block_n=self.spec.block_n,
                              interpret=self.interpret)


# kind → factory(spec, interpret).  A new backend kind registers a
# factory here (and its kind/fields in spec.py) — nothing else changes.
KERNEL_BACKENDS: Dict[str, Callable] = {
    "reference": lambda spec, interpret: ReferenceKernels(spec=spec),
    "pallas": lambda spec, interpret: PallasKernels(spec=spec,
                                                    interpret=interpret),
}


def build_kernels(spec: KernelSpec, *, platform: str | None = None):
    """Resolve a :class:`KernelSpec` into an executable backend.

    ``platform`` defaults to the live ``jax.default_backend()``; the
    Pallas kind compiles for Mosaic on ``"tpu"`` and runs in interpret
    mode on anything else, so the same spec is valid on every target.
    """
    if not isinstance(spec, KernelSpec):
        raise TypeError(f"build_kernels wants a repro.kernels.KernelSpec; "
                        f"got {type(spec).__name__}")
    factory = KERNEL_BACKENDS.get(spec.kind)
    if factory is None:                                 # pragma: no cover
        raise ValueError(_KIND_MSG.format(spec.kind))
    if platform is None:
        platform = jax.default_backend()
    return factory(spec, platform != "tpu")
