"""Blockwise (flash) attention forward kernel for TPU.

Design (TPU-native, not a CUDA port):
  * 4-D grid ``(B, Hq, num_q_blocks, num_kv_blocks)`` — the kv axis is the
    innermost (sequential on TPU), so the online-softmax running state
    (m, l, acc) lives in VMEM scratch and is revisited across kv steps.
  * BlockSpecs tile Q/K/V into (block_q × head_dim) / (block_k × head_dim)
    VMEM tiles; block sizes default to 128 to align with the MXU systolic
    array (128×128) and the (8,128) VREG lanes.
  * GQA without materializing repeated KV: the K/V index_map divides the
    query-head grid index by the group size, so each query-head group
    streams the same KV tile from HBM.
  * Causal + sliding-window masks are applied per-tile; fully-masked tiles
    are skipped with ``pl.when`` (the TPU grid is sequential, so skipping
    is pure latency win — this is what makes the long_500k window path
    sub-quadratic in wall-time as well as FLOPs).

Validated in interpret mode against ``ref.attention_ref`` (CPU container);
on TPU the same ``pl.pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: Optional[int], scale: float,
                  block_q: int, block_k: int, seq_q: int, seq_kv: int):
    i = pl.program_id(2)          # q block index
    j = pl.program_id(3)          # kv block index
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile visibility: absolute query rows are offset by (seq_kv - seq_q)
    # so decode (q is the suffix of the kv timeline) works unchanged.
    offs = seq_kv - seq_q
    q_lo = i * block_q + offs            # first absolute q position in tile
    q_hi = q_lo + block_q - 1
    k_lo = j * block_k
    k_hi = k_lo + block_k - 1

    visible = True
    if causal:
        visible = jnp.logical_and(visible, k_lo <= q_hi)
    if window is not None:
        visible = jnp.logical_and(visible, k_hi > q_lo - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                # (Bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (Bq, Bk)

        q_ids = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_ids = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= q_ids >= k_ids
        if window is not None:
            mask &= (q_ids - k_ids) < window
        mask &= k_ids < seq_kv                 # kv padding guard
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # (Bq,)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == nj - 1)
    def _finalize():
        lsum = l_ref[...]
        safe = jnp.where(lsum == 0.0, 1.0, lsum)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """Flash attention.  Layout: q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D).

    Returns (B, Hq, Sq, D) in q.dtype.  Sq/Skv are padded to block
    multiples internally; window/causal offsets treat q as the *suffix*
    of the kv timeline (decode-compatible).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Skv, 8))
    pq = (-Sq) % block_q
    pk = (-Skv) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sqp, Skvp = Sq + pq, Skv + pk

    grid = (B, Hq, Sqp // block_q, Skvp // block_k)
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, seq_q=Sq, seq_kv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
            pltpu.VMEM((block_q, D), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
