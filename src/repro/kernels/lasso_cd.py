"""Blocked kernels for the STRADS Lasso push hot-spots.

Two MXU-tiled reductions dominate the paper's Lasso round:

  * ``lasso_partial`` — the push partials  z_j = x_jᵀ r  over the
    scheduled block, a (n × U)ᵀ·(n,) mat-vec reduced over row tiles.
  * ``gram_block``    — the ρ-dependency-filter Gram block
    G = X_Cᵀ X_C over the U′ candidates, a (n × U′)ᵀ·(n × U′) matmul
    reduced over row tiles.

Both stream row tiles through VMEM with a resident (U or U′×U′) f32
accumulator, so arbitrarily large n never leaves HBM more than once.
Row-tile size defaults to 256 (= 2 MXU passes); U/U′ are zero-padded to
the 128-lane boundary by the wrappers.

Validated against ``ref.lasso_partial_ref`` / ``ref.gram_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 256


def _partial_kernel(x_ref, r_ref, z_ref, acc_ref, *, rows: int,
                    block_n: int):
    i = pl.program_id(0)
    ni = pl.num_programs(0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                     # (Bn, U)
    r = r_ref[...].astype(jnp.float32)                     # (Bn,)
    row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    r = jnp.where(row < rows, r, 0.0)                      # row padding
    acc_ref[...] += x.T @ r

    @pl.when(i == ni - 1)
    def _():
        z_ref[...] = acc_ref[...]


def lasso_partial(Xb: jax.Array, r: jax.Array,
                  block_n: int = DEFAULT_BLOCK_N,
                  interpret: bool = False) -> jax.Array:
    """z = Xbᵀ r : (n, U), (n,) → (U,) f32."""
    n, U = Xb.shape
    block_n = min(block_n, max(n, 8))
    pn = (-n) % block_n
    if pn:
        Xb = jnp.pad(Xb, ((0, pn), (0, 0)))
        r = jnp.pad(r, ((0, pn),))
    kernel = functools.partial(_partial_kernel, rows=n, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=((n + pn) // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, U), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((U,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((U,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((U,), jnp.float32)],
        interpret=interpret,
    )(Xb, r)


def _gram_kernel(x_ref, g_ref, acc_ref, *, rows: int, block_n: int):
    i = pl.program_id(0)
    ni = pl.num_programs(0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                     # (Bn, U')
    row = i * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (block_n, 1), 0)
    x = jnp.where(row < rows, x, 0.0)
    acc_ref[...] += x.T @ x

    @pl.when(i == ni - 1)
    def _():
        g_ref[...] = acc_ref[...]


def gram_block(Xc: jax.Array, block_n: int = DEFAULT_BLOCK_N,
               interpret: bool = False) -> jax.Array:
    """G = Xcᵀ Xc : (n, U′) → (U′, U′) f32."""
    n, U = Xc.shape
    block_n = min(block_n, max(n, 8))
    pn = (-n) % block_n
    if pn:
        Xc = jnp.pad(Xc, ((0, pn), (0, 0)))
    kernel = functools.partial(_gram_kernel, rows=n, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=((n + pn) // block_n,),
        in_specs=[pl.BlockSpec((block_n, U), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((U, U), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((U, U), jnp.float32),
        scratch_shapes=[pltpu.VMEM((U, U), jnp.float32)],
        interpret=interpret,
    )(Xc)
