"""Public jit'd wrappers over the Pallas kernels.

Each op dispatches between the Pallas kernel (TPU target; ``interpret=True``
on CPU for validation) and the pure-jnp reference path (``ref.py``), chosen
by ``backend``:

  * "auto"      — Pallas on TPU, reference elsewhere (the honest default
                  for this CPU-only container).
  * "pallas"    — force the kernel (compiles for TPU Mosaic).
  * "interpret" — force the kernel in interpret mode (CPU-executable).
  * "ref"       — force the reference path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from . import flash_attention as _fa
from . import lasso_cd as _lc
from . import moe_gating as _mg
from . import ssm_scan as _ss
from . import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "backend", "block_q", "block_k"))
def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None, scale: Optional[float] = None,
              backend: str = "auto", block_q: int = _fa.DEFAULT_BLOCK_Q,
              block_k: int = _fa.DEFAULT_BLOCK_K):
    """Attention in (B, S, H, D) layout, GQA-aware.  See ref.attention_ref."""
    mode = _resolve(backend)
    if mode == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 scale=scale)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    out = _fa.flash_attention(tr(q), tr(k), tr(v), causal=causal,
                              window=window, scale=scale, block_q=block_q,
                              block_k=block_k,
                              interpret=(mode == "interpret"))
    return tr(out)


@functools.partial(jax.jit, static_argnames=("backend", "chunk"))
def ssm_scan(x, dt, A, Bm, Cm, h0=None, *, backend: str = "auto",
             chunk: int = _ss.DEFAULT_CHUNK):
    """Diagonal selective scan.  See ref.ssm_scan_ref."""
    mode = _resolve(backend)
    if mode == "ref":
        return ref.ssm_scan_ref(x, dt, A, Bm, Cm, h0)
    return _ss.ssm_scan(x, dt, A, Bm, Cm, h0, chunk=chunk,
                        interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("k", "backend", "block_t"))
def topk_gating(logits, k: int, *, backend: str = "auto",
                block_t: int = _mg.DEFAULT_BLOCK_T):
    """Fused softmax→top-k→renorm router gating.  See ref.topk_gating_ref."""
    mode = _resolve(backend)
    if mode == "ref":
        return ref.topk_gating_ref(logits, k)
    return _mg.topk_gating(logits, k, block_t=block_t,
                           interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend", "block_n"))
def lasso_partial(Xb, r, *, backend: str = "auto",
                  block_n: int = _lc.DEFAULT_BLOCK_N):
    mode = _resolve(backend)
    if mode == "ref":
        return ref.lasso_partial_ref(Xb, r)
    return _lc.lasso_partial(Xb, r, block_n=block_n,
                             interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend", "block_n"))
def gram_block(Xc, *, backend: str = "auto",
               block_n: int = _lc.DEFAULT_BLOCK_N):
    mode = _resolve(backend)
    if mode == "ref":
        return ref.gram_ref(Xc)
    return _lc.gram_block(Xc, block_n=block_n,
                          interpret=(mode == "interpret"))
