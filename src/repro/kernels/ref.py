"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the semantics contracts: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function here.  They are also the
*default execution path* of the model substrate on CPU (this container has
no TPU; XLA fuses these fine), with the Pallas kernels as the TPU target.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention (flash_attention.py)
# ---------------------------------------------------------------------------

def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  window: Optional[int] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """Masked multi-head attention, GQA-aware.

    Shapes: q (B, Sq, Hq, D); k, v (B, Skv, Hkv, D) with Hq % Hkv == 0.
    ``window``: sliding-window width — query i attends to keys in
    (i − window, i]  (offset by Skv − Sq for decode where q is a suffix).
    Compute in f32, return q.dtype.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, G, axis=2)
    vf = jnp.repeat(vf, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    q_ids = jnp.arange(Sq)[:, None] + (Skv - Sq)   # absolute positions
    k_ids = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_ids >= k_ids
    if window is not None:
        mask &= (q_ids - k_ids) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Selective-state-space scan (ssm_scan.py)
# ---------------------------------------------------------------------------

def ssm_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                 Bm: jax.Array, Cm: jax.Array,
                 h0: Optional[jax.Array] = None):
    """Diagonal selective SSM (Mamba2-style), sequential reference.

    x  (B, S, C)   input channels
    dt (B, S, C)   positive step sizes (post-softplus)
    A  (C,)        negative diagonal state matrix
    Bm (B, S, N)   input projection (shared across channels)
    Cm (B, S, N)   output projection
    h0 (B, C, N)   optional initial state.

    h_t = exp(dt_t ⊙ A) ⊙ h_{t−1} + (dt_t ⊙ x_t) ⊗ B_t
    y_t = ⟨h_t, C_t⟩_N

    Returns (y (B,S,C), h_final (B,C,N)).  f32 math.
    """
    Bsz, S, C = x.shape
    N = Bm.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = (jnp.zeros((Bsz, C, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, t):
        a = jnp.exp(dtf[:, t] * Af[None, :])               # (B, C)
        inp = (dtf[:, t] * xf[:, t])[:, :, None] * Bf[:, t][:, None, :]
        h = a[:, :, None] * h + inp                        # (B, C, N)
        y = jnp.einsum("bcn,bn->bc", h, Cf[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)                             # (B, S, C)
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# MoE gating (moe_gating.py)
# ---------------------------------------------------------------------------

def topk_gating_ref(logits: jax.Array, k: int):
    """Softmax over experts, keep top-k, renormalize.

    logits (T, E) → probs (T, k) f32, idx (T, k) int32.
    Ties broken by lower expert index (jnp.top_k semantics)."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(p, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_p, top_i.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Lasso coordinate-descent partials (lasso_cd.py)
# ---------------------------------------------------------------------------

def lasso_partial_ref(Xb: jax.Array, r: jax.Array) -> jax.Array:
    """z_j = x_jᵀ r for the scheduled block: (n, U), (n,) → (U,) f32."""
    return Xb.astype(jnp.float32).T @ r.astype(jnp.float32)


def gram_ref(Xc: jax.Array) -> jax.Array:
    """Candidate Gram block: (n, U′) → (U′, U′) f32."""
    Xf = Xc.astype(jnp.float32)
    return Xf.T @ Xf
