"""Chunked selective-state-space scan kernel (Mamba2-style diagonal SSM).

Recurrence (per batch, channel c, state dim n):

    h_t = exp(dt_t · A_c) · h_{t−1} + (dt_t · x_t) · B_t
    y_t = Σ_n h_t[n] · C_t[n]

TPU adaptation: the GPU Mamba kernel leans on warp shuffles for the
intra-warp scan; TPUs have no warp analogue, so we restructure as a
*chunked* scan — grid ``(B, num_chunks)`` with the chunk axis sequential
(TPU grids execute in order), carrying the (C, N) state tile in VMEM
scratch across chunk steps.  Inside a chunk we run a ``fori_loop`` over
the chunk length with fully-vectorized (C, N) updates: the VPU processes
the whole channel×state tile per step, so the sequential dimension is the
only non-parallel axis, matching the recurrence's data dependency.

Block sizes: chunk length is a tuning knob (§Perf); (C, N) tiles should be
multiples of (8, 128) VREG lanes.  Validated in interpret mode against
``ref.ssm_scan_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _ssm_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                y_ref, hout_ref, h_ref, *, chunk: int, seq: int):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    A = a_ref[...].astype(jnp.float32)                     # (C,)

    def step(t, h):
        tok = j * chunk + t
        live = tok < seq
        dt = dt_ref[0, t].astype(jnp.float32)              # (C,)
        xt = x_ref[0, t].astype(jnp.float32)               # (C,)
        Bt = b_ref[0, t].astype(jnp.float32)               # (N,)
        Ct = c_ref[0, t].astype(jnp.float32)               # (N,)
        decay = jnp.exp(dt * A)                            # (C,)
        h_new = decay[:, None] * h + (dt * xt)[:, None] * Bt[None, :]
        h = jnp.where(live, h_new, h)
        y = h @ Ct                                         # (C,)
        y_ref[0, t] = jnp.where(live, y, 0.0).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(j == nj - 1)
    def _final():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def ssm_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
             Bm: jax.Array, Cm: jax.Array,
             h0: Optional[jax.Array] = None,
             chunk: int = DEFAULT_CHUNK,
             interpret: bool = False):
    """Chunked diagonal selective scan.

    x, dt (B, S, C); A (C,); Bm, Cm (B, S, N); h0 (B, C, N) optional.
    Returns (y (B, S, C), h_final (B, C, N) f32)."""
    Bsz, S, C = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, C, N), jnp.float32)

    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        x, dt, Bm, Cm = map(zpad, (x, dt, Bm, Cm))
    Sp = S + pad
    grid = (Bsz, Sp // chunk)

    kernel = functools.partial(_ssm_kernel, chunk=chunk, seq=S)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, C), lambda b, j: (b, j, 0)),   # x
            pl.BlockSpec((1, chunk, C), lambda b, j: (b, j, 0)),   # dt
            pl.BlockSpec((C,), lambda b, j: (0,)),                 # A
            pl.BlockSpec((1, chunk, N), lambda b, j: (b, j, 0)),   # B
            pl.BlockSpec((1, chunk, N), lambda b, j: (b, j, 0)),   # C
            pl.BlockSpec((1, C, N), lambda b, j: (b, 0, 0)),       # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, C), lambda b, j: (b, j, 0)),   # y
            pl.BlockSpec((1, C, N), lambda b, j: (b, 0, 0)),       # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, Sp, C), x.dtype),
            jax.ShapeDtypeStruct((Bsz, C, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((C, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, h0)
    return y[:, :S], hout
