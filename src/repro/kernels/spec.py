"""The declarative kernel surface: :class:`KernelSpec`.

The round body's FLOPs live in two hot-spots (1312.5766's observation
that the scheduled-block Gram/correlation computations dominate a Lasso
round): the push partials ``z_j = x_jᵀr`` and the dynamic scheduler's
candidate Gram block ``X_CᵀX_C``.  A :class:`KernelSpec` makes the
*backend* serving them a declarative value on the
:class:`~repro.core.ExecutionPlan`, exactly like
:class:`~repro.sched.spec.SchedulerSpec` and
:class:`~repro.part.spec.PartitionerSpec` made scheduling and
partitioning policy ones:

* **frozen + hashable** — a spec is a value; the engine keys its
  compiled-program caches per (SchedulerSpec, Assignment, KernelSpec);
* **validated at construction** — every invalid kind/parameter
  combination raises here, at spec-build time, never at trace time;
* **JSON-round-trippable** — ``to_json``/``from_json`` are exact
  (defaults included), so specs live inside checked-in plan files
  (``examples/plans/lasso_pallas.json``), benchmark records
  (``BENCH_kernels.json``) and CLI flags (``launch/dryrun.py
  --kernels``).

The spec is backend policy only — it never names an app or a shape.
Execution details (which jax platform is live, hence whether the Pallas
kernels compile for Mosaic or run in interpret mode) are resolved at
injection time (``repro.kernels.build_kernels``), so one spec sweeps
across TPU and the CPU CI container unchanged.
"""
from __future__ import annotations

import dataclasses
import json

KERNEL_KINDS = ("reference", "pallas")

_KIND_MSG = "kernel kind must be 'reference' or 'pallas'; got {!r}"

# Which fields each kind consumes; everything else must stay at its zero
# default (a spec never carries silently-ignored knobs — the same rule
# SchedulerSpec and PartitionerSpec enforce).
_FIELDS_BY_KIND = {
    "reference": (),
    "pallas": ("block_n",),
}


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Everything the engine needs to know about *what executes* the
    round body's compute hot-spots.

    Fields
    ------
    kind:     ``"reference"`` (the pure-jnp oracles in
              ``repro.kernels.ref`` — XLA fuses these fine on CPU, and
              they are the bit-identical pre-KernelSpec behavior),
              ``"pallas"`` (the fused VMEM-tiled kernels in
              ``repro.kernels.lasso_cd`` — compiled for Mosaic on TPU,
              automatically run in interpret mode elsewhere so the same
              plan lowers on the CPU CI container).
    block_n:  row-tile size the Pallas kernels stream through VMEM
              (``pallas`` only; > 0 — 256 = two MXU passes is the
              conventional default ``default_for`` fills in; the
              kernels clamp it down to the row count for small shards).
    """

    kind: str
    block_n: int = 0

    def __post_init__(self):
        if self.kind not in KERNEL_KINDS:
            raise ValueError(_KIND_MSG.format(self.kind))
        v = self.block_n
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            raise ValueError(f"block_n must be an int >= 0; got {v!r}")
        used = _FIELDS_BY_KIND[self.kind]
        for field in ("block_n",):
            if field not in used and getattr(self, field):
                raise ValueError(
                    f"{field}={getattr(self, field)!r} does not apply to "
                    f"kind={self.kind!r} (leave it at its default)")
        if self.kind == "pallas" and self.block_n < 1:
            raise ValueError(
                f"kind='pallas' needs block_n >= 1 (the VMEM row-tile "
                f"size; KernelSpec.default_for('pallas') fills the "
                f"conventional 256); got {self.block_n!r}")

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        """A plain JSON-safe dict (every field, defaults included) —
        ``from_json(to_json(s)) == s`` exactly."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj) -> "KernelSpec":
        """Rebuild from ``to_json`` output, a JSON string, or a partial
        dict (missing fields take their defaults; unknown keys raise)."""
        if isinstance(obj, (str, bytes)):
            obj = json.loads(obj)
        if not isinstance(obj, dict):
            raise TypeError(f"KernelSpec.from_json wants a dict or "
                            f"JSON string; got {type(obj).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - known
        if unknown:
            raise ValueError(f"unknown KernelSpec field(s): "
                             f"{sorted(unknown)}")
        return cls(**obj)

    @classmethod
    def default_for(cls, kind: str, **overrides) -> "KernelSpec":
        """The conventional spec for a kind — the ONE defaults table the
        CLI surfaces (``dryrun --kernels``) resolve flag-built specs
        from, so per-site copies cannot drift.  ``overrides`` replace
        individual fields on the conventional base."""
        if kind == "reference":
            base = dict(kind=kind)
        elif kind == "pallas":
            from .lasso_cd import DEFAULT_BLOCK_N
            base = dict(kind=kind, block_n=DEFAULT_BLOCK_N)
        else:
            raise ValueError(_KIND_MSG.format(kind))
        base.update(overrides)
        return cls(**base)
