"""Pallas TPU kernels for the compute hot-spots.

<name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
ops.py     — jit'd public wrappers (backend dispatch: pallas/interpret/ref)
ref.py     — pure-jnp oracles (semantics contract + CPU execution path)
"""
from . import ops, ref  # noqa: F401
