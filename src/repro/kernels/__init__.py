"""Pallas TPU kernels for the compute hot-spots.

<name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
ops.py     — jit'd public wrappers (backend dispatch: pallas/interpret/ref)
ref.py     — pure-jnp oracles (semantics contract + CPU execution path)
spec.py    — KernelSpec: the declarative backend choice carried as
             ``ExecutionPlan.kernels`` (frozen, validated,
             JSON-round-trippable — the third leg of the
             scheduler/partitioner spec pattern)
backend.py — build_kernels registry resolving a spec into an executable
             backend (Pallas on TPU, interpret-mode fallback elsewhere)
"""
from . import ops, ref  # noqa: F401
from .backend import (KERNEL_BACKENDS, PallasKernels,  # noqa: F401
                      ReferenceKernels, build_kernels)
from .spec import KERNEL_KINDS, KernelSpec  # noqa: F401

__all__ = [
    "ops", "ref", "KERNEL_KINDS", "KernelSpec", "KERNEL_BACKENDS",
    "ReferenceKernels", "PallasKernels", "build_kernels",
]
