"""Fused top-k softmax gating kernel for MoE routing.

Routing *is* the STRADS correspondence (DESIGN.md §4): the router executes
``schedule`` at token granularity.  This kernel fuses softmax → top-k →
renormalize over the expert axis in one VMEM pass per token tile, instead
of three HBM round-trips.

Grid: ``(num_token_blocks,)``; each program handles a (block_t, E) logits
tile.  Top-k for small k (1–8 in all assigned MoE archs) is computed by k
iterative masked argmaxes — O(k·E) VPU work, no sort.  E is padded to the
128-lane boundary by the wrapper.

Validated against ``ref.topk_gating_ref`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

DEFAULT_BLOCK_T = 256


def _gating_kernel(logits_ref, probs_ref, idx_ref, *, k: int,
                   num_experts: int):
    x = logits_ref[...].astype(jnp.float32)                # (Bt, Ep)
    bt, ep = x.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (bt, ep), 1)
    x = jnp.where(lane < num_experts, x, NEG_INF)          # expert padding

    # softmax over the real experts
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    # iterative top-k (k is small and static)
    work = p
    tot = jnp.zeros((bt,), jnp.float32)
    for i in range(k):
        best = jnp.argmax(work, axis=-1).astype(jnp.int32)    # (Bt,)
        bp = jnp.max(work, axis=-1)
        probs_ref[:, i] = bp
        idx_ref[:, i] = best
        tot = tot + bp
        work = jnp.where(lane == best[:, None], -1.0, work)

    # renormalize the kept probabilities
    for i in range(k):
        probs_ref[:, i] = probs_ref[:, i] / tot


def topk_gating(logits: jax.Array, k: int,
                block_t: int = DEFAULT_BLOCK_T,
                interpret: bool = False):
    """(T, E) logits → (probs (T,k) f32, idx (T,k) i32), renormalized."""
    T, E = logits.shape
    block_t = min(block_t, max(T, 8))
    pt = (-T) % block_t
    pe = (-E) % 128 if E > 8 else 0     # lane alignment on real TPU
    x = jnp.pad(logits, ((0, pt), (0, pe)), constant_values=NEG_INF)
    Tp, Ep = x.shape

    kernel = functools.partial(_gating_kernel, k=k, num_experts=E)
    probs, idx = pl.pallas_call(
        kernel,
        grid=(Tp // block_t,),
        in_specs=[pl.BlockSpec((block_t, Ep), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp, k), jnp.float32),
            jax.ShapeDtypeStruct((Tp, k), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    return probs[:T], idx[:T]
