"""Sharded-tree checkpointing to ``.npz`` (flattened key paths).

Trees are flattened with '/'-joined key paths; ints in paths (scan-stacked
layers) round-trip.  Works for any pytree of arrays (params, optimizer
moments, full train state, engine run state incl. typed PRNG keys — keys
are stored as their ``key_data`` and re-wrapped on restore, so a resumed
run continues the exact random stream).  On a real multi-host cluster
each host would write its addressable shards; in this single-host
container the global array is materialized — the format is the same.
"""
from __future__ import annotations

import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np


def _is_key(leaf: Any) -> bool:
    return (isinstance(leaf, jax.Array)
            and jax.dtypes.issubdtype(leaf.dtype, jax.dtypes.prng_key))


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if _is_key(leaf):
            leaf = jax.random.key_data(leaf)
        out[name] = np.asarray(leaf)
    return out


def _unflatten_into(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    vals = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if name not in flat:
            raise KeyError(f"checkpoint missing {name}")
        arr = flat[name]
        if _is_key(leaf):
            kd = jax.random.key_data(leaf)
            if tuple(arr.shape) != tuple(kd.shape):
                raise ValueError(f"{name}: key data shape {arr.shape} != "
                                 f"{kd.shape}")
            vals.append(jax.random.wrap_key_data(
                arr.astype(kd.dtype), impl=jax.random.key_impl(leaf)))
            continue
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {leaf.shape}")
        vals.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), vals)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **_flatten(tree))
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def load_flat(ckpt_dir: str, step: int) -> Dict[str, np.ndarray]:
    """One checkpoint's raw flattened arrays ('/'-joined key paths) —
    for callers that must inspect *optional* subtrees before committing
    to a template: a streamed run saves a ``"stream"`` cursor subtree
    beside ``"state"``/``"carry"``/``"assignment"`` (see
    :meth:`repro.core.StradsEngine.execute`), and a resume path probes
    ``stream/...`` keys here to tell streamed checkpoints from
    unstreamed ones without a shape-checked restore."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def restore_checkpoint(ckpt_dir: str, step: int, template: Any) -> Any:
    return _unflatten_into(template, load_flat(ckpt_dir, step))
