"""Logical-axis sharding rules (MaxText-style) for the Big-Model substrate.

Parameters and activations are annotated with *logical* axis names; this
module resolves them against a concrete mesh ((data, model) single-pod or
(pod, data, model) multi-pod) into ``PartitionSpec``s.

Resolution rules
----------------
* A logical name maps to a tuple of mesh axes (e.g. ``batch`` →
  ``("pod", "data")``); axes absent from the mesh are dropped (so the same
  template works on single- and multi-pod meshes and on the 1-device CPU
  test mesh).
* jax requires explicitly-sharded dims to be **divisible** by the product
  of mesh axis sizes; ``resolve`` silently drops the mapping when it does
  not divide (e.g. kv_heads=2 over a 16-way model axis → replicated).
  Where dropping would be catastrophic for efficiency (query heads, vocab)
  the model instead *pads the physical dimension* — see ``padded_heads`` /
  ``padded_vocab`` — so the spec always applies.

Layouts produced
----------------
* **TP** (tensor parallel): heads / d_ff / experts / vocab over ``model``.
* **FSDP**: the d_model dim of every weight over ``data`` (ZeRO-3 —
  GSPMD inserts per-layer all-gathers; optimizer moments shard the same
  way, giving ZeRO moments for free).
* **DP**: batch over ``("pod", "data")``; grads all-reduce over both.
* **Decode**: KV-cache sequence dim over ``model`` (cache-sequence
  parallelism — softmax/psum stays collective-cheap because the reduction
  over the sharded key axis is a scalar-sized psum, not a gather).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# Logical axis names -------------------------------------------------------
BATCH = "batch"            # data-parallel batch dim
FSDP = "fsdp"              # weight d_model dim (ZeRO-3 over data)
TENSOR = "tensor"          # heads / d_ff / d_inner (TP over model)
EXPERT = "expert"          # MoE expert dim (EP over model)
VOCAB = "vocab"            # vocab dim (TP over model)
CACHE_SEQ = "cache_seq"    # decode KV-cache sequence dim
SEQ = "seq"                # activation sequence dim (sequence parallelism)

LOGICAL_TO_MESH = {
    BATCH: ("pod", "data"),
    FSDP: ("data",),
    TENSOR: ("model",),
    EXPERT: ("model",),
    VOCAB: ("model",),
    CACHE_SEQ: ("pod", "model"),
    SEQ: ("model",),
}

# The production model axis is 16 on both assigned meshes; padding targets
# (query heads, vocab) are derived from it.
MODEL_AXIS_SIZE = 16


def logical_to_mesh(name: Optional[str], mesh: Mesh
                    ) -> Union[None, str, Tuple[str, ...]]:
    """Map one logical name to the mesh axes present in ``mesh``."""
    if name is None:
        return None
    axes = tuple(a for a in LOGICAL_TO_MESH[name] if a in mesh.axis_names)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def axis_size(mesh: Mesh, name: Optional[str]) -> int:
    """Product of mesh-axis sizes a logical name resolves to (1 if none)."""
    m = logical_to_mesh(name, mesh)
    if m is None:
        return 1
    if isinstance(m, str):
        m = (m,)
    return math.prod(mesh.shape[a] for a in m)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in LOGICAL_TO_MESH[BATCH] if a in mesh.axis_names)


def resolve(mesh: Mesh, axes: Sequence[Optional[str]],
            shape: Sequence[int]) -> PartitionSpec:
    """Resolve logical axes against ``mesh``, dropping non-divisible dims."""
    assert len(axes) == len(shape), (axes, shape)
    entries = []
    used = set()
    for name, dim in zip(axes, shape):
        m = logical_to_mesh(name, mesh)
        if m is not None:
            flat = (m,) if isinstance(m, str) else m
            if any(a in used for a in flat):
                m = None                       # mesh axis already consumed
            elif dim % math.prod(mesh.shape[a] for a in flat) != 0:
                m = None                       # jax requires divisibility
            else:
                used.update(flat)
        entries.append(m)
    while entries and entries[-1] is None:
        entries.pop()                          # canonical short spec
    return PartitionSpec(*entries)


def named_sharding(mesh: Mesh, axes: Sequence[Optional[str]],
                   shape: Sequence[int]) -> NamedSharding:
    return NamedSharding(mesh, resolve(mesh, axes, shape))


_ACTIVE_MESH: list = []       # stack managed by ``activation_mesh``


class activation_mesh:
    """Context manager installing the mesh that ``constrain`` annotates
    activations against.  The launcher enters it around tracing; unit
    tests (1-device) never do, so ``constrain`` is an identity there."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    def __enter__(self):
        _ACTIVE_MESH.append(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        _ACTIVE_MESH.pop()
        return False


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """``with_sharding_constraint`` against the active mesh (identity when
    no mesh is installed or the mesh is trivial)."""
    if not _ACTIVE_MESH:
        return x
    mesh = _ACTIVE_MESH[-1]
    if math.prod(mesh.shape.values()) == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(mesh, axes, x.shape)))


# Padding helpers -----------------------------------------------------------

def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def padded_vocab(vocab_size: int) -> int:
    """Pad vocab so each model shard is a multiple of 128 (MXU lane width).

    152k-class softmaxes dominate nothing; the pad rows carry −inf logits
    via masking in the loss.
    """
    return pad_to_multiple(vocab_size, 128 * MODEL_AXIS_SIZE)


def padded_heads(num_heads: int, num_kv_heads: int) -> Tuple[int, int]:
    """Physical (q, kv) head counts for TP over the 16-way model axis.

    * q heads are always padded up to a multiple of 16 **that keeps the GQA
      group count integral** (llama4: 40→48 with kv=8 → G=6).
    * kv heads shard only when ≥ the axis and divisible; smaller kv groups
      are replicated (their projections are tiny), except MHA-style counts
      (kv == q) which pad together (minicpm: 36/36 → 48/48).
    """
    hq = pad_to_multiple(num_heads, MODEL_AXIS_SIZE)
    if num_kv_heads == num_heads:
        return hq, hq
    kv = num_kv_heads
    while hq % kv:
        hq += MODEL_AXIS_SIZE                 # keep G = hq / kv integral
    return hq, kv
