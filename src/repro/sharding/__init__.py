from .rules import (  # noqa: F401
    BATCH, EXPERT, FSDP, TENSOR, VOCAB,
    axis_size, batch_axes, logical_to_mesh, resolve, named_sharding,
    constrain, activation_mesh, pad_to_multiple, padded_vocab, padded_heads,
    MODEL_AXIS_SIZE, CACHE_SEQ, SEQ,
)
