"""xLSTM-125M — sLSTM + mLSTM blocks at a [7:1]-style ratio (sLSTM at
layers 3 and 9 of 12); d_ff=0 because the up/down projection lives
inside the mLSTM block (proj_factor 2) [arXiv:2405.04517]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_layers=(3, 9), xlstm_proj_factor=2.0,
    source="arXiv:2405.04517",
)
