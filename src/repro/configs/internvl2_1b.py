"""InternVL2-1B — InternViT vision frontend (STUB per spec: patch
embeddings provided pre-projected at d_model) + InternLM2 dense decoder
backbone [arXiv:2404.16821]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655,
    frontend="vision", frontend_tokens=256,
    source="arXiv:2404.16821",
)
