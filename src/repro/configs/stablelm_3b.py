"""StableLM-3B — dense MHA (kv = q = 32), LayerNorm
[hf:stabilityai/stablelm-2-1_6b]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=6912, vocab_size=50304,
    norm="ln", rope_fraction=0.25,
    source="hf:stabilityai/stablelm-2-1_6b",
)
