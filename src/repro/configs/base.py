"""Model configuration schema covering all six assigned architecture
families (dense / moe / ssm / hybrid / vlm / audio) plus the paper's own
STRADS applications.

Every assigned architecture is one :class:`ModelConfig` instance in its
own module (``src/repro/configs/<arch_id>.py``) citing its source; smoke
tests instantiate ``cfg.reduced()`` (2 layers, d_model ≤ 512, ≤ 4 experts)
per the harness contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    # trunk
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_every: int = 1               # MoE FFN every k-th layer (llama4: 2)
    moe_shared_expert: bool = False  # dense shared expert on MoE layers
    moe_impl: str = "einsum"         # "einsum" (GShard) | "sort" (§Perf)
    # SSM (Mamba2-style)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_impl: str = "ssd"            # "ssd" (chunked matmul form, default after §Perf) | "scan"
    # hybrid (zamba2): one *shared* attention block applied every k layers
    attn_every: int = 0
    # xLSTM: which layer indices are sLSTM (others mLSTM)
    slstm_layers: Tuple[int, ...] = ()
    xlstm_proj_factor: float = 2.0
    # attention details
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # chatglm "RoPE 2d": rotary on half dim
    window: Optional[int] = None     # sliding-window width (long-context)
    causal: bool = True
    # misc
    norm_eps: float = 1e-5
    norm: str = "rms"                # "rms" | "ln"
    tie_embeddings: bool = False
    scale_embed: bool = False        # multiply embeddings by sqrt(d_model)
    # modality frontend stubs (spec carve-out: embeddings provided)
    frontend: Optional[str] = None   # "vision" | "audio"
    frontend_tokens: int = 256       # patches / frames prepended (vlm)
    encoder_only: bool = False       # hubert: no decode step
    # numerics
    dtype: str = "bfloat16"
    # citation
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def d_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Approximate parameter count N (for 6·N·D model-FLOPs)."""
        hd = self.head_dim_
        d = self.d_model
        per_layer = 0
        attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads \
            + hd * self.num_heads * d
        ffn_dense = 3 * d * self.d_ff
        for i in range(self.num_layers):
            if self.family in ("dense", "vlm", "audio"):
                per_layer += attn + ffn_dense
            elif self.family == "moe":
                per_layer += attn + self.num_experts * ffn_dense
            elif self.family == "ssm" and self.slstm_layers is not None \
                    and self.d_ff == 0:
                # xLSTM block: qkv+gates+proj within block
                per_layer += int(2 * d * d * self.xlstm_proj_factor) + 4 * d * d
            elif self.family in ("ssm", "hybrid"):
                dssm = self.d_ssm
                per_layer += 2 * d * dssm + dssm * d + dssm * self.ssm_conv \
                    + 2 * dssm * self.ssm_state
                if self.family == "hybrid" and self.attn_every and \
                        (i + 1) % self.attn_every == 0 and i == 0:
                    pass
        if self.family == "hybrid" and self.attn_every:
            per_layer += attn + ffn_dense      # ONE shared block
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return per_layer + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.head_dim_
        attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads \
            + hd * self.num_heads * d
        ffn = 3 * d * self.d_ff * self.experts_per_token
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.num_layers * (attn + ffn) + emb

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model ≤ 512, ≤ 4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        if heads % kv:
            kv = 1
        attn_every = min(self.attn_every, 2) if self.attn_every else 0
        layers = 2 * attn_every if attn_every else 2
        if self.moe_every > 1:
            layers = 2 * self.moe_every
        return dataclasses.replace(
            self,
            attn_every=attn_every,
            num_layers=layers,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d // heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            slstm_layers=tuple(i for i in self.slstm_layers if i < 2),
            frontend_tokens=min(self.frontend_tokens, 16),
            window=min(self.window, 64) if self.window else None,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
