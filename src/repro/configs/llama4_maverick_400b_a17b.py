"""Llama-4 Maverick 400B-A17B — interleaved MoE (every 2nd layer),
128 routed experts top-1 + shared expert, GQA kv=8, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

The 40 query heads are physically padded to 48 for 16-way tensor
parallelism (DESIGN.md §7); kv=8 heads are replicated across the model
axis (their projections are small)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    num_experts=128, experts_per_token=1,
    moe_every=2, moe_shared_expert=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
