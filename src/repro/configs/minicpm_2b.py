"""MiniCPM-2B — llama-like dense, MHA 36 heads (padded to 48 for 16-way
TP, DESIGN.md §7), tied embeddings, WSD LR schedule (optim/schedules.py)
[arXiv:2404.06395]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753,
    tie_embeddings=True, scale_embed=True,
    source="arXiv:2404.06395",
)
