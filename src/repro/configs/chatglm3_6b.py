"""ChatGLM3-6B — dense, GQA kv=2, 2d-RoPE (rotary over half the head
dim) [arXiv:2406.12793]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    rope_fraction=0.5,
    source="arXiv:2406.12793",
)
