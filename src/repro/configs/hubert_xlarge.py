"""HuBERT X-Large — encoder-only audio transformer (conv/mel frontend is
a STUB per spec: frame embeddings provided); vocab 504 = k-means cluster
targets.  No decode shapes (encoder-only; DESIGN.md §6)
[arXiv:2106.07447]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    norm="ln", causal=False, frontend="audio", encoder_only=True,
    source="arXiv:2106.07447",
)
