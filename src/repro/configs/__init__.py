"""Architecture registry: ``get_config("<arch-id>")`` returns the exact
assigned :class:`ModelConfig`; ``ARCHS`` lists all ten ids."""
from __future__ import annotations

import importlib

from .base import ModelConfig, InputShape, INPUT_SHAPES  # noqa: F401

ARCHS = (
    "zamba2-2.7b",
    "llama4-maverick-400b-a17b",
    "chatglm3-6b",
    "internvl2-1b",
    "stablelm-3b",
    "granite-3-2b",
    "minicpm-2b",
    "hubert-xlarge",
    "xlstm-125m",
    "phi3.5-moe-42b-a6.6b",
)

_MODULE_OF = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_OF:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(f".{_MODULE_OF[arch]}", __package__)
    return mod.CONFIG
