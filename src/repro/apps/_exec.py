"""Thin plan adapter for the app-level ``fit`` drivers.

Every app exposes ``fit(..., plan=ExecutionPlan(...))``; the legacy
``executor=``/``staleness=`` kwargs still work behind
:func:`resolve_plan` (emitting a ``DeprecationWarning`` and producing a
bit-identical run), and a bare ``trace_every=`` maps silently onto
``collect_every`` (it stays the loop-path trace knob and does not warn
on its own).  All executor-name/kwarg validation lives in
:class:`repro.core.plan.ExecutionPlan` — the single source of truth the
old ``scan_depth`` helper's drifted error message was folded into.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, List, Optional, Tuple

from repro.core import ExecutionPlan


def resolve_plan(plan: Optional[ExecutionPlan], *,
                 num_rounds: Optional[int] = None,
                 executor: Optional[str] = None,
                 staleness: Optional[int] = None,
                 trace_every: Optional[int] = None) -> ExecutionPlan:
    """One plan out of either surface: the declarative ``plan=`` or the
    deprecated per-kwarg form (which warns and builds the same plan, so
    both run bit-identically through ``StradsEngine.execute``)."""
    if plan is not None:
        if executor is not None or staleness is not None:
            raise ValueError("pass either plan= or the legacy executor=/"
                             "staleness= kwargs, not both")
        if num_rounds is not None and num_rounds != plan.rounds:
            raise ValueError(f"num_rounds={num_rounds} contradicts "
                             f"plan.rounds={plan.rounds}; drop one")
        if trace_every:
            raise ValueError("trace cadence comes from plan.collect_every "
                             "when a plan is passed")
        if plan.telemetry or plan.checkpoint_every:
            raise ValueError(
                "fit() has no telemetry/checkpoint surface — it would "
                "silently drop plan.telemetry / plan.checkpoint_every; "
                "drive StradsEngine.execute(..., ckpt_dir=...) directly "
                "for those plan fields")
        return plan
    if executor is not None or staleness is not None:
        warnings.warn(
            "fit(executor=..., staleness=...) is deprecated; pass "
            "plan=ExecutionPlan(executor=..., staleness=..., rounds=...) "
            "instead", DeprecationWarning, stacklevel=3)
    if num_rounds is None:
        raise ValueError("fit needs num_rounds (or a plan= carrying "
                         "rounds)")
    return ExecutionPlan(executor=executor if executor is not None
                         else "loop",
                         rounds=num_rounds,
                         staleness=staleness or 0,
                         collect_every=trace_every or 0)


def run_executor(eng, state, data, rng, num_rounds: int, executor: str,
                 collect: Optional[Callable[[Any], Any]] = None,
                 staleness: int = 0):
    """Deprecated: build an :class:`ExecutionPlan` and call
    ``StradsEngine.execute`` instead."""
    warnings.warn("run_executor is deprecated; use StradsEngine.execute "
                  "with an ExecutionPlan", DeprecationWarning,
                  stacklevel=2)
    plan = ExecutionPlan(executor=executor, rounds=num_rounds,
                         staleness=staleness)
    rep = eng.execute(state, data, rng, plan, collect=collect)
    return rep.state if collect is None else (rep.state, rep.trace)


def trace_points(num_rounds: int, trace_every: int) -> List[int]:
    """The round indices a host-loop trace callback would record."""
    return [t for t in range(num_rounds)
            if t % trace_every == 0 or t == num_rounds - 1]


def decimate(values, num_rounds: int,
             trace_every: int) -> List[Tuple[int, float]]:
    """Per-round collect output → the host-loop-style (t, float) trace."""
    return [(t, float(values[t]))
            for t in trace_points(num_rounds, trace_every)]
