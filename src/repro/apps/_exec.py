"""Shared executor dispatch for the app-level ``fit`` drivers.

Every app exposes ``fit(..., executor="loop"|"scan"|"pipelined"|"ssp")``;
the non-loop paths all reduce to the same call into the engine's scanned
executors (``run_scanned`` / ``run_ssp``) plus the same trace decimation,
so they live here once.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Tuple

_EXEC_DEPTH = {"scan": 0, "pipelined": 1}


def scan_depth(executor: str) -> int:
    """Map an executor name to its pipeline depth (raising on typos)."""
    depth = _EXEC_DEPTH.get(executor)
    if depth is None:
        raise ValueError(f"executor must be 'loop', 'scan', 'pipelined' "
                         f"or 'ssp'; got {executor!r}")
    return depth


def run_executor(eng, state, data, rng, num_rounds: int, executor: str,
                 collect: Optional[Callable[[Any], Any]] = None,
                 staleness: int = 0):
    """Dispatch a non-loop executor.  ``staleness`` only applies to
    ``executor="ssp"`` (the bounded-staleness path in ``repro.ps``)."""
    if executor == "ssp":
        return eng.run_ssp(state, data, rng, num_rounds,
                           staleness=staleness, collect=collect)
    if staleness:
        raise ValueError(f"staleness={staleness} requires executor='ssp'; "
                         f"got executor={executor!r}")
    return eng.run_scanned(state, data, rng, num_rounds,
                           pipeline_depth=scan_depth(executor),
                           collect=collect)


def trace_points(num_rounds: int, trace_every: int) -> List[int]:
    """The round indices a host-loop trace callback would record."""
    return [t for t in range(num_rounds)
            if t % trace_every == 0 or t == num_rounds - 1]


def decimate(values, num_rounds: int,
             trace_every: int) -> List[Tuple[int, float]]:
    """Per-round collect output → the host-loop-style (t, float) trace."""
    return [(t, float(values[t]))
            for t in trace_points(num_rounds, trace_every)]
