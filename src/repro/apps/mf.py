"""STRADS Matrix Factorization (paper §3.2) and an ALS baseline.

Task:  min_{W,H}  Σ_{(i,j)∈Ω} (a_ij − wᵢhⱼ)² + λ(‖W‖_F² + ‖H‖_F²)
with W ∈ R^{N×K}, H ∈ R^{K×M} (paper eq. 2), solved by rank-wise parallel
coordinate descent (CCD-style, paper eq. 3).

schedule: round-robin over (matrix ∈ {W, H}) × (rank k) — the paper's
round-robin dispatch over the q_p / r_p index sets; with rows of A sharded
over workers, *all* columns of H can be updated concurrently for a fixed
rank k (they are mutually independent given W — the paper's "free from
parallelization error" argument), and symmetrically for W against the
column-sharded replica.

push (H-phase, rank k):   a_j^p = Σ_{i∈(Ω_j)_p} (r_ij + w_ik h_kj) w_ik   (g₁)
                          b_j^p = Σ_{i∈(Ω_j)_p} w_ik²                     (g₂)
pull:                     h_kj ← Σ_p a_j^p / (λ + Σ_p b_j^p)              (g₃)
sync (automatic):         R ← R − w_k (h_k_new − h_k_old) on local rows.

Laptop-scale layout: A dense with an observation mask, rows sharded over
the ``data`` axis.  W and the residual R shard with the rows (model
partitioning — Fig 3); H is the synced KV-store block (replicated, it is
K×M which is small relative to W for N ≫ M).  The W-phase uses the same
row shards: for fixed k, w_ik ← Σ_j ... over the row's *local* observed
entries, which requires no cross-worker sum at all (rows live whole on one
worker) — partials degenerate to local updates, matching the paper's
submatrix A^{q_p} storage.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import StradsAppBase, StradsEngine
from repro.core.compat import shard_map
from repro.part import PartitionerSpec
from repro.sched import SchedulerSpec

from . import _exec


@dataclasses.dataclass(frozen=True)
class MFConfig:
    num_rows: int                # N (users)
    num_cols: int                # M (items)
    rank: int                    # K
    lam: float = 0.05
    ranks_per_round: int = 1     # how many rank indices per BSP round
    top_k: int = 8               # recommendations per query() request


class StradsMF(StradsAppBase):
    """Round-robin rank-wise CD on STRADS primitives."""

    phase_period = 2                     # H-phase / W-phase alternation
    # rank blocks are mutually independent given the other factor — no
    # dependency filter applies, so only the stateless dispatch kinds
    supported_scheduler_kinds = ("round_robin", "random")
    # rank-1 outer-product updates have no fused Pallas kernel yet —
    # only the reference backend applies, enforced at injection time
    supported_kernel_kinds = ("reference",)

    def __init__(self, cfg: MFConfig):
        self.cfg = cfg

    # state: W,R row-sharded; H replicated (synced KV block)
    def init_state(self, rng, A=None, mask=None):
        cfg = self.cfg
        kw, kh = jax.random.split(rng)
        W = jax.random.normal(kw, (cfg.num_rows, cfg.rank), jnp.float32)
        W = W / jnp.sqrt(cfg.rank)
        H = jax.random.normal(kh, (cfg.rank, cfg.num_cols), jnp.float32)
        H = H / jnp.sqrt(cfg.rank)
        if A is None:
            raise ValueError("StradsMF.init_state needs A (for the residual)")
        R = (A - W @ H) * mask
        return {"W": W, "H": H, "R": R}

    def state_specs(self):
        return {"W": P("data"), "H": P(), "R": P("data")}

    def data_specs(self):
        return {"A": P("data"), "mask": P("data")}

    # -- schedule: round-robin (phase, rank) --------------------------------

    def default_scheduler_spec(self) -> SchedulerSpec:
        # the paper's round-robin dispatch over the q_p / r_p index sets
        return SchedulerSpec(kind="round_robin",
                             block_size=self.cfg.ranks_per_round)

    def num_schedulable(self) -> int:
        return self.cfg.rank

    # -- partition injection -------------------------------------------------
    # Rank blocks are interchangeable (mutually independent given the
    # other factor), so ownership may move freely; the activity signal
    # is the per-rank L1 mass of H — rank rows that move a lot pull
    # their server load with them.

    supported_partitioner_kinds = ("static", "size_balanced",
                                   "load_balanced")

    def default_partitioner_spec(self) -> PartitionerSpec:
        return PartitionerSpec(kind="static")

    def partition_signal(self, state):
        return jnp.sum(jnp.abs(state["H"]), axis=1)

    def partition_sizes(self):
        # bytes per rank: a row of H (M floats) + a column of W (N)
        cfg = self.cfg
        return [4 * (cfg.num_cols + cfg.num_rows)] * cfg.rank

    def static_phase(self, t: int) -> int:
        # Alternate H-phase (0) and W-phase (1) every round.
        return t % 2

    def propose(self, state, carry, rng, t, phase):
        # rank block for this round: the injected policy over K ranks,
        # advanced once per H/W cycle (two BSP rounds share a rank
        # block).  Stochastic policies must draw the SAME block in both
        # halves of a cycle, so the proposal key derives from the cycle
        # index off a fixed base — the fold_in pattern LDA's Gibbs keys
        # use — not from the per-round engine stream; like those Gibbs
        # keys, the schedule sequence is therefore deterministic across
        # runs regardless of the fit seed.
        cyc = t // 2
        key = jax.random.fold_in(jax.random.key(29), cyc)
        ks = self.scheduler.propose(carry, key, cyc, phase)
        return {"ranks": ks}

    # -- push / pull ----------------------------------------------------------

    def push(self, data, state, sched, phase):
        cfg = self.cfg
        W, H, R, mask = state["W"], state["H"], state["R"], data["mask"]
        ks = sched["ranks"]
        if phase == 0:
            # H-phase: numerator/denominator partial sums over local rows.
            Wk = jnp.take(W, ks, axis=1)            # (n_p, Kr)
            Hk = jnp.take(H, ks, axis=0)            # (Kr, M)
            # a_j = Σ_i m_ij (r_ij + w_ik h_kj) w_ik ; b_j = Σ_i m_ij w_ik²
            wk2 = jnp.einsum("ij,ik->kj", mask, Wk * Wk)        # (Kr, M)
            a = jnp.einsum("ik,ij->kj", Wk, R * mask) + wk2 * Hk
            return {"a": a, "b": wk2}, None
        else:
            # W-phase: rows are whole on this worker — no cross-worker sum
            # needed; return zero-shaped partials to keep the round uniform.
            return {"a": jnp.zeros((len(ks), 1), jnp.float32),
                    "b": jnp.zeros((len(ks), 1), jnp.float32)}, None

    def pull(self, state, sched, z, local, data, phase):
        cfg = self.cfg
        W, H, R, mask = state["W"], state["H"], state["R"], data["mask"]
        ks = sched["ranks"]
        if phase == 0:
            Hk_old = jnp.take(H, ks, axis=0)                      # (Kr, M)
            Hk_new = z["a"] / (cfg.lam + z["b"])                  # g₃
            H = H.at[ks].set(Hk_new)
            Wk = jnp.take(W, ks, axis=1)                          # (n_p, Kr)
            R = R - (Wk @ (Hk_new - Hk_old)) * mask               # sync
            return {"W": W, "H": H, "R": R}
        else:
            # W-phase (local closed-form CD for rank block ks on local rows)
            Hk = jnp.take(H, ks, axis=0)                          # (Kr, M)
            Wk_old = jnp.take(W, ks, axis=1)                      # (n_p, Kr)
            num = jnp.einsum("ij,kj->ik", R * mask, Hk) \
                + Wk_old * jnp.einsum("ij,kj->ik", mask, Hk * Hk)
            den = cfg.lam + jnp.einsum("ij,kj->ik", mask, Hk * Hk)
            Wk_new = num / den
            W = W.at[:, ks].set(Wk_new)
            R = R - ((Wk_new - Wk_old) @ Hk) * mask               # sync
            return {"W": W, "H": H, "R": R}

    # -- serving (query primitive) -------------------------------------------

    def query(self, state, batch):
        """``recommend``: top-k item scores for each requested user row
        (batch ``{"user": (B,)}`` → ``{"items": (B, k), "scores":
        (B, k)}``).  Scores are w_uᵀh_j over all items; W is
        worker-resident (served live at the boundary), H is the
        server-resident leaf (the possibly-stale half under
        ``kind="stale"`` — the same split an SSP training read sees)."""
        k = min(self.cfg.top_k, self.cfg.num_cols)
        Wu = jnp.take(state["W"], batch["user"], axis=0)   # (B, K)
        scores = Wu @ state["H"]                           # (B, M)
        top_scores, top_items = jax.lax.top_k(scores, k)
        return {"items": top_items, "scores": top_scores}

    # -- streaming (ingest primitives) ---------------------------------------

    #: the ratings mask doubles as the validity channel, so padding
    #: user rows (mask all-zero) can absorb extend-kind appends — such
    #: rows are exactly inert until a delta lands (their push partials
    #: and residuals are zero, the W-phase keeps them at 0)
    supported_stream_kinds = ("replace", "extend")

    def ingest_specs(self):
        return {"leaves": ("A", "mask"),
                "valid": lambda data:
                    np.asarray(data["mask"]).any(axis=1)}

    def ingest(self, data, state, rows, delta):
        """Overwrite user rows (refreshed ratings, or new users landing
        in ring slots) and keep the residual invariant ``R = (A − WH) ·
        mask`` true on exactly those rows.  The W row is kept as a warm
        start (zero for never-touched padding slots); the next W-phase
        refits it against the new ratings."""
        rows = jnp.asarray(rows)
        A_new = jnp.asarray(delta["data"]["A"], jnp.float32)
        m_new = jnp.asarray(delta["data"]["mask"], jnp.float32)
        new_data = dict(data,
                        A=data["A"].at[rows].set(A_new),
                        mask=data["mask"].at[rows].set(m_new))
        if state is None:
            return new_data, None
        W_rows = jnp.take(state["W"], rows, axis=0)
        R = state["R"].at[rows].set(
            (A_new - W_rows @ state["H"]) * m_new)
        return new_data, dict(state, R=R)

    def objective_fn(self, mesh):
        cfg = self.cfg

        def local(R, W, H):
            sse = jnp.sum(R * R)
            wn = jnp.sum(W * W)
            tot = jax.lax.psum(sse + cfg.lam * wn, "data")
            return tot + cfg.lam * jnp.sum(H * H)

        fn = shard_map(local, mesh=mesh,
                       in_specs=(P("data"), P("data"), P()),
                       out_specs=P())
        return jax.jit(lambda s: fn(s["R"], s["W"], s["H"]))

    def objective_collect(self):
        """Global-expression objective for ``run_scanned`` collect."""
        lam = self.cfg.lam
        return lambda s: (jnp.sum(s["R"] * s["R"])
                          + lam * jnp.sum(s["W"] * s["W"])
                          + lam * jnp.sum(s["H"] * s["H"]))


# ---------------------------------------------------------------------------
# ALS baseline (GraphLab-style alternating least squares)
# ---------------------------------------------------------------------------

def als_step(A, mask, W, H, lam):
    """One full ALS alternation (dense masked closed-form solves)."""
    K = W.shape[1]
    eye = jnp.eye(K, dtype=W.dtype) * lam

    def solve_rows(Wrow_unused, a_row, m_row):
        # solve (Hᵀ diag(m) H + λI) w = Hᵀ diag(m) a
        G = (H * m_row) @ H.T + eye
        b = (H * m_row) @ a_row
        return jnp.linalg.solve(G, b)

    W = jax.vmap(solve_rows)(W, A, mask)

    def solve_cols(h_col_unused, a_col, m_col):
        G = (W.T * m_col) @ W + eye
        b = (W.T * m_col) @ a_col
        return jnp.linalg.solve(G, b)

    H = jax.vmap(solve_cols, in_axes=(1, 1, 1), out_axes=1)(H, A, mask)
    return W, H


def als_fit(A, mask, rank, lam, num_iters, rng):
    kw, kh = jax.random.split(rng)
    N, M = A.shape
    W = jax.random.normal(kw, (N, rank), jnp.float32) / jnp.sqrt(rank)
    H = jax.random.normal(kh, (rank, M), jnp.float32) / jnp.sqrt(rank)
    step = jax.jit(lambda W, H: als_step(A, mask, W, H, lam))
    trace = []
    for it in range(num_iters):
        W, H = step(W, H)
        R = (A - W @ H) * mask
        obj = float(jnp.sum(R * R) + lam * (jnp.sum(W * W) + jnp.sum(H * H)))
        trace.append((it, obj))
    return (W, H), trace


# ---------------------------------------------------------------------------
# Data + driver
# ---------------------------------------------------------------------------

def synthetic_ratings(rng: np.random.Generator, N: int, M: int,
                      true_rank: int, density: float = 0.3,
                      noise: float = 0.05):
    """Low-rank + noise ratings with a sparse observation mask."""
    Wt = rng.normal(0, 1, size=(N, true_rank)).astype(np.float32)
    Ht = rng.normal(0, 1, size=(true_rank, M)).astype(np.float32)
    A = (Wt @ Ht / np.sqrt(true_rank)).astype(np.float32)
    A += noise * rng.normal(0, 1, size=A.shape).astype(np.float32)
    mask = (rng.uniform(size=A.shape) < density).astype(np.float32)
    return A * mask, mask


def make_engine(cfg: MFConfig, mesh) -> StradsEngine:
    app = StradsMF(cfg)
    return StradsEngine(app, mesh, data_specs=app.data_specs(),
                        state_specs=app.state_specs())


def fit(cfg: MFConfig, A: np.ndarray, mask: np.ndarray, mesh,
        num_rounds: Optional[int] = None, rng: Optional[jax.Array] = None,
        trace_every=None, executor=None, staleness=None, plan=None):
    """``plan``: an :class:`~repro.core.ExecutionPlan` (see lasso.fit;
    legacy ``executor=``/``staleness=`` kwargs deprecated).  For
    "pipelined"/"ssp", the rounds must divide into H/W phase cycles (and
    SSP windows)."""
    plan = _exec.resolve_plan(plan, num_rounds=num_rounds,
                              executor=executor, staleness=staleness,
                              trace_every=trace_every)
    rng = rng if rng is not None else jax.random.key(0)
    eng = make_engine(cfg, mesh)
    data = eng.shard_data({"A": jnp.asarray(A), "mask": jnp.asarray(mask)})
    state = eng.init_state(rng, A=jnp.asarray(A), mask=jnp.asarray(mask))
    every = plan.collect_every

    if plan.executor != "loop":
        collect = eng.app.objective_collect() if every else None
        rep = eng.execute(state, data, rng, plan, collect=collect)
        if collect is None:
            return rep.state, []
        return rep.state, _exec.decimate(np.asarray(rep.trace),
                                         plan.rounds, every)

    obj = eng.app.objective_fn(mesh)
    trace = []

    def cb(t, s, out):
        if every and (t % every == 0 or t == plan.rounds - 1):
            trace.append((t, float(obj(s))))
        return False

    rep = eng.execute(state, data, rng, plan, callback=cb)
    return rep.state, trace
