"""The paper's three STRADS applications + their baselines.

* :mod:`repro.apps.lasso` — STRADS Lasso (dynamic priority + ρ-dependency
  filter) and Lasso-RR (Shotgun-style random scheduling baseline).
* :mod:`repro.apps.mf`    — STRADS Matrix Factorization (round-robin
  coordinate descent) and an ALS baseline (GraphLab-style).
* :mod:`repro.apps.lda`   — STRADS LDA (word-rotation collapsed Gibbs) and
  a data-parallel baseline (YahooLDA-style replicated word-topic table).
"""
