"""STRADS LDA (paper §3.1): word-rotation collapsed Gibbs sampling.

Model variables are the topic assignments z_ij; sufficient statistics are
the doc-topic table D and the word-topic table B (+ its column sums s).

schedule (word rotation): the vocabulary is split into U contiguous blocks
V_1..V_U; at round t worker p processes block (p + t) mod U, so blocks
rotate and every token is sampled exactly once per U rounds while
concurrently-sampled tokens always have *disjoint words and disjoint
documents* — the conditional-independence argument that keeps the
parallelization error tiny (the only shared quantity is s, synced each
pull; its drift is the paper's Fig-5 s-error, which we measure).

Layout (model parallelism — the Fig-3 memory claim):
  * B is sharded by word block: home shard u holds rows of block u
    (``(U·V_b, K)`` sharded over ``data``).  At round t the blocks rotate
    to their processing worker via a *static* ``lax.ppermute`` and rotate
    home afterwards — this is the schedule's communication pattern, and
    it is exactly why per-machine memory falls as 1/U.
  * D and z shard with the documents (each doc lives on one worker).
  * s (K,) is the synced KV-store value, replicated.

push: sequential collapsed Gibbs over the worker's tokens whose word lies
in its current block (a ``lax.scan``; within-worker sampling is exact),
using the worker's stale local copy s̃ — paper f₁.
pull: commit z/D/B locally; s ← psum of per-block column sums — paper f₂;
the automatic sync makes s consistent again.  The round also reports the
s-error Δ_t = (1/PM) Σ_p ‖s̃_p − s‖₁ (paper eq. 1).

The data-parallel baseline (:class:`DataParallelLDA`, YahooLDA-style)
replicates the *full* B on every worker, samples all local tokens against
the stale replica and merges table deltas at the end of the round — more
parallel error (every word conflicts) and O(V·K) memory per machine
regardless of cluster size.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import gammaln
from jax.sharding import PartitionSpec as P

from repro.core import StradsAppBase, StradsEngine
from repro.core.compat import shard_map
from repro.part import PartitionerSpec
from repro.sched import SchedulerSpec

from . import _exec


@dataclasses.dataclass(frozen=True)
class LDAConfig:
    vocab: int                   # V (padded up to U * block_vocab)
    num_topics: int              # K
    num_workers: int             # U (= data-axis size)
    tokens_per_worker: int       # T_p (padded)
    docs_per_worker: int         # local doc count
    alpha: float = 0.1           # doc-topic prior
    gamma: float = 0.1           # word-topic prior

    @property
    def block_vocab(self) -> int:
        return -(-self.vocab // self.num_workers)    # ceil

    @property
    def padded_vocab(self) -> int:
        return self.block_vocab * self.num_workers


def _gibbs_scan(cfg: LDAConfig, B, D, s, words, docs, z, active_mask,
                block_start, rng):
    """Sequential collapsed Gibbs over one worker's scheduled tokens.

    Exact within the worker (counts updated after every sample); the only
    stale quantity is s̃, which starts at the synced s."""
    K = cfg.num_topics

    def body(carry, tok):
        B, D, st, key = carry
        v, d, zi, active = tok
        a = active.astype(B.dtype)
        vloc = jnp.clip(v - block_start, 0, cfg.block_vocab - 1)
        # remove current assignment
        B = B.at[vloc, zi].add(-a)
        D = D.at[d, zi].add(-a)
        st = st.at[zi].add(-a)
        # conditional:  (γ+B[v,k]) / (Vγ+s̃[k]) · (α+D[d,k])
        logits = (jnp.log(cfg.gamma + B[vloc]) -
                  jnp.log(cfg.padded_vocab * cfg.gamma + st) +
                  jnp.log(cfg.alpha + D[d]))
        key, sub = jax.random.split(key)
        znew = jax.random.categorical(sub, logits)
        znew = jnp.where(active, znew, zi).astype(zi.dtype)
        # add back
        B = B.at[vloc, znew].add(a)
        D = D.at[d, znew].add(a)
        st = st.at[znew].add(a)
        return (B, D, st, key), znew

    (B, D, st, _), z_new = jax.lax.scan(
        body, (B, D, s, rng), (words, docs, z, active_mask))
    return B, D, st, z_new


class StradsLDA(StradsAppBase):
    """Word-rotation model-parallel collapsed Gibbs on STRADS primitives."""

    supported_scheduler_kinds = ("rotation",)
    # Gibbs sampling is gather/scan-bound, not matmul-bound: no Pallas
    # hot-spot exists, so a plan asking for one is rejected at injection.
    supported_kernel_kinds = ("reference",)

    def __init__(self, cfg: LDAConfig):
        self.cfg = cfg
        # one full rotation = U rounds; the scanned executor unrolls a
        # whole rotation per scan step so each ppermute stays static
        self.phase_period = cfg.num_workers

    def default_scheduler_spec(self) -> SchedulerSpec:
        # word-rotation over the U disjoint vocabulary blocks
        return SchedulerSpec(kind="rotation")

    def num_schedulable(self) -> int:
        return self.cfg.padded_vocab

    # The rotation's ppermute pattern *is* a frozen contiguous word→
    # worker map (RotationScheduler.bounds); ownership cannot move
    # without retiling B, so only the static partitioner applies — the
    # engine rejects anything else at injection time.  The static
    # assignment is bit-identical to the rotation bounds
    # (repro.part.contiguous_assignment shares the linspace).
    supported_partitioner_kinds = ("static",)

    def default_partitioner_spec(self) -> PartitionerSpec:
        return PartitionerSpec(kind="static")

    def static_phase(self, t: int) -> int:
        return t % self.cfg.num_workers

    def init_state(self, rng, words=None, docs=None, z0=None):
        if words is None:
            raise ValueError("StradsLDA.init_state needs the corpus "
                             "(words=, docs=, z0=)")
        return build_state(self.cfg, words, docs, z0)

    def state_specs(self):
        return {"z": P("data"), "D": P("data"), "B": P("data"),
                "s": P(), "s_err": P()}

    def data_specs(self):
        return {"words": P("data"), "docs": P("data")}

    # -- push / pull ----------------------------------------------------------

    def push(self, data, state, sched, phase):
        cfg = self.cfg
        # the injected rotation policy owns the block↔worker assignment
        # and the (static) ppermute communication pattern it implies
        p_fwd = self.scheduler.forward_perm(phase)         # block → worker
        B = jax.lax.ppermute(state["B"], "data", p_fwd)

        p = jax.lax.axis_index("data")
        block = self.scheduler.block_for_worker(p, phase)
        block_start = block * cfg.block_vocab
        words, docs, z = data["words"], data["docs"], state["z"]
        active = (words >= 0) & (words // cfg.block_vocab == block)

        rng = jax.random.fold_in(jax.random.key(17), phase)
        rng = jax.random.fold_in(rng, p)

        B, D, s_tilde, z_new = _gibbs_scan(
            cfg, B, state["D"], state["s"], words, docs, z, active,
            block_start, rng)

        # send the processed block home
        p_bwd = self.scheduler.backward_perm(phase)
        B_home = jax.lax.ppermute(B, "data", p_bwd)

        # partials for pull: fresh column sums + s-error numerator
        s_partial = jnp.sum(B, axis=0)                    # this block's sums
        partial = {"s": s_partial}
        local = {"z": z_new, "D": D, "B": B_home, "s_tilde": s_tilde}
        return partial, local

    def pull(self, state, sched, z, local, data, phase):
        cfg = self.cfg
        s_new = z["s"]                                    # synced (psummed)
        # Fig-5 s-error: (1/PM) Σ_p ‖s̃_p − s_new‖₁   (M = total tokens)
        err_p = jnp.sum(jnp.abs(local["s_tilde"] - s_new))
        M = cfg.num_workers * cfg.tokens_per_worker
        s_err = jax.lax.psum(err_p, "data") / (cfg.num_workers * M)
        return {"z": local["z"], "D": local["D"], "B": local["B"],
                "s": s_new, "s_err": s_err}

    # SSP behavior is fully derived from the placement above (v2 write
    # contract, repro.core.primitives): ``local``'s z/D/B name the
    # worker-resident state leaves, so they commit through every round (a
    # worker's own Gibbs moves are never re-sampled from a stale table);
    # only ``s_tilde`` defers to the flush, where ``pull`` replays — the
    # LightLDA-style staleness-tolerant server, where s̃ is exactly the
    # stale quantity the paper's Fig-5 error bound is about.

    # -- serving (query primitive) -------------------------------------------

    #: fixed fold-in iterations for query() (static, so one jitted
    #: program serves every batch)
    query_iters: int = 8

    def query(self, state, batch):
        """``infer_topics``: fold a batch of unseen documents into the
        trained topics (batch ``{"words": (B, L)}``, -1-padded, →
        ``{"theta": (B, K), "top_topic": (B,)}``).

        A fixed-iteration mean-field fold-in (the deterministic twin of
        fold-in Gibbs): φ_lk ∝ (γ+B[v_l,k]) / (Vγ+s[k]) holds the topics
        fixed and θ is re-estimated ``query_iters`` times.  B is
        worker-resident (read live at the boundary); s is the
        server-resident leaf — so the only stale ingredient under
        ``kind="stale"`` is s̃, exactly the quantity the paper's Fig-5
        error bound is about."""
        cfg = self.cfg
        words = batch["words"]                              # (B, L)
        v = jnp.clip(words, 0, cfg.padded_vocab - 1)
        active = (words >= 0)[..., None]                    # (B, L, 1)
        phi = ((cfg.gamma + state["B"][v]) /
               (cfg.padded_vocab * cfg.gamma + state["s"]))  # (B, L, K)
        phi = jnp.where(active, phi, 1.0)
        theta = jnp.full(words.shape[:1] + (cfg.num_topics,),
                         1.0 / cfg.num_topics, jnp.float32)
        for _ in range(self.query_iters):
            q = phi * theta[:, None, :]
            q = q / jnp.maximum(jnp.sum(q, -1, keepdims=True), 1e-30)
            q = jnp.where(active, q, 0.0)
            theta = cfg.alpha + jnp.sum(q, axis=1)
            theta = theta / jnp.sum(theta, -1, keepdims=True)
        return {"theta": theta, "top_topic": jnp.argmax(theta, axis=-1)}

    # -- streaming (ingest primitives) ---------------------------------------

    #: token slots with word -1 are exactly the padding the Gibbs scan
    #: already skips (``active``), so they double as the extend-kind
    #: validity channel — 1411.2305-style doc-shard streaming
    supported_stream_kinds = ("replace", "extend")

    def ingest_specs(self):
        return {"leaves": ("words", "docs"),
                "valid": lambda data: np.asarray(data["words"]) >= 0}

    def ingest(self, data, state, rows, delta):
        """Swap token slots (new tokens into padding/oldest slots, or
        resampled replacements) and keep the collapsed counts exact:
        each displaced active token is decremented out of D/B/s, each
        incoming one (topic draw ``delta["z"]``) incremented in.  Word
        -1 in a delta deletes the slot's token."""
        cfg = self.cfg
        Tp, dpw = cfg.tokens_per_worker, cfg.docs_per_worker
        slots = np.asarray(rows, np.int64)
        w_new = np.asarray(delta["data"]["words"], np.int32)
        d_new = np.asarray(delta["data"]["docs"], np.int32)
        if w_new.max(initial=-1) >= cfg.vocab or \
                w_new.min(initial=0) < -1:
            raise ValueError(f"ingested words out of [-1, {cfg.vocab})")
        if d_new.size and (d_new.min() < 0 or d_new.max() >= dpw):
            raise ValueError(f"ingested docs out of [0, {dpw}) (doc ids "
                             f"are worker-local)")
        new_data = dict(data,
                        words=data["words"].at[slots].set(
                            jnp.asarray(w_new)),
                        docs=data["docs"].at[slots].set(
                            jnp.asarray(d_new)))
        if state is None:
            return new_data, None
        z_new = np.asarray(delta["z"], np.int32)
        if z_new.size and (z_new.min() < 0
                           or z_new.max() >= cfg.num_topics):
            raise ValueError(f"ingested z out of [0, {cfg.num_topics})")
        u = slots // Tp                        # owning worker per slot
        w_old = np.asarray(data["words"])[slots]
        d_old = np.asarray(data["docs"])[slots]
        z = np.array(np.asarray(state["z"]))
        z_old = z[slots]
        D = np.array(np.asarray(state["D"]))
        B = np.array(np.asarray(state["B"]))
        s = np.array(np.asarray(state["s"]))
        out = w_old >= 0                       # displaced active tokens
        np.add.at(B, (w_old[out], z_old[out]), -1)
        np.add.at(D, (u[out] * dpw + d_old[out], z_old[out]), -1)
        np.add.at(s, z_old[out], -1)
        inn = w_new >= 0                       # arriving active tokens
        np.add.at(B, (w_new[inn], z_new[inn]), 1)
        np.add.at(D, (u[inn] * dpw + d_new[inn], z_new[inn]), 1)
        np.add.at(s, z_new[inn], 1)
        z[slots] = z_new
        return new_data, dict(state, z=jnp.asarray(z), D=jnp.asarray(D),
                              B=jnp.asarray(B), s=jnp.asarray(s))

    # -- diagnostics ------------------------------------------------------------

    def loglik_fn(self, mesh):
        """Collapsed joint log P(W, Z) up to constants (convergence metric)."""
        cfg = self.cfg

        def local(B, D, s):
            lb = jnp.sum(gammaln(B + cfg.gamma))
            ld = jnp.sum(gammaln(D + cfg.alpha)) \
                - jnp.sum(gammaln(jnp.sum(D, 1) + cfg.num_topics * cfg.alpha))
            tot = jax.lax.psum(lb + ld, "data")
            return tot - jnp.sum(gammaln(s + cfg.padded_vocab * cfg.gamma))

        fn = shard_map(local, mesh=mesh,
                       in_specs=(P("data"), P("data"), P()),
                       out_specs=P())
        return jax.jit(lambda st: fn(st["B"], st["D"], st["s"]))


# ---------------------------------------------------------------------------
# Data-parallel baseline (YahooLDA-style)
# ---------------------------------------------------------------------------

def _full_gibbs_scan(cfg: LDAConfig, B, D, s, words, docs, z, active_mask,
                     rng):
    """Gibbs over the full vocab table (data-parallel baseline)."""
    def body(carry, tok):
        B, D, st, key = carry
        v, d, zi, active = tok
        a = active.astype(B.dtype)
        vc = jnp.clip(v, 0, cfg.padded_vocab - 1)
        B = B.at[vc, zi].add(-a)
        D = D.at[d, zi].add(-a)
        st = st.at[zi].add(-a)
        logits = (jnp.log(cfg.gamma + B[vc]) -
                  jnp.log(cfg.padded_vocab * cfg.gamma + st) +
                  jnp.log(cfg.alpha + D[d]))
        key, sub = jax.random.split(key)
        znew = jax.random.categorical(sub, logits)
        znew = jnp.where(active, znew, zi).astype(zi.dtype)
        B = B.at[vc, znew].add(a)
        D = D.at[d, znew].add(a)
        st = st.at[znew].add(a)
        return (B, D, st, key), znew

    (B, D, st, _), z_new = jax.lax.scan(
        body, (B, D, s, rng), (words, docs, z, active_mask))
    return B, D, st, z_new


class DataParallelLDAApp(StradsAppBase):
    """Working data-parallel baseline app."""

    def __init__(self, cfg: LDAConfig):
        self.cfg = cfg

    def init_state(self, rng, words=None, docs=None, z0=None):
        if words is None:
            raise ValueError("DataParallelLDAApp.init_state needs the "
                             "corpus (words=, docs=, z0=)")
        full = build_state(self.cfg, words, docs, z0)
        return {k: full[k] for k in ("z", "D", "B", "s")}

    def state_specs(self):
        return {"z": P("data"), "D": P("data"), "B": P(), "s": P()}

    def data_specs(self):
        return {"words": P("data"), "docs": P("data")}

    def push(self, data, state, sched, phase):
        cfg = self.cfg
        words, docs, z = data["words"], data["docs"], state["z"]
        active = words >= 0
        p = jax.lax.axis_index("data")
        rng = jax.random.fold_in(jax.random.key(23), p)
        B, D, s_tilde, z_new = _full_gibbs_scan(
            cfg, state["B"], state["D"], state["s"], words, docs, z,
            active, rng)
        partial = {"dB": B - state["B"]}
        local = {"z": z_new, "D": D}
        return partial, local

    def pull(self, state, sched, z, local, data, phase):
        B = state["B"] + z["dB"]                 # merge stale deltas
        s = jnp.sum(B, axis=0)
        return {"z": local["z"], "D": local["D"], "B": B, "s": s}


# ---------------------------------------------------------------------------
# Synthetic corpus + drivers
# ---------------------------------------------------------------------------

def synthetic_corpus(rng: np.random.Generator, cfg: LDAConfig,
                     true_topics: int = 10, concentration: float = 0.05):
    """Draw a corpus from a planted LDA model (so likelihood climbs are
    meaningful).  Returns (words, docs, z_init) flat arrays laid out as
    num_workers contiguous shards."""
    U, Tp, dpw = cfg.num_workers, cfg.tokens_per_worker, cfg.docs_per_worker
    V, K = cfg.vocab, cfg.num_topics
    topics = rng.dirichlet([concentration] * V, size=true_topics)
    words = np.full((U * Tp,), -1, np.int32)
    docs = np.zeros((U * Tp,), np.int32)
    for u in range(U):
        for i in range(Tp):
            d = rng.integers(dpw)
            theta = rng.dirichlet([0.3] * true_topics)
            k = rng.choice(true_topics, p=theta)
            v = rng.choice(V, p=topics[k])
            words[u * Tp + i] = v
            docs[u * Tp + i] = d
    z0 = rng.integers(0, K, size=(U * Tp,)).astype(np.int32)
    return words, docs, z0


def build_state(cfg: LDAConfig, words, docs, z0):
    """Materialize consistent D, B, s from the initial assignments."""
    U, Tp, dpw = cfg.num_workers, cfg.tokens_per_worker, cfg.docs_per_worker
    Vp, K = cfg.padded_vocab, cfg.num_topics
    D = np.zeros((U * dpw, K), np.float32)
    B = np.zeros((Vp, K), np.float32)
    for u in range(U):
        for i in range(Tp):
            v, d, k = words[u * Tp + i], docs[u * Tp + i], z0[u * Tp + i]
            if v < 0:
                continue
            D[u * dpw + d, k] += 1
            B[v, k] += 1
    s = B.sum(axis=0).astype(np.float32)
    return {"z": jnp.asarray(z0), "D": jnp.asarray(D), "B": jnp.asarray(B),
            "s": jnp.asarray(s), "s_err": jnp.float32(0)}


def make_engine(cfg: LDAConfig, mesh, baseline: bool = False) -> StradsEngine:
    app = DataParallelLDAApp(cfg) if baseline else StradsLDA(cfg)
    return StradsEngine(app, mesh, data_specs=app.data_specs(),
                        state_specs=app.state_specs())


def _global_loglik(cfg: LDAConfig, state):
    """The collapsed log P(W, Z) as a plain global expression (equal to the
    shard_map reduction — psum of per-shard sums is the global sum), so it
    can run as a ``run_scanned`` collect fn inside the scan."""
    lb = jnp.sum(gammaln(state["B"] + cfg.gamma))
    ld = jnp.sum(gammaln(state["D"] + cfg.alpha)) \
        - jnp.sum(gammaln(jnp.sum(state["D"], 1)
                          + cfg.num_topics * cfg.alpha))
    return lb + ld - jnp.sum(gammaln(state["s"]
                                     + cfg.padded_vocab * cfg.gamma))


def fit(cfg: LDAConfig, words, docs, z0, mesh, num_rounds=None,
        baseline: bool = False, trace_every=None,
        executor=None, staleness=None, plan=None):
    """``plan``: an :class:`~repro.core.ExecutionPlan` (see lasso.fit;
    legacy ``executor=``/``staleness=`` kwargs deprecated).  For
    "pipelined"/"ssp", the rounds must tile the rotation length U (and
    the SSP window)."""
    plan = _exec.resolve_plan(plan, num_rounds=num_rounds,
                              executor=executor, staleness=staleness,
                              trace_every=trace_every)
    eng = make_engine(cfg, mesh, baseline=baseline)
    data = eng.shard_data({"words": jnp.asarray(words),
                           "docs": jnp.asarray(docs)})
    state = eng.init_state(jax.random.key(0), words=words, docs=docs,
                           z0=z0)
    every = plan.collect_every

    if plan.executor != "loop":
        collect = None
        if every:
            def collect(s):
                out = {"ll": _global_loglik(cfg, s)}
                if "s_err" in s:
                    out["s_err"] = s["s_err"]
                return out
        rep = eng.execute(state, data, jax.random.key(0), plan,
                          collect=collect)
        if collect is None:
            return rep.state, [], []
        ys = rep.trace
        trace = _exec.decimate(np.asarray(ys["ll"]), plan.rounds, every)
        s_errs = (_exec.decimate(np.asarray(ys["s_err"]), plan.rounds,
                                 every) if "s_err" in ys else [])
        return rep.state, trace, s_errs

    llfn = StradsLDA(cfg).loglik_fn(mesh) if not baseline else \
        _baseline_loglik(cfg, mesh)
    trace, s_errs = [], []

    def cb(t, s, out):
        if every and (t % every == 0 or t == plan.rounds - 1):
            trace.append((t, float(llfn(s))))
            if "s_err" in s:
                s_errs.append((t, float(s["s_err"])))
        return False

    rep = eng.execute(state, data, jax.random.key(0), plan, callback=cb)
    return rep.state, trace, s_errs


def _baseline_loglik(cfg: LDAConfig, mesh):
    def local(B, D, s):
        ld = jnp.sum(gammaln(D + cfg.alpha)) \
            - jnp.sum(gammaln(jnp.sum(D, 1) + cfg.num_topics * cfg.alpha))
        tot = jax.lax.psum(ld, "data")
        lb = jnp.sum(gammaln(B + cfg.gamma))
        return tot + lb - jnp.sum(gammaln(s + cfg.padded_vocab * cfg.gamma))

    fn = shard_map(local, mesh=mesh, in_specs=(P(), P("data"), P()),
                   out_specs=P())
    return jax.jit(lambda st: fn(st["B"], st["D"], st["s"]))
