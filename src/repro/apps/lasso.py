"""STRADS Lasso (paper §3.3) and the Lasso-RR baseline.

Problem:   min_β ½‖y − Xβ‖² + λ‖β‖₁        (X standardized, no intercept)
CD update: β_j ← S(x_jᵀy − Σ_{k≠j} x_jᵀx_k β_k, λ)   with soft-threshold S.

With columns normalized to unit L2 norm and residual r = y − Xβ the update
is β_j ← S(x_jᵀ r + β_j, λ), and the distributed push computes the partial
dot products  z_{j,p} = (x_j^p)ᵀ r^p  over worker p's row shard (paper
eq. 6, rearranged through the residual — algebraically identical, O(n·U)
per round instead of O(n·J)).

schedule (STRADS, dynamic — ``SchedulerSpec(kind="dynamic_priority")``):
  1. propose U′ candidates with prob c_j ∝ |β_j^(t−1) − β_j^(t−2)| + η  (f₁)
  2. schedule_stats: candidate Gram block G = Σ_p (X_C^p)ᵀ X_C^p  (psum)
  3. greedy ρ-filter: keep ≤ U candidates with pairwise |x_jᵀx_k| < ρ (f₂)

schedule (Lasso-RR baseline — ``kind="random"``): U uniform-random
coordinates, no filter — imitating Shotgun [Bradley et al. 2011], which
diverges on correlated designs when U is large.

push:  z_{j,p} = (x_j^p)ᵀ r^p                                  (f₃)
pull:  β_j ← S(Σ_p z_{j,p} + β_j, λ);  r^p ← r^p − X_B^p Δβ_B  (f₄ + sync)

The policy is injected (v2 scheduler-injection contract): the app only
declares its default ``SchedulerSpec`` (from ``cfg.scheduler``) and
consumes whatever the plan resolves — swapping ρ/U′/kind is a plan edit,
not an app change.  The Δβ priority history is the engine-owned scheduler
carry (``EngineCarry.sched_carry``), no longer a state leaf.

The compute hot-spots follow the same contract (kernel-injection): the
push partials and the ρ-filter Gram block dispatch through
``self.kernels`` — the backend the engine resolves from
``plan.kernels`` (a :class:`~repro.kernels.spec.KernelSpec`) — so
swapping the reference jnp oracles for the fused Pallas kernels is a
plan edit too.  ``cfg.kernel_backend`` survives as the *default* the
app declares when the plan leaves ``kernels=None``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import StradsAppBase, StradsEngine
from repro.core.compat import shard_map
from repro.kernels import KernelSpec, build_kernels
from repro.part import PartitionerSpec
from repro.sched import SchedulerSpec

from . import _exec


def soft_threshold(x: jax.Array, lam: float) -> jax.Array:
    """S(x, λ) = sign(x)·max(|x| − λ, 0)  (Friedman et al., 2007)."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - lam, 0.0)


@dataclasses.dataclass(frozen=True)
class LassoConfig:
    num_features: int            # J
    lam: float = 0.1             # λ
    block_size: int = 8          # U  — concurrent updates per round
    num_candidates: int = 32     # U′ — proposal pool (STRADS only)
    rho: float = 0.3             # ρ  — dependency threshold (STRADS only)
    eta: float = 1e-6            # η  — priority floor
    scheduler: str = "strads"    # "strads" | "rr" (random) | "cyclic"
    # Default hot-spot kernel backend when the plan leaves kernels=None
    # ("auto" = pallas on TPU, reference elsewhere); a plan-level
    # KernelSpec always wins.
    kernel_backend: str = "auto"  # auto | ref | interpret | pallas


class StradsLasso(StradsAppBase):
    """The paper's Lasso on STRADS primitives; the scheduler arrives by
    injection, so the Lasso-RR baseline is literally the same app with a
    ``kind="random"`` spec (exactly how the paper built its baseline)."""

    supported_scheduler_kinds = ("dynamic_priority", "random",
                                 "round_robin")
    supported_kernel_kinds = ("reference", "pallas")

    def __init__(self, cfg: LassoConfig):
        self.cfg = cfg

    # -- scheduler injection -------------------------------------------------

    def default_scheduler_spec(self) -> SchedulerSpec:
        cfg = self.cfg
        if cfg.scheduler == "strads":
            return SchedulerSpec(kind="dynamic_priority",
                                 block_size=cfg.block_size,
                                 num_candidates=cfg.num_candidates,
                                 rho=cfg.rho, eta=cfg.eta)
        if cfg.scheduler == "rr":
            return SchedulerSpec(kind="random", block_size=cfg.block_size)
        if cfg.scheduler == "cyclic":
            return SchedulerSpec(kind="round_robin",
                                 block_size=cfg.block_size)
        raise ValueError(f"LassoConfig.scheduler must be 'strads', 'rr' "
                         f"or 'cyclic'; got {cfg.scheduler!r}")

    def num_schedulable(self) -> int:
        return self.cfg.num_features

    # -- kernel injection ----------------------------------------------------

    def default_kernel_spec(self) -> KernelSpec:
        kb = self.cfg.kernel_backend
        if kb == "auto":
            if jax.default_backend() == "tpu":
                return KernelSpec.default_for("pallas")
            return KernelSpec(kind="reference")
        if kb == "ref":
            return KernelSpec(kind="reference")
        if kb in ("pallas", "interpret"):
            # build_kernels flips interpret mode from the live platform,
            # so both legacy names resolve to the same spec.
            return KernelSpec.default_for("pallas")
        raise ValueError(f"LassoConfig.kernel_backend must be 'auto', "
                         f"'ref', 'interpret' or 'pallas'; got {kb!r}")

    def _kernels(self):
        # Engine-less direct calls (tests poking push/schedule_stats)
        # lazily self-inject the config default; under an engine the
        # resolved plan backend is already installed via use_kernels.
        if self.kernels is None:
            self.kernels = build_kernels(self.default_kernel_spec())
        return self.kernels

    # -- partition injection -------------------------------------------------
    # Coefficients are interchangeable, so every partition kind applies:
    # the ownership map is model-store bookkeeping (which worker serves
    # β_j), and the load balancer's activity signal is |Δβ| — the same
    # quantity the dynamic scheduler's priorities track.

    supported_partitioner_kinds = ("static", "size_balanced",
                                   "load_balanced")

    def default_partitioner_spec(self) -> PartitionerSpec:
        return PartitionerSpec(kind="static")

    def partition_signal(self, state):
        return state["beta"]

    @property
    def needs_schedule_stats(self) -> bool:
        # the Gram ρ-filter is the only policy needing the stats psum
        return self.scheduler is not None and self.scheduler.needs_stats

    # -- state: β (replicated), r (row-sharded) ------------------------------
    # (the Δβ priority history is the injected scheduler's carry, owned by
    # the engine — see EngineCarry.sched_carry)

    def init_state(self, rng, y=None):
        J = self.cfg.num_features
        if y is None:
            raise ValueError("StradsLasso.init_state needs y (the initial "
                             "residual r = y at β = 0)")
        return {
            "beta": jnp.zeros((J,), jnp.float32),
            "r": jnp.asarray(y, jnp.float32),       # r = y − Xβ, β=0
        }

    def state_specs(self):
        return {"beta": P(), "r": P("data")}

    def data_specs(self):
        return {"X": P("data"), "y": P("data")}

    # -- schedule ------------------------------------------------------------

    def propose(self, state, carry, rng, t, phase):
        return self.scheduler.propose(carry, rng, t, phase)

    def schedule_stats(self, data, state, candidates, phase):
        # Candidate Gram block over this worker's rows: (X_C^p)ᵀ X_C^p —
        # the ρ-filter hot-spot, served by the injected gram_block kernel.
        Xc = jnp.take(data["X"], candidates, axis=1)
        return self._kernels().gram_block(Xc)

    def schedule(self, state, carry, candidates, stats, rng, t, phase):
        idx, mask = self.scheduler.finalize(candidates, stats)
        return {"idx": idx, "mask": mask}

    def sched_update(self, carry, before, after, sched, phase):
        # Feed the committed Δβ of the scheduled block back into the
        # policy (f₁'s priority signal); stateless policies ignore it.
        if carry is None:
            return carry
        idx, mask = sched["idx"], sched["mask"]
        dx = jnp.take(after["beta"], idx) - jnp.take(before["beta"], idx)
        return self.scheduler.update_carry(carry, idx, mask, dx)

    # -- push / pull ----------------------------------------------------------

    def push(self, data, state, sched, phase):
        # z_{j,p} = (x_j^p)ᵀ r^p for each scheduled j (paper f₃) — the
        # push hot-spot, served by the injected lasso_partial kernel.
        Xb = jnp.take(data["X"], sched["idx"], axis=1)   # (n_p, U)
        z = self._kernels().lasso_partial(Xb, state["r"])
        return z, None

    def pull(self, state, sched, z, local, data, phase):
        cfg = self.cfg
        idx, mask = sched["idx"], sched["mask"]
        beta_old = jnp.take(state["beta"], idx)
        beta_new = soft_threshold(z + beta_old, cfg.lam)
        beta_new = jnp.where(mask, beta_new, beta_old)
        d = beta_new - beta_old

        # Guard duplicate indices from masked padding: only first occurrence
        # applies (mask already ensures kept indices are distinct).
        beta = state["beta"].at[idx].set(
            jnp.where(mask, beta_new, jnp.take(state["beta"], idx)))

        # residual maintenance on this worker's rows (the automatic sync of
        # the shared quantity r):  r ← r − X_B Δβ
        Xb = jnp.take(data["X"], idx, axis=1)
        r = state["r"] - Xb @ (d * mask)
        return {"beta": beta, "r": r}

    # -- serving (query primitive) -------------------------------------------

    def query(self, state, batch):
        """``predict``: ŷ = xᵀβ per request row (batch ``{"x": (B, J)}``
        → ``{"y_hat": (B,)}``).  Only β is read — the server-resident
        leaf, so under ``ServeSpec(kind="stale")`` a prediction is
        exactly as stale as an SSP worker's own read of β."""
        return {"y_hat": batch["x"] @ state["beta"]}

    # -- streaming (ingest primitives) ---------------------------------------

    #: every observation row is real (no validity channel to derive an
    #: extend-kind ring mask from), so only in-place replacement streams
    supported_stream_kinds = ("replace",)

    def ingest_specs(self):
        return {"leaves": ("X", "y"), "valid": None}

    def ingest(self, data, state, rows, delta):
        """Overwrite observation rows and keep the residual invariant
        ``r = y − Xβ`` true on exactly those rows (β is untouched — the
        next scheduled rounds react to the new data through r)."""
        rows = jnp.asarray(rows)
        X_new = jnp.asarray(delta["data"]["X"], jnp.float32)
        y_new = jnp.asarray(delta["data"]["y"], jnp.float32)
        new_data = dict(data,
                        X=data["X"].at[rows].set(X_new),
                        y=data["y"].at[rows].set(y_new))
        if state is None:
            return new_data, None
        r = state["r"].at[rows].set(y_new - X_new @ state["beta"])
        return new_data, dict(state, r=r)

    # -- objective -------------------------------------------------------------

    def objective_fn(self, mesh):
        """½‖y−Xβ‖² + λ‖β‖₁ as a jitted distributed reduction."""
        cfg = self.cfg

        def local(r, beta):
            sse = 0.5 * jnp.sum(r * r)
            return jax.lax.psum(sse, "data") + cfg.lam * jnp.sum(jnp.abs(beta))

        fn = shard_map(local, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P())
        return jax.jit(lambda state: fn(state["r"], state["beta"]))

    def objective_collect(self):
        """Same objective as a global (non-shard_map) expression, usable as
        a ``run_scanned`` collect fn inside the scan trace."""
        lam = self.cfg.lam
        return lambda s: (0.5 * jnp.sum(s["r"] * s["r"])
                          + lam * jnp.sum(jnp.abs(s["beta"])))


# ---------------------------------------------------------------------------
# Data generation (paper §4.1) + driver
# ---------------------------------------------------------------------------

def synthetic_correlated(rng: np.random.Generator, n: int, J: int,
                         corr: float = 0.9, k_true: int = 10,
                         noise: float = 0.1):
    """The paper's correlated synthetic design, dense laptop-scale variant.

    x₁ ~ U(0,1) noise; for j ≥ 2, with prob ``corr`` x_j gets fresh noise,
    otherwise x_j = 0.9·ε_{j−1} + 0.1·U(0,1) — adjacent features strongly
    correlated, which is exactly what breaks naive parallel CD.  Columns
    are standardized (zero mean, unit L2), y from a k_true-sparse β*.
    """
    eps = rng.uniform(0, 1, size=(n, J)).astype(np.float32)
    X = np.empty((n, J), np.float32)
    X[:, 0] = eps[:, 0]
    for j in range(1, J):
        fresh = rng.uniform() < corr
        X[:, j] = eps[:, j] if fresh else 0.9 * X[:, j - 1] + 0.1 * eps[:, j]
    X -= X.mean(axis=0)
    X /= np.maximum(np.linalg.norm(X, axis=0), 1e-12)
    beta_star = np.zeros((J,), np.float32)
    support = rng.choice(J, size=k_true, replace=False)
    beta_star[support] = rng.normal(0, 1, size=k_true).astype(np.float32)
    y = X @ beta_star + noise * rng.normal(0, 1, size=n).astype(np.float32)
    y = (y - y.mean()).astype(np.float32)
    return X, y, beta_star


def make_engine(cfg: LassoConfig, mesh,
                scheduler: Optional[SchedulerSpec] = None) -> StradsEngine:
    app = StradsLasso(cfg)
    return StradsEngine(app, mesh, data_specs=app.data_specs(),
                        state_specs=app.state_specs(), scheduler=scheduler)


def fit(cfg: LassoConfig, X: np.ndarray, y: np.ndarray, mesh,
        num_rounds: Optional[int] = None, rng: Optional[jax.Array] = None,
        trace_every: Optional[int] = None, executor: Optional[str] = None,
        staleness: Optional[int] = None, plan=None):
    """Run STRADS Lasso; returns (state, trace of objective values).

    ``plan`` (an :class:`~repro.core.ExecutionPlan`) declares how to run:
    executor (``"loop"`` host loop / ``"scan"`` one ``lax.scan`` program,
    bit-identical to the loop / ``"pipelined"`` one-round-stale schedule
    prefetch / ``"ssp"`` bounded staleness, at s=0 bit-identical to
    ``"scan"``), rounds, the ``collect_every`` trace cadence, and the
    scheduling policy (``plan.scheduler``, a ``SchedulerSpec`` — ``None``
    runs the config's default policy).  The legacy
    ``executor=``/``staleness=``/``trace_every=`` kwargs still work
    (deprecated, bit-identical).
    """
    plan = _exec.resolve_plan(plan, num_rounds=num_rounds,
                              executor=executor, staleness=staleness,
                              trace_every=trace_every)
    rng = rng if rng is not None else jax.random.key(0)
    eng = make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    state = eng.init_state(rng, y=y)
    every = plan.collect_every

    if plan.executor != "loop":
        collect = eng.app.objective_collect() if every else None
        rep = eng.execute(state, data, rng, plan, collect=collect)
        if collect is None:
            return rep.state, []
        return rep.state, _exec.decimate(np.asarray(rep.trace),
                                         plan.rounds, every)

    obj = eng.app.objective_fn(mesh)
    trace = []

    def cb(t, s, out):
        if every and (t % every == 0 or t == plan.rounds - 1):
            trace.append((t, float(obj(s))))
        return False

    rep = eng.execute(state, data, rng, plan, callback=cb)
    return rep.state, trace


def reference_cd(X: np.ndarray, y: np.ndarray, lam: float,
                 num_sweeps: int) -> np.ndarray:
    """Single-machine cyclic CD oracle (ground truth for tests)."""
    J = X.shape[1]
    beta = np.zeros((J,), np.float32)
    r = y.copy()
    for _ in range(num_sweeps):
        for j in range(J):
            zj = X[:, j] @ r + beta[j]
            bj = np.sign(zj) * max(abs(zj) - lam, 0.0)
            r -= X[:, j] * (bj - beta[j])
            beta[j] = bj
    return beta
