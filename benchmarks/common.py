"""Shared benchmark utilities: result I/O, subprocess runner for
multi-device benches (the parent process must keep 1 CPU device)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(ROOT, "benchmarks", "results")


def save(name: str, payload) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def run_sub(code: str, devices: int = 4, timeout: int = 540) -> str:
    """Run ``code`` in a subprocess with ``devices`` forced host devices;
    returns stdout (the child prints a JSON payload on its last line)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-4000:])
    return out.stdout


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
        return False
