"""SSP executor benchmark: the staleness/convergence trade-off.

For 1/2/4 forced host devices, run STRADS Lasso under the BSP scan
baseline and the SSP executor at staleness s ∈ {0, 1, 2, 4}, reporting
rounds/sec (compile excluded, best of two timed repetitions) AND the
objective-vs-round curve — so the SSP literature's claim (bounded-stale
reads trade a controlled amount of per-round progress for throughput) is
reproduced as data, not asserted.  Per window of s+1 rounds the SSP
program issues one batched flush collective instead of one pull psum per
round; on forced host devices (shared cores) the collective saving is
modest, so the expectation here is ssp(s≥1) ≥ scan, with the real win on
multi-chip meshes.

Also records the staleness telemetry (max observed read staleness — must
equal s — plus flush count and push/pull byte accounting).  The sweep is
a dict of :class:`repro.core.ExecutionPlan` values run through
``StradsEngine.execute``; the BENCH json embeds every plan dict, so the
cross-PR trajectory records exactly what was measured.

Writes ``benchmarks/results/BENCH_ssp.json`` for the cross-PR perf
trajectory.
"""
from __future__ import annotations

import json

from .common import run_sub, save

_CODE = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.apps import lasso
from repro.core import ExecutionPlan, worker_mesh
from repro.obs import TelemetrySpec

U, R = {workers}, {rounds}
rng = np.random.default_rng(0)
X, y, _ = lasso.synthetic_correlated(rng, n={rows}, J={feats}, k_true=10)
cfg = lasso.LassoConfig(num_features={feats}, lam=0.02, block_size=16,
                        num_candidates=64, rho=0.3)
mesh = worker_mesh(U)
eng = lasso.make_engine(cfg, mesh)
data = eng.shard_data({{"X": jnp.asarray(X), "y": jnp.asarray(y)}})
init = lambda: eng.init_state(jax.random.key(0), y=y)
collect = eng.app.objective_collect()

# The sweep is a dict of ExecutionPlans through the one entry point.
plans = {{"scan": ExecutionPlan(executor="scan", rounds=R)}}
for s in (0, 1, 2, 4):
    plans[f"s{{s}}"] = ExecutionPlan(executor="ssp", rounds=R, staleness=s)

run = lambda st, plan: eng.execute(st, data, jax.random.key(1), plan).state

for plan in plans.values():                  # compile warmup, all first
    run(init(), plan)

# Interleaved best-of-3: a slow minute on a shared box hits every
# config, not whichever happened to be measured during it.
best = {{name: 0.0 for name in plans}}
for _ in range(3):
    for name, plan in plans.items():
        st = init()
        t0 = time.time()
        jax.block_until_ready(run(st, plan))
        best[name] = max(best[name], R / (time.time() - t0))

out = {{"scan": best["scan"], "ssp": {{}},
       "plans": {{n: p.to_json() for n, p in plans.items()}}}}
for s in (0, 1, 2, 4):
    plan = ExecutionPlan(executor="ssp", rounds=R, staleness=s,
                         collect_every=1,
                         telemetry=TelemetrySpec(kind="counters"))
    rep = eng.execute(init(), data, jax.random.key(1), plan,
                      collect=collect)
    obj = np.asarray(rep.trace)
    stride = max(1, R // 20)
    out["ssp"][s] = {{
        "rounds_per_sec": best[f"s{{s}}"],
        "objective": [float(v) for v in obj[::stride]] + [float(obj[-1])],
        "telemetry": rep.telemetry.to_json(),
        "plan": plan.to_json(),
    }}
print("PAYLOAD:" + json.dumps(out))
"""


def run(quick: bool = True):
    # 120/600 are divisible by every SSP window (s+1 for s in 0,1,2,4);
    # long enough that one timed run is ~0.2s, not timer noise
    rounds = 120 if quick else 600
    rows, feats = (256, 256) if quick else (2048, 2048)
    out = {"rounds": rounds, "rows": rows, "feats": feats, "workers": {}}
    for U in (1, 2, 4):
        stdout = run_sub(_CODE.format(workers=U, rounds=rounds,
                                      rows=rows, feats=feats),
                         devices=U, timeout=560)
        payload = json.loads(
            stdout.strip().splitlines()[-1][len("PAYLOAD:"):])
        out["workers"][U] = payload
    save("BENCH_ssp", out)
    return out


def rows(out):
    for U, p in out["workers"].items():
        scan = p["scan"]
        yield (f"ssp/U{U}/scan_us_per_round", 1e6 / scan, round(scan, 2))
        for s, rec in p["ssp"].items():
            rps = rec["rounds_per_sec"]
            yield (f"ssp/U{U}/s{s}_us_per_round", 1e6 / rps, round(rps, 2))
            yield (f"ssp/U{U}/s{s}_speedup_vs_scan", 0.0,
                   round(rps / scan, 3))
            yield (f"ssp/U{U}/s{s}_final_objective", 0.0,
                   round(rec["objective"][-1], 4))
