"""Partition-policy benchmark: static vs load-balanced ownership on a
deliberately skewed lasso workload.

The paper's other headline primitive is *partitioning* of the model
variables; the companion papers (1312.5766, 1411.2305) make it dynamic —
ownership follows load.  With partition policy now a declarative
``PartitionerSpec`` on the ``ExecutionPlan``, the comparison is literally
two plans.

The workload is built to be skewed: a power-law β* concentrated on a
*contiguous* block of columns, so almost all update activity lands on
the first worker's contiguous static shard.  The benchmark runs
``kind="static"`` vs ``kind="load_balanced"`` (same dynamic-priority
scheduler, same chunked scan executor — rebalance checks ride the
``checkpoint_every`` chunk boundaries) and reports, per arm:

* rounds/sec (compile excluded, interleaved best-of-3);
* per-worker load spread ``(max − min)/mean`` of the *measured* update
  activity Σ_t |Δβ_t| binned by the arm's final ownership assignment —
  the quantity the repartitioner exists to shrink;
* the objective-vs-round curve (ownership is model-store bookkeeping;
  the curves must not degrade — identical schedules ⇒ identical math);
* the rebalance count (final ``Assignment.version``).

Writes ``benchmarks/results/BENCH_part.json`` (each arm embeds the exact
plan + partitioner-spec dicts and the per-worker load vector) for the
cross-PR trajectory; uploaded as a CI artifact by the bench-part job.
``examples/plans/lasso_loadbal.json`` is the checked-in form of the
load-balanced arm.
"""
from __future__ import annotations

import json

from .common import run_sub, save

_CODE = """
import json, tempfile, time
import numpy as np
import jax, jax.numpy as jnp
from repro.apps import lasso
from repro.core import (ExecutionPlan, PartitionerSpec, SchedulerSpec,
                        worker_mesh)

U, R, CK, RB, BS = {workers}, {rounds}, {chunk}, {rebalance}, 16
n, J = {rows}, {feats}

# Skewed design: power-law activity concentrated on a CONTIGUOUS hot
# block, so the static contiguous partition overloads worker 0.
rng = np.random.default_rng(0)
X = rng.normal(size=(n, J)).astype(np.float32)
X -= X.mean(axis=0)
X /= np.maximum(np.linalg.norm(X, axis=0), 1e-12)
hot = J // 8
bstar = np.zeros((J,), np.float32)
bstar[:hot] = 8.0 * np.arange(1, hot + 1, dtype=np.float32) ** -1.2
y = (X @ bstar).astype(np.float32)
y -= y.mean()

cfg = lasso.LassoConfig(num_features=J, lam=0.02, block_size=BS,
                        num_candidates=4 * BS)
mesh = worker_mesh(U)
eng = lasso.make_engine(cfg, mesh)
data = eng.shard_data({{"X": jnp.asarray(X), "y": jnp.asarray(y)}})
init = lambda: eng.init_state(jax.random.key(0), y=y)
obj = eng.app.objective_collect()

sched = SchedulerSpec(kind="dynamic_priority", block_size=BS,
                      num_candidates=4 * BS, rho=0.3, eta=1e-3)
plans = {{
    "static": ExecutionPlan(
        executor="scan", rounds=R, checkpoint_every=CK, scheduler=sched,
        partitioner=PartitionerSpec(kind="static")),
    "load_balanced": ExecutionPlan(
        executor="scan", rounds=R, checkpoint_every=CK, scheduler=sched,
        partitioner=PartitionerSpec(kind="load_balanced", ema=0.5,
                                    imbalance_threshold=0.1,
                                    rebalance_every=RB)),
}}

run = lambda st, plan: eng.execute(st, data, jax.random.key(1), plan,
                                   ckpt_dir=tempfile.mkdtemp()).state

for plan in plans.values():                  # compile warmup, all first
    run(init(), plan)

# Interleaved best-of-3 (chunk checkpoints included in both arms).
best = {{name: 0.0 for name in plans}}
for _ in range(3):
    for name, plan in plans.items():
        st = init()
        t0 = time.time()
        jax.block_until_ready(run(st, plan))
        best[name] = max(best[name], R / (time.time() - t0))

out = {{}}
stride = max(1, R // 20)
for name, plan in plans.items():
    rep = eng.execute(init(), data, jax.random.key(1), plan,
                      collect=lambda s: {{"beta": s["beta"],
                                          "obj": obj(s)}},
                      ckpt_dir=tempfile.mkdtemp())
    betas = np.asarray(rep.trace["beta"])            # (R, J)
    objs = np.asarray(rep.trace["obj"])
    # measured per-variable update activity over the whole run
    steps = np.vstack([betas[:1], np.diff(betas, axis=0)])
    activity = np.abs(steps).sum(axis=0)
    asgn = eng.partition_assignment
    loads = asgn.loads(activity)
    out[name] = {{
        "rounds_per_sec": best[name],
        "load_spread": asgn.spread(activity),
        "per_worker_load": [float(v) for v in loads],
        "rebalances": asgn.version,
        "objective": [float(v) for v in objs[::stride]]
                     + [float(objs[-1])],
        "plan": plan.to_json(),
        "partitioner": plan.partitioner.to_json(),
    }}
print("PAYLOAD:" + json.dumps(out))
"""


def run(quick: bool = True):
    rounds, chunk, rebalance = (120, 20, 40) if quick else (300, 30, 60)
    rows, feats = (256, 256) if quick else (2048, 2048)
    out = {"rounds": rounds, "chunk": chunk, "rebalance": rebalance,
           "rows": rows, "feats": feats, "workers": {}}
    for U in (4,):
        stdout = run_sub(_CODE.format(workers=U, rounds=rounds,
                                      chunk=chunk, rebalance=rebalance,
                                      rows=rows, feats=feats),
                         devices=U, timeout=560)
        payload = json.loads(
            stdout.strip().splitlines()[-1][len("PAYLOAD:"):])
        out["workers"][U] = payload
    save("BENCH_part", out)
    return out


def rows(out):
    for U, p in out["workers"].items():
        for name, rec in p.items():
            rps = rec["rounds_per_sec"]
            yield (f"part/U{U}/{name}_us_per_round", 1e6 / rps,
                   round(rps, 2))
            yield (f"part/U{U}/{name}_load_spread", 0.0,
                   round(rec["load_spread"], 4))
            yield (f"part/U{U}/{name}_rebalances", 0.0,
                   rec["rebalances"])
            yield (f"part/U{U}/{name}_final_objective", 0.0,
                   round(rec["objective"][-1], 4))
