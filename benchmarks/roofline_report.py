"""Render the dry-run roofline results (benchmarks/results/dryrun/*.json)
as the §Dry-run / §Roofline markdown tables for EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from .common import RESULTS

DRYRUN = os.path.join(RESULTS, "dryrun")


def load(tag: Optional[str] = None) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        r = json.load(open(f))
        rtag = r.get("tag", "baseline")
        if tag is not None and rtag != tag:
            continue
        rows.append(r)
    return rows


def _fmt_ms(s: float) -> str:
    return f"{s*1e3:.2f}"


def roofline_table(mesh: str = "single", tag: str = "baseline") -> str:
    """§Roofline markdown table (single-pod per spec)."""
    lines = [
        "| arch | shape | T_comp (ms) | T_mem (ms) | T_coll (ms) | "
        "dominant | model GFLOPs | useful/HLO | mem/dev (GiB) |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|",
    ]
    for r in load(tag):
        if r["mesh"] != mesh:
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped: {r['skipped']} | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR |||||||")
            continue
        rl = r["roofline"]
        mem = r["memory"].get("total_per_device", 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_ms(rl['t_compute'])} | "
            f"{_fmt_ms(rl['t_memory'])} | {_fmt_ms(rl['t_collective'])} | "
            f"**{rl['dominant']}** | {r['model_flops']/1e9:.0f} | "
            f"{r['useful_flops_ratio']:.3f} | {mem:.2f} |")
    return "\n".join(lines)


def dryrun_table(tag: str = "baseline") -> str:
    """§Dry-run markdown table: both meshes, compile stats + collectives."""
    lines = [
        "| arch | shape | mesh | chips | lower (s) | compile (s) | "
        "mem/dev (GiB) | wire GB/chip | #coll | top collectives |",
        "|---|---|---|---:|---:|---:|---:|---:|---:|---|",
    ]
    for r in load(tag):
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r['chips']} | — | — | — | — | — | skipped |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| {r['chips']} | ERROR ||||||")
            continue
        ana = r.get("hlo_analysis", {})
        by = sorted(ana.get("by_kind", {}).items(), key=lambda kv: -kv[1])
        top = ", ".join(f"{k}:{v/1e9:.2f}GB" for k, v in by[:2]) or "none"
        mem = r["memory"].get("total_per_device", 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['lower_s']} | {r['compile_s']} | {mem:.2f} | "
            f"{ana.get('wire_bytes', 0)/1e9:.2f} | "
            f"{ana.get('collective_count', 0)} | {top} |")
    return "\n".join(lines)


def worst_pairs(mesh: str = "single", n: int = 5) -> List[Dict]:
    """Pairs ranked by useful/HLO-FLOPs ratio (ascending = worst) and by
    collective dominance — the §Perf candidate shortlist."""
    rows = [r for r in load("baseline")
            if r["mesh"] == mesh and "roofline" in r]
    by_ratio = sorted(rows, key=lambda r: r["useful_flops_ratio"])[:n]
    coll = [r for r in rows if r["roofline"]["dominant"] == "collective"]
    coll = sorted(coll, key=lambda r: -(r["roofline"]["t_collective"]
                                        / max(r["roofline"]["t_compute"],
                                              1e-12)))[:n]
    return {"worst_ratio": [(r["arch"], r["shape"]) for r in by_ratio],
            "most_collective_bound": [(r["arch"], r["shape"])
                                      for r in coll]}


def main():
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n## Roofline (single pod)\n")
    print(roofline_table())
    print("\nCandidates:", json.dumps(worst_pairs(), indent=1))


if __name__ == "__main__":
    main()
