"""Paper Fig 10: STRADS LDA scalability — convergence with 1/2/4 workers
at a fixed model size.

Under word-rotation scheduling one full Gibbs *sweep* = U rounds (each
round touches 1/U of each worker's tokens), so runs are compared in sweep
units.  The hardware-independent headline is sweeps-to-target: model
parallelism must not slow convergence per sweep (paper Fig 10 shows
near-linear wall-clock scaling *because* sweeps-to-target stays flat while
per-sweep wall time drops ≈U×).  CPU caveat: forced host devices share
the same cores, so wall-clock here cannot show the paper's speedup; we
report measured per-round work instead.
"""
from __future__ import annotations

import json

from .common import run_sub, save

_CODE = """
import json, time
import numpy as np
from repro.apps import lda
from repro.core import worker_mesh

U = {workers}
cfg = lda.LDAConfig(num_workers=U, vocab=160, num_topics=8,
                    tokens_per_worker={tokens} // U,
                    docs_per_worker=max(120 // U, 1))
rng = np.random.default_rng(0)
words, docs, z0 = lda.synthetic_corpus(rng, cfg)
mesh = worker_mesh(U)
t0 = time.time()
st, trace, _ = lda.fit(cfg, words, docs, z0, mesh, {sweeps} * U,
                       trace_every=max(U, 1))
wall = time.time() - t0
sweep_trace = [(t / U, v) for t, v in trace]
print("PAYLOAD:" + json.dumps({{"trace": sweep_trace, "wall_s": wall}}))
"""


def run(quick: bool = True):
    tokens = 4000 if quick else 20000
    sweeps = 12 if quick else 30
    out = {"tokens": tokens, "sweeps": sweeps, "workers": {}}
    for U in (1, 2, 4):
        stdout = run_sub(_CODE.format(workers=U, tokens=tokens,
                                      sweeps=sweeps),
                         devices=U, timeout=560)
        payload = json.loads(
            stdout.strip().splitlines()[-1][len("PAYLOAD:"):])
        out["workers"][U] = payload
    best = max(p["trace"][-1][1] for p in out["workers"].values())
    tgt = best - abs(best) * 0.01
    out["target"] = tgt
    out["sweeps_to_target"] = {}
    for U, p in out["workers"].items():
        hit = next((t for t, v in p["trace"] if v >= tgt), None)
        out["sweeps_to_target"][U] = hit
    save("bench_scaling", out)
    return out


def rows(out):
    for U, p in out["workers"].items():
        yield (f"scaling/U{U}/per_sweep_us",
               p["wall_s"] * 1e6 / out["sweeps"], p["trace"][-1][1])
        yield (f"scaling/U{U}/sweeps_to_target", 0.0,
               out["sweeps_to_target"][U] or -1)
