"""Paper Fig 8/9 (center): MF convergence across ranks — STRADS
round-robin CD vs a GraphLab-style ALS baseline.  The paper's point is
twofold: (a) STRADS reaches *larger ranks* than the baseline (memory /
partitioning) and (b) converges at least as fast; here we run the
training-objective trajectories at several ranks on the Netflix-like
synthetic (§4.1, scaled)."""
from __future__ import annotations

import numpy as np

from repro.apps import mf
from repro.core import single_device_mesh

from .common import save, timer


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    N, M = (96, 64) if quick else (300, 200)
    ranks = (8, 16) if quick else (8, 16, 32, 64)
    # CD rounds are ~150× cheaper than exact ALS alternations; compare at
    # roughly matched wall time (paper compares time-to-objective).
    rounds = 600 if quick else 1200
    als_iters = 10 if quick else 20
    A, mask = mf.synthetic_ratings(rng, N, M, true_rank=8, density=0.4)
    mesh = single_device_mesh()
    out = {"N": N, "M": M, "rounds": rounds, "ranks": list(ranks),
           "strads": {}, "als": {}, "wall_s": {}}

    for K in ranks:
        cfg = mf.MFConfig(num_rows=N, num_cols=M, rank=K, lam=0.05)
        with timer() as t:
            _, trace = mf.fit(cfg, A, mask, mesh, num_rounds=rounds,
                              trace_every=50)
        out["strads"][K] = trace
        out["wall_s"][f"strads/{K}"] = round(t.s, 2)

        import jax
        with timer() as t:
            _, als_trace = mf.als_fit(A, mask, K, 0.05, als_iters,
                                      jax.random.key(1))
        out["als"][K] = als_trace
        out["wall_s"][f"als/{K}"] = round(t.s, 2)
    save("bench_mf", out)
    return out


def rows(out):
    for K in out["ranks"]:
        yield (f"mf/strads/K{K}/final",
               out["wall_s"][f"strads/{K}"] * 1e6 / out["rounds"],
               out["strads"][K][-1][1])
        yield (f"mf/als/K{K}/final",
               out["wall_s"][f"als/{K}"] * 1e6 / max(len(out["als"][K]), 1),
               out["als"][K][-1][1])
