"""Streaming-ingest benchmark: static vs streaming training, ingest
cost isolated, compiled-program reuse, and serving under drift.

Four arms on 4-worker plans (one subprocess, forced host devices):

* **lasso static / empty / drift** — the same SSP lasso run three ways:
  plain ``execute()``, streamed with an :class:`repro.stream.EmptySource`
  (pure boundary-loop chunking cost; asserted bit-identical to the
  static run leaf-by-leaf), and streamed with a
  :class:`~repro.stream.LassoDriftSource` (the empty→drift delta is the
  actual ingest cost).  The final ½‖y−Xβ‖²+λ‖β‖₁ objective is recorded
  for the static and drifted runs — drift moves the optimum, so the
  objectives differ while both runs stay finite and converged.
* **mf extend no-recompile** — a capacity-padded MF ring on the scan
  executor: after one streamed warmup, a second streamed run with fresh
  ``"extend"`` deltas must leave ``engine._scan_cache`` untouched (the
  validity-mask ring keeps every data shape static, so ingest never
  triggers an XLA recompile) — asserted in-process.
* **serve under ingest** — :func:`repro.serve.serve_while_training`
  with a concurrent drift stream: p50/p99 request latency, the measured
  staleness-at-read histogram (bound asserted), and rows
  ingested/dropped, showing reads and writes riding one boundary.

Writes ``benchmarks/results/BENCH_stream.json``.
"""
from __future__ import annotations

import json

from .common import run_sub, save

_CODE = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core import ExecutionPlan, worker_mesh
from repro.serve import ServeSpec, serve_while_training
from repro.stream import (StreamSpec, EmptySource, LassoDriftSource,
                          MFDriftSource)

U = 4
mesh = worker_mesh(U)
rng = np.random.default_rng(0)

def bit_identical(a, b):
    return all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))

# ---- arm 1: lasso static vs empty-streamed vs drift-streamed ---------
from repro.apps import lasso
R, n, J = {rounds}, {rows}, {feats}
X, y, _ = lasso.synthetic_correlated(rng, n=n, J=J, k_true=10)
cfg = lasso.LassoConfig(num_features=J, lam=0.02, block_size=8,
                        num_candidates=32)
eng = lasso.make_engine(cfg, mesh)
data = eng.shard_data({{"X": jnp.asarray(X), "y": jnp.asarray(y)}})
init = lambda: eng.init_state(jax.random.key(0), y=y)
plan = ExecutionPlan(executor="ssp", rounds=R, staleness=1, workers=U)
spec = StreamSpec(kind="replace", ingest_every={ingest_every})
drift = lambda: LassoDriftSource(num_rows=n, num_features=J,
                                 rows_per_ingest={rpi}, seed=3)
obj = eng.app.objective_fn(mesh)

# warm every program variant before timing (static fast path AND the
# streamed span loop compile different scan lengths)
jax.block_until_ready(eng.execute(init(), data, jax.random.key(1),
                                  plan).state)
jax.block_until_ready(eng.execute(init(), data, jax.random.key(1), plan,
                                  stream=spec,
                                  source=EmptySource()).state)
jax.block_until_ready(eng.execute(init(), data, jax.random.key(1), plan,
                                  stream=spec, source=drift()).state)

def timed(**kw):
    t0 = time.time()
    rep = eng.execute(init(), data, jax.random.key(1), plan, **kw)
    jax.block_until_ready(rep.state)
    return rep, time.time() - t0

rep_s, wall_s = timed()
rep_e, wall_e = timed(stream=spec, source=EmptySource())
rep_d, wall_d = timed(stream=spec, source=drift())
assert bit_identical(rep_s.state, rep_e.state), \\
    "empty-source streaming perturbed the trajectory"
lasso_arm = {{
    "plan": plan.to_json(), "stream_spec": spec.to_json(),
    "static_rounds_per_s": R / wall_s,
    "empty_rounds_per_s": R / wall_e,
    "drift_rounds_per_s": R / wall_d,
    "chunking_cost_s": wall_e - wall_s,
    "ingest_cost_s": wall_d - wall_e,
    "empty_bit_identical": True,
    "objective_static": float(obj(rep_s.state)),
    "objective_drift": float(obj(rep_d.state)),
    "ingest": {{k: int(v) for k, v in rep_d.stream.items()}},
}}

# ---- arm 2: MF extend ring reuses compiled programs ------------------
from repro.apps import mf
N, M, FILL = {mf_rows}, {mf_cols}, {mf_fill}
A, mask = mf.synthetic_ratings(rng, FILL, M, true_rank=4)
A = np.concatenate([A, np.zeros((N - FILL, M), A.dtype)])
mask = np.concatenate([mask, np.zeros((N - FILL, M), mask.dtype)])
mcfg = mf.MFConfig(num_rows=N, num_cols=M, rank=8)
meng = mf.make_engine(mcfg, mesh)
mdata = meng.shard_data({{"A": jnp.asarray(A), "mask": jnp.asarray(mask)}})
minit = lambda: meng.init_state(jax.random.key(0), A=jnp.asarray(A),
                                mask=jnp.asarray(mask))
mplan = ExecutionPlan(executor="scan", rounds={mf_rounds}, workers=U)
mspec = StreamSpec(kind="extend", ingest_every=2, capacity=N)
msrc = lambda seed: MFDriftSource(num_rows=N, num_cols=M,
                                  rows_per_ingest=4, true_rank=4,
                                  kind="extend", seed=seed)
mrep0 = meng.execute(minit(), mdata, jax.random.key(1), mplan,
                     stream=mspec, source=msrc(1))
jax.block_until_ready(mrep0.state)
n0 = len(meng._scan_cache)
t0 = time.time()
mrep1 = meng.execute(minit(), mdata, jax.random.key(1), mplan,
                     stream=mspec, source=msrc(2))
jax.block_until_ready(mrep1.state)
mwall = time.time() - t0
n1 = len(meng._scan_cache)
assert n1 == n0, f"extend ingest recompiled: {{n0}} -> {{n1}} programs"
mf_arm = {{
    "plan": mplan.to_json(), "stream_spec": mspec.to_json(),
    "scan_cache_after_warmup": n0, "scan_cache_after_ingests": n1,
    "recompiles": n1 - n0,
    "streamed_rounds_per_s": {mf_rounds} / mwall,
    "ingest": {{k: int(v) for k, v in mrep1.stream.items()}},
}}

# ---- arm 3: serve-while-train under concurrent ingest ----------------
NREQ = {requests}
sspec = ServeSpec(kind="stale", max_staleness=3, max_batch=8)
payload = lambda i: {{"x": jnp.asarray(X[i % n])}}
reqs = [((i * R) // NREQ, payload(i)) for i in range(NREQ)]
t0 = time.time()
swt = serve_while_training(eng, init(), data, jax.random.key(1), plan,
                           spec=sspec, requests=list(reqs),
                           stream=spec, source=drift())
jax.block_until_ready(swt.report.state)
swall = time.time() - t0
pct = swt.latency_percentiles()
bound_held = swt.max_staleness_read() <= sspec.max_staleness
assert bound_held, "staleness-at-read exceeded the bound under ingest"
serve_arm = {{
    "serve_spec": sspec.to_json(), "stream_spec": spec.to_json(),
    "p50_ms": pct["p50_ms"], "p99_ms": pct["p99_ms"],
    "throughput_rps": len(swt.responses) / max(swall, 1e-9),
    "staleness_hist": {{str(k): v for k, v in
                        sorted(swt.staleness_hist().items())}},
    "max_staleness_read": swt.max_staleness_read(),
    "bound_held": bound_held,
    "ingest": {{k: int(v) for k, v in swt.ingest.items()}},
}}

out = {{"workers": U, "lasso": lasso_arm, "mf_extend": mf_arm,
        "serve_under_ingest": serve_arm}}
print("PAYLOAD:" + json.dumps(out))
"""


def run(quick: bool = True):
    kw = dict(rounds=24 if quick else 96,
              rows=256 if quick else 1024,
              feats=256 if quick else 1024,
              ingest_every=4, rpi=16 if quick else 64,
              mf_rows=64 if quick else 256, mf_cols=64 if quick else 128,
              mf_fill=48 if quick else 192,
              mf_rounds=16 if quick else 48,
              requests=64 if quick else 256)
    stdout = run_sub(_CODE.format(**kw), devices=4, timeout=560)
    out = json.loads(stdout.strip().splitlines()[-1][len("PAYLOAD:"):])
    save("BENCH_stream", out)
    return out


def rows(out):
    la = out["lasso"]
    for arm in ("static", "empty", "drift"):
        yield (f"stream/lasso/{arm}_rounds_per_s", 0.0,
               round(la[f"{arm}_rounds_per_s"], 1))
    yield ("stream/lasso/ingest_cost_ms", la["ingest_cost_s"] * 1e6,
           round(la["ingest_cost_s"] * 1e3, 2))
    yield ("stream/lasso/empty_bit_identical", 0.0,
           int(la["empty_bit_identical"]))
    yield ("stream/lasso/rows_ingested", 0.0, la["ingest"]["rows_in"])
    mf = out["mf_extend"]
    yield ("stream/mf_extend/recompiles", 0.0, mf["recompiles"])
    yield ("stream/mf_extend/rounds_per_s", 0.0,
           round(mf["streamed_rounds_per_s"], 1))
    yield ("stream/mf_extend/rows_ingested", 0.0, mf["ingest"]["rows_in"])
    sv = out["serve_under_ingest"]
    yield ("stream/serve/p50_ms", sv["p50_ms"] * 1e3,
           round(sv["p99_ms"], 2))
    yield ("stream/serve/max_staleness_read", 0.0,
           sv["max_staleness_read"])
    yield ("stream/serve/bound_held", 0.0, int(sv["bound_held"]))
    yield ("stream/serve/rows_ingested", 0.0, sv["ingest"]["rows_in"])


def summary(out):
    la = out["lasso"]
    yield (f"# stream/lasso spec={json.dumps(la['stream_spec'])} "
           f"obj static={la['objective_static']:.4f} "
           f"drift={la['objective_drift']:.4f}")
    sv = out["serve_under_ingest"]
    yield (f"# stream/serve spec={json.dumps(sv['stream_spec'])} "
           f"hist={json.dumps(sv['staleness_hist'])}")
