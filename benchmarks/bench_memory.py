"""Paper Fig 3: per-machine memory vs number of machines.

Model-parallel STRADS LDA shards the (padded-vocab × topics) word-topic
table: per-machine bytes *shrink* as machines are added.  The
YahooLDA-style data-parallel baseline replicates the full table on every
machine: per-machine bytes are flat (and the biggest model that fits is
set by the smallest machine).  We compute both from the *actual state
templates* of the two apps (the same arrays the engine shards/replicates),
which is exactly what the paper plots."""
from __future__ import annotations

from repro.apps import lda

from .common import save


def bytes_per_machine(cfg: "lda.LDAConfig", baseline: bool) -> int:
    """Word-topic table bytes resident per machine (f32)."""
    Vp, K, U = cfg.padded_vocab, cfg.num_topics, cfg.num_workers
    table = Vp * K * 4
    doc = cfg.docs_per_worker * K * 4          # doc-topic rows (both shard)
    if baseline:
        return table + doc                     # replicated table
    return table // U + doc                    # model-parallel shard


def run(quick: bool = True):
    vocab, topics = (20000, 64) if quick else (200000, 128)
    out = {"vocab": vocab, "topics": topics, "machines": [],
           "strads_mb": [], "baseline_mb": []}
    for U in (1, 2, 4, 8, 16, 32, 64, 128):
        cfg = lda.LDAConfig(num_workers=U, vocab=vocab, num_topics=topics,
                            tokens_per_worker=1000, docs_per_worker=50)
        out["machines"].append(U)
        out["strads_mb"].append(
            round(bytes_per_machine(cfg, False) / 2**20, 3))
        out["baseline_mb"].append(
            round(bytes_per_machine(cfg, True) / 2**20, 3))
    # BENCH_-prefixed like the other tracked artifacts (the bench-memory
    # CI job uploads it — Fig 3 is the trajectory the repartitioner's
    # byte accounting feeds, so it is tracked per PR, not best-effort)
    save("BENCH_memory", out)
    return out


def rows(out):
    for u, s, b in zip(out["machines"], out["strads_mb"],
                       out["baseline_mb"]):
        yield (f"memory/U{u}/strads_mb", 0.0, s)
        yield (f"memory/U{u}/yahoolda_mb", 0.0, b)
