"""Executor benchmark: host loop vs scanned vs pipelined (the tentpole
claim of the pipelined executor PR).

For 1/2/4 forced host devices, run STRADS Lasso under the three engine
paths and report rounds/sec with compile time excluded (each path is
warmed up on its own program first).  The host loop pays one dispatch and
one host↔device sync per round; ``run_scanned`` amortizes R rounds into
one XLA program; ``pipelined`` additionally overlaps round t+1's
schedule with round t's push/pull (one-round-stale schedules, paper
§pipelining).  CPU caveat: forced host devices share the same cores, so
cross-U scaling is not meaningful here — the loop-vs-scan dispatch
overhead ratio is.

The sweep is expressed as :class:`repro.core.ExecutionPlan` values run
through the one engine entry point (``StradsEngine.execute``); each
worker-count record embeds the plan dicts under ``"plans"`` so the
artifact states exactly what was measured.

Writes ``benchmarks/results/BENCH_pipeline.json`` so later PRs have a
perf trajectory to compare against.
"""
from __future__ import annotations

import json

from .common import run_sub, save

_CODE = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.apps import lasso
from repro.core import ExecutionPlan, worker_mesh

U, R = {workers}, {rounds}
rng = np.random.default_rng(0)
X, y, _ = lasso.synthetic_correlated(rng, n={rows}, J={feats}, k_true=10)
cfg = lasso.LassoConfig(num_features={feats}, lam=0.02, block_size=16,
                        num_candidates=64, rho=0.3)
mesh = worker_mesh(U)
eng = lasso.make_engine(cfg, mesh)
data = eng.shard_data({{"X": jnp.asarray(X), "y": jnp.asarray(y)}})

def init():
    return eng.init_state(jax.random.key(0), y=y)

# One plan per executor — the sweep is over ExecutionPlans, and the
# BENCH json records exactly what ran.
plans = {{name: ExecutionPlan(executor=name, rounds=R)
         for name in ("loop", "scan", "pipelined")}}
out = {{"plans": {{n: p.to_json() for n, p in plans.items()}}}}
for name, plan in plans.items():
    warm = 2 if name == "loop" else R       # loop compiles one round once
    eng.execute(init(), data, jax.random.key(1),
                ExecutionPlan(executor=name, rounds=warm))  # compile warmup
    st = init()
    t0 = time.time()
    rep = eng.execute(st, data, jax.random.key(1), plan)
    jax.block_until_ready(rep.state)
    out[name] = R / (time.time() - t0)
print("PAYLOAD:" + json.dumps(out))
"""


def run(quick: bool = True):
    rounds = 60 if quick else 300
    rows, feats = (256, 256) if quick else (2048, 2048)
    out = {"rounds": rounds, "rows": rows, "feats": feats, "workers": {}}
    for U in (1, 2, 4):
        stdout = run_sub(_CODE.format(workers=U, rounds=rounds,
                                      rows=rows, feats=feats),
                         devices=U, timeout=560)
        payload = json.loads(
            stdout.strip().splitlines()[-1][len("PAYLOAD:"):])
        out["workers"][U] = payload
    save("BENCH_pipeline", out)
    return out


def rows(out):
    for U, p in out["workers"].items():
        for name in ("loop", "scan", "pipelined"):
            rps = p[name]
            yield (f"pipeline/U{U}/{name}_us_per_round", 1e6 / rps,
                   round(rps, 2))
        yield (f"pipeline/U{U}/scan_speedup_vs_loop", 0.0,
               round(p["scan"] / p["loop"], 3))
