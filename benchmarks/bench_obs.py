"""Observability overhead benchmark: what does telemetry cost?

For 4 forced host devices, run STRADS Lasso on the scan and SSP
executors with telemetry off, with device counters
(``TelemetrySpec(kind="counters")``), and with counters + host events
(``kind="trace"``), reporting rounds/sec for each — the acceptance bar
is that the device counters (a handful of int32 adds folded into an
R-round scan) cost within noise of the uninstrumented run, and even the
trace recorder only pays at host phase boundaries, never per round.

Also exercises the artifact path end to end: the instrumented 4-worker
SSP run's :class:`~repro.obs.report.RunReport` is saved under
``benchmarks/results/obs/`` together with its JSONL and Chrome-trace
exports, and ``python -m repro.launch.trace <artifact> --check``
validates them (the CI trace-smoke job uploads all three).

Writes ``benchmarks/results/BENCH_obs.json`` for the cross-PR perf
trajectory.
"""
from __future__ import annotations

import json
import os

from .common import RESULTS, run_sub, save

OBS_DIR = os.path.join(RESULTS, "obs")

_CODE = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.apps import lasso
from repro.core import ExecutionPlan, worker_mesh
from repro.obs import TelemetrySpec

U, R = {workers}, {rounds}
rng = np.random.default_rng(0)
X, y, _ = lasso.synthetic_correlated(rng, n={rows}, J={feats}, k_true=10)
cfg = lasso.LassoConfig(num_features={feats}, lam=0.02, block_size=16,
                        num_candidates=64, rho=0.3)
mesh = worker_mesh(U)
eng = lasso.make_engine(cfg, mesh)
data = eng.shard_data({{"X": jnp.asarray(X), "y": jnp.asarray(y)}})
init = lambda: eng.init_state(jax.random.key(0), y=y)

SPECS = {{"off": False,
          "counters": TelemetrySpec(kind="counters"),
          "trace": TelemetrySpec(kind="trace")}}
plans = {{}}
for ex, kw in (("scan", {{}}), ("ssp", {{"staleness": 2}})):
    for tname, tspec in SPECS.items():
        plans[f"{{ex}}/{{tname}}"] = ExecutionPlan(
            executor=ex, rounds=R, telemetry=tspec, **kw)

run = lambda st, plan: eng.execute(st, data, jax.random.key(1), plan)

for plan in plans.values():                  # compile warmup, all first
    jax.block_until_ready(run(init(), plan).state)

# Interleaved best-of-3: a slow minute on a shared box hits every
# config, not whichever happened to be measured during it.
best = {{name: 0.0 for name in plans}}
for _ in range(3):
    for name, plan in plans.items():
        st = init()
        t0 = time.time()
        jax.block_until_ready(run(st, plan).state)
        best[name] = max(best[name], R / (time.time() - t0))

out = {{"rounds_per_sec": best,
        "plans": {{n: p.to_json() for n, p in plans.items()}}}}

# the 4-worker instrumented SSP artifact the trace-smoke job checks:
# RunReport JSON + JSONL + Chrome trace (loads in chrome://tracing)
rep = run(init(), plans["ssp/trace"]).telemetry
out["ssp_trace_report"] = rep.to_json()
obs_dir = {obs_dir!r}
if obs_dir:
    import os
    os.makedirs(obs_dir, exist_ok=True)
    with open(os.path.join(obs_dir, "run_ssp_trace.json"), "w") as f:
        json.dump(rep.to_json(), f, indent=1)
    rep.write_jsonl(os.path.join(obs_dir, "run_ssp_trace.jsonl"))
    rep.write_chrome_trace(
        os.path.join(obs_dir, "run_ssp_trace.trace.json"))
print("PAYLOAD:" + json.dumps(out))
"""


def run(quick: bool = True):
    rounds = 120 if quick else 600
    rows_, feats = (256, 256) if quick else (2048, 2048)
    U = 4
    stdout = run_sub(_CODE.format(workers=U, rounds=rounds, rows=rows_,
                                  feats=feats, obs_dir=OBS_DIR),
                     devices=U, timeout=560)
    payload = json.loads(stdout.strip().splitlines()[-1][len("PAYLOAD:"):])
    out = {"rounds": rounds, "rows": rows_, "feats": feats, "workers": U,
           **payload}
    save("BENCH_obs", out)
    return out


def rows(out):
    rps = out["rounds_per_sec"]
    for ex in ("scan", "ssp"):
        off = rps[f"{ex}/off"]
        yield (f"obs/{ex}/off_us_per_round", 1e6 / off, round(off, 2))
        for t in ("counters", "trace"):
            v = rps[f"{ex}/{t}"]
            yield (f"obs/{ex}/{t}_us_per_round", 1e6 / v, round(v, 2))
            yield (f"obs/{ex}/{t}_overhead_vs_off", 0.0,
                   round(off / v, 3))


def summary(out):
    rep = out["ssp_trace_report"]
    c = rep.get("counters", {})
    yield (f"obs/ssp_trace: rounds {c.get('rounds')} "
           f"accepted/proposed {c.get('accepted')}/{c.get('proposed')} "
           f"events {len(rep.get('events', []))} "
           f"→ {os.path.join(OBS_DIR, 'run_ssp_trace.trace.json')}")
