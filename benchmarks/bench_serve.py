"""Serving benchmark: serve-only vs serve-while-train on 4-worker SSP.

For the lasso and LDA workloads on a 4-worker SSP plan, run the
:mod:`repro.serve` read path two ways:

* **serve-while-train** — :func:`repro.serve.serve_while_training`
  interleaves ``execute()`` chunks (one SSP flush window each) with
  serving reads at the flush boundaries; requests arrive spread over the
  training rounds.  The serving staleness bound is set *above* the
  window length, so the ModelView skips cache refreshes while the SSP
  gate holds and the staleness-at-read histogram actually exercises the
  bound (reads at 0 and at one-window staleness), not just the fresh
  case.
* **serve-only** — the same requests served from the final trained
  state (the no-interleaving baseline for latency).

Each arm reports p50/p99 request latency, throughput, and the measured
staleness-at-read histogram; the serve-while-train arm additionally
asserts the acceptance bar in-process — final trained state
bit-identical to an unserved ``execute()`` of the same plan, every read
≤ ``ServeSpec.max_staleness`` — and records the verdicts.  The BENCH
json embeds the exact ServeSpec and ExecutionPlan dicts, so the
cross-PR trajectory records exactly what was measured; a Chrome trace
of the interleaved run is written to ``benchmarks/results/serve/`` for
the CI artifact upload.

Writes ``benchmarks/results/BENCH_serve.json``.
"""
from __future__ import annotations

import json
import os

from .common import RESULTS, run_sub, save

SERVE_DIR = os.path.join(RESULTS, "serve")

_CODE = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core import ExecutionPlan, worker_mesh
from repro.obs import Recorder
from repro.serve import ServeSpec, serve_only, serve_while_training

APP = {app!r}
U, R, S, BOUND, NREQ = 4, {rounds}, {staleness}, {bound}, {requests}
rng = np.random.default_rng(0)
mesh = worker_mesh(U)

if APP == "lasso":
    from repro.apps import lasso
    n, J = {rows}, {feats}
    X, y, _ = lasso.synthetic_correlated(rng, n=n, J=J, k_true=10)
    cfg = lasso.LassoConfig(num_features=J, lam=0.02, block_size=8,
                            num_candidates=32)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({{"X": jnp.asarray(X), "y": jnp.asarray(y)}})
    init = lambda: eng.init_state(jax.random.key(0), y=y)
    payload = lambda i: {{"x": jnp.asarray(X[i % n])}}
else:
    from repro.apps import lda
    cfg = lda.LDAConfig(vocab=U * 32, num_topics=8, num_workers=U,
                        tokens_per_worker={tokens}, docs_per_worker=8)
    words, docs, z0 = lda.synthetic_corpus(rng, cfg, true_topics=4)
    eng = lda.make_engine(cfg, mesh)
    data = eng.shard_data({{"words": jnp.asarray(words),
                            "docs": jnp.asarray(docs)}})
    init = lambda: eng.init_state(jax.random.key(0), words=words,
                                  docs=docs, z0=z0)
    docs_q = rng.integers(0, cfg.vocab, size=(NREQ, 16)).astype(np.int32)
    payload = lambda i: {{"words": jnp.asarray(docs_q[i % NREQ])}}

plan = ExecutionPlan(executor="ssp", rounds=R, staleness=S, workers=U)
spec = ServeSpec(kind="stale", max_staleness=BOUND, max_batch=8)
reqs = [((i * R) // NREQ, payload(i)) for i in range(NREQ)]

def arm_stats(srep, wall):
    pct = srep.latency_percentiles()
    return {{"p50_ms": pct["p50_ms"], "p99_ms": pct["p99_ms"],
             "throughput_rps": len(srep.responses) / max(wall, 1e-9),
             "requests": len(srep.responses),
             "staleness_hist": {{str(k): v for k, v in
                                 sorted(srep.staleness_hist().items())}},
             "max_staleness_read": srep.max_staleness_read()}}

# warm the compiled round programs so the timed arms measure serving,
# not XLA compiles
jax.block_until_ready(
    eng.execute(init(), data, jax.random.key(1), plan).state)

rec = Recorder()
t0 = time.time()
swt = serve_while_training(eng, init(), data, jax.random.key(1), plan,
                           spec=spec, requests=list(reqs), recorder=rec)
jax.block_until_ready(swt.report.state)
swt_wall = time.time() - t0
rec.write_chrome_trace({trace_path!r})

# acceptance: serving never perturbed training (bit-exact), bound held
ref = eng.execute(init(), data, jax.random.key(1), plan)
bit_identical = all(
    bool(jnp.array_equal(a, b)) for a, b in zip(
        jax.tree.leaves(swt.report.state), jax.tree.leaves(ref.state)))
bound_held = swt.max_staleness_read() <= spec.max_staleness

trained = ref.state
t0 = time.time()
so = serve_only(eng, trained, spec=spec,
                requests=[p for _, p in reqs], t=R)
so_wall = time.time() - t0

out = {{"plan": plan.to_json(), "serve_spec": spec.to_json(),
        "bit_identical": bit_identical, "bound_held": bound_held,
        "train_plus_serve_s": swt_wall,
        "serve_while_train": arm_stats(swt, swt_wall),
        "serve_only": arm_stats(so, so_wall)}}
assert bit_identical, "serving perturbed training state"
assert bound_held, "staleness-at-read exceeded the spec bound"
print("PAYLOAD:" + json.dumps(out))
"""


def run(quick: bool = True):
    os.makedirs(SERVE_DIR, exist_ok=True)
    nreq = 64 if quick else 256
    workloads = {
        # lasso: window L = s+1 = 3; bound 5 lets the cache serve one
        # whole extra window before the gate forces a refresh, so the
        # histogram shows reads at staleness 0 AND 3
        "lasso": dict(app="lasso", rounds=24 if quick else 120,
                      staleness=2, bound=5, requests=nreq,
                      rows=256 if quick else 1024,
                      feats=256 if quick else 1024, tokens=0),
        # lda: rotation period 4 makes the window L = lcm(2, 4) = 4;
        # bound 4 keeps the cache exactly one window before refreshing
        "lda": dict(app="lda", rounds=16 if quick else 64,
                    staleness=1, bound=4, requests=nreq,
                    rows=0, feats=0, tokens=64 if quick else 256),
    }
    out = {"workers": 4, "workloads": {}}
    for name, kw in workloads.items():
        trace_path = os.path.join(SERVE_DIR, f"serve_{name}.trace.json")
        stdout = run_sub(_CODE.format(trace_path=trace_path, **kw),
                         devices=4, timeout=560)
        payload = json.loads(
            stdout.strip().splitlines()[-1][len("PAYLOAD:"):])
        out["workloads"][name] = payload
    save("BENCH_serve", out)
    return out


def rows(out):
    for name, p in out["workloads"].items():
        for arm in ("serve_while_train", "serve_only"):
            a = p[arm]
            yield (f"serve/{name}/{arm}_p50_ms", a["p50_ms"] * 1e3,
                   round(a["p99_ms"], 2))
            yield (f"serve/{name}/{arm}_rps", 0.0,
                   round(a["throughput_rps"], 1))
        yield (f"serve/{name}/max_staleness_read", 0.0,
               p["serve_while_train"]["max_staleness_read"])
        yield (f"serve/{name}/bit_identical", 0.0,
               int(p["bit_identical"]))


def summary(out):
    for name, p in out["workloads"].items():
        yield (f"# serve/{name} spec={json.dumps(p['serve_spec'])} "
               f"plan={json.dumps(p['plan'])} "
               f"hist={json.dumps(p['serve_while_train']['staleness_hist'])}")
