"""Paper Fig 8/9 (right): Lasso convergence — STRADS dynamic schedule vs
Lasso-RR (Shotgun-style random scheduling), plus objective-vs-round
trajectories.  Laptop-scale re-run of the paper's 100M-feature experiment
(same correlated design §4.1, J scaled down; the *qualitative* claim —
dynamic priority + ρ-filter beats random scheduling and never diverges —
is scale-free and reproduces here)."""
from __future__ import annotations

import numpy as np

from repro.apps import lasso
from repro.core import single_device_mesh

from .common import save, timer


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    n, J = (200, 400) if quick else (500, 2000)
    rounds = 150 if quick else 400
    X, y, _ = lasso.synthetic_correlated(rng, n=n, J=J, corr=0.9, k_true=20)
    mesh = single_device_mesh()
    out = {"n": n, "J": J, "rounds": rounds, "traces": {}, "wall_s": {}}

    base = dict(num_features=J, lam=0.05, block_size=16,
                num_candidates=64, rho=0.3)
    for name, sched in (("strads", "strads"), ("rr", "rr")):
        cfg = lasso.LassoConfig(scheduler=sched, **base)
        with timer() as t:
            _, trace = lasso.fit(cfg, X, y, mesh, num_rounds=rounds,
                                 trace_every=10)
        out["traces"][name] = trace
        out["wall_s"][name] = round(t.s, 2)

    # headline: rounds to reach 102% of the STRADS final objective
    tgt = out["traces"]["strads"][-1][1] * 1.02
    def rounds_to(tr):
        for t, v in tr:
            if v <= tgt:
                return t
        return None
    out["target_objective"] = tgt
    out["rounds_to_target"] = {k: rounds_to(v)
                               for k, v in out["traces"].items()}
    save("bench_lasso", out)
    return out


def rows(out):
    for k, tr in out["traces"].items():
        yield (f"lasso/{k}/final_obj", out["wall_s"][k] * 1e6 / out["rounds"],
               tr[-1][1])
        rt = out["rounds_to_target"][k]
        yield (f"lasso/{k}/rounds_to_target", 0.0,
               rt if rt is not None else -1)
