"""Kernel-backend benchmark: reference jnp oracles vs the Pallas kernels.

With the round body's hot-spots behind a declarative ``KernelSpec``
(``ExecutionPlan.kernels``), a backend comparison is two plans differing
in one field.  For STRADS Lasso (correlated design, scanned executor)
this records end-to-end rounds/sec per backend (compile excluded,
interleaved best-of-3) and checks the two backends agree on the final
coefficients — the plan-level twin of the tests' kernel-level agreement
sweep.

Each hot-spot kernel (``lasso_partial``: z = X_Bᵀr; ``gram_block``:
G = X_CᵀX_C) is also microbenched standalone: the compiled program's
``cost_analysis()`` FLOPs / bytes-accessed give the *measured*
arithmetic intensity, reported against the v5e ridge point
(``PEAK_FLOPS / HBM_BW``) with the single-chip roofline terms — so the
artifact says not just which backend is faster here but where each
kernel sits on the roofline of the real target.

On this CPU container the Pallas kind runs in interpret mode (per-tile
lax ops, no Mosaic), so its rounds/sec UNDERSTATES the TPU backend —
the numbers prove dispatch plumbing and numerical agreement, not TPU
speedups; the roofline columns carry the target-relevant signal.

Writes ``benchmarks/results/BENCH_kernels.json`` (embedding the exact
``KernelSpec`` dicts and the resolved backend class per kind); uploaded
as a CI artifact by the bench-kernels job.
"""
from __future__ import annotations

import json

from .common import run_sub, save

_CODE = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.apps import lasso
from repro.core import ExecutionPlan, KernelSpec, worker_mesh
from repro.kernels import build_kernels
from repro.launch import roofline as RL

U, R = {workers}, {rounds}
rng = np.random.default_rng(0)
X, y, _ = lasso.synthetic_correlated(rng, n={rows}, J={feats}, corr=0.9,
                                     k_true=10)
cfg = lasso.LassoConfig(num_features={feats}, lam=0.02, block_size=16,
                        num_candidates=64)
mesh = worker_mesh(U)
eng = lasso.make_engine(cfg, mesh)
data = eng.shard_data({{"X": jnp.asarray(X), "y": jnp.asarray(y)}})
init = lambda: eng.init_state(jax.random.key(0), y=y)

# The comparison is two plans differing in ONE field — backend policy
# lives in the plan, exactly like scheduler/partitioner policy.
specs = {{"reference": KernelSpec(kind="reference"),
          "pallas": KernelSpec.default_for("pallas")}}
plans = {{name: ExecutionPlan(executor="scan", rounds=R, kernels=spec)
          for name, spec in specs.items()}}
run = lambda st, plan: eng.execute(st, data, jax.random.key(1), plan).state

finals = {{}}
for name, plan in plans.items():             # compile warmup, all first
    finals[name] = run(init(), plan)
agree = bool(np.allclose(np.asarray(finals["reference"]["beta"]),
                         np.asarray(finals["pallas"]["beta"]),
                         rtol=1e-4, atol=1e-5))

# Interleaved best-of-3: a slow minute on a shared box hits every
# backend, not whichever happened to be measured during it.
best = {{name: 0.0 for name in plans}}
for _ in range(3):
    for name, plan in plans.items():
        st = init()
        t0 = time.time()
        jax.block_until_ready(run(st, plan))
        best[name] = max(best[name], R / (time.time() - t0))

# Per-kernel microbench: compiled-program cost_analysis gives measured
# FLOPs / bytes-accessed -> arithmetic intensity vs the v5e ridge, plus
# single-chip roofline terms (no collectives at kernel granularity).
n_p = {rows} // U
Xb = jnp.asarray(rng.standard_normal((n_p, 16)), jnp.float32)
r = jnp.asarray(rng.standard_normal((n_p,)), jnp.float32)
Xc = jnp.asarray(rng.standard_normal((n_p, 64)), jnp.float32)
micro, backends = {{}}, {{}}
for name, spec in specs.items():
    backend = build_kernels(spec)
    backends[name] = {{"class": type(backend).__name__,
                       "interpret": bool(getattr(backend, "interpret",
                                                 False))}}
    micro[name] = {{}}
    for kname, fn, args in (
            ("lasso_partial", backend.lasso_partial, (Xb, r)),
            ("gram_block", backend.gram_block, (Xc,))):
        compiled = jax.jit(fn).lower(*args).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        flops = float(ca.get("flops", 0.0))
        byts = float(ca.get("bytes accessed", 0.0))
        micro[name][kname] = {{
            "flops": flops, "bytes": byts,
            "intensity": RL.arithmetic_intensity(flops, byts),
            "ridge_intensity": RL.RIDGE_INTENSITY,
            "roofline": RL.roofline_terms(flops, byts, 0.0),
        }}

out = {{
    "agreement": agree,
    "platform": jax.default_backend(),
    "specs": {{name: s.to_json() for name, s in specs.items()}},
    "backends": backends,
    "engine": {{name: {{"rounds_per_sec": best[name],
                        "plan": plans[name].to_json()}}
                for name in plans}},
    "kernels": micro,
}}
print("PAYLOAD:" + json.dumps(out))
"""


def run(quick: bool = True):
    rounds = 40 if quick else 200
    rows_, feats = (256, 512) if quick else (2048, 2048)
    out = {"rounds": rounds, "rows": rows_, "feats": feats, "workers": {}}
    for U in (1, 4):
        stdout = run_sub(_CODE.format(workers=U, rounds=rounds,
                                      rows=rows_, feats=feats),
                         devices=U, timeout=560)
        payload = json.loads(
            stdout.strip().splitlines()[-1][len("PAYLOAD:"):])
        if not payload["agreement"]:
            raise RuntimeError(
                f"kernel backends disagree on final beta at U={U}")
        out["workers"][U] = payload
    save("BENCH_kernels", out)
    return out


def rows(out):
    for U, p in out["workers"].items():
        for name, rec in p["engine"].items():
            rps = rec["rounds_per_sec"]
            yield (f"kernels/U{U}/{name}_us_per_round", 1e6 / rps,
                   round(rps, 2))
        for name, kernels in p["kernels"].items():
            for kname, m in kernels.items():
                yield (f"kernels/U{U}/{name}_{kname}_intensity", 0.0,
                       round(m["intensity"], 3))


def summary(out):
    """Extra lines for the harness: the resolved backend + spec dicts
    (what a plan's ``kernels`` field actually dispatched)."""
    for U, p in out["workers"].items():
        for name, spec in p["specs"].items():
            backend = p["backends"][name]
            yield (f"# kernels/U{U}/{name}: spec={json.dumps(spec)} "
                   f"backend={json.dumps(backend)} "
                   f"platform={p['platform']}")
