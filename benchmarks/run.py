"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only lasso,mf,...]

Prints ``name,us_per_call,derived`` CSV rows (plus writes JSON payloads to
benchmarks/results/).  The roofline/dry-run tables render from the cached
dry-run artifacts if present (run launch/dryrun.py --all to regenerate).
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (bench_kernels, bench_lasso, bench_lda, bench_memory,
               bench_mf, bench_obs, bench_part, bench_pipeline,
               bench_scaling, bench_sched, bench_serve, bench_ssp,
               bench_stream)

BENCHES = {
    "lasso": bench_lasso,       # Fig 8/9 right
    "mf": bench_mf,             # Fig 8/9 center
    "lda": bench_lda,           # Fig 5 + Fig 8/9 left
    "memory": bench_memory,     # Fig 3
    "scaling": bench_scaling,   # Fig 10
    "pipeline": bench_pipeline,  # loop vs scan vs pipelined executor
    "ssp": bench_ssp,           # bounded staleness vs BSP (repro.ps)
    "sched": bench_sched,       # scheduler-policy ρ × U′ sweep (repro.sched)
    "part": bench_part,         # partition-policy static vs load_balanced
    "kernels": bench_kernels,   # kernel backend reference vs pallas
    "obs": bench_obs,           # telemetry overhead off/counters/trace
    "serve": bench_serve,       # serve-only vs serve-while-train (repro.serve)
    "stream": bench_stream,     # static vs streaming ingest (repro.stream)
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale-ish sizes (slower)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of "
                         f"{','.join(BENCHES)},roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    if only:
        # a typo'd name used to run nothing and exit 0 — fail loudly
        unknown = only - set(BENCHES) - {"roofline"}
        if unknown:
            ap.error(f"unknown benchmark name(s) {sorted(unknown)}; "
                     f"valid: {sorted(BENCHES) + ['roofline']}")

    print("name,us_per_call,derived")
    failed = []
    for name, mod in BENCHES.items():
        if only and name not in only:
            continue
        try:
            out = mod.run(quick=not args.full)
            for row in mod.rows(out):
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            # benches may expose extra summary lines (e.g. the resolved
            # KernelSpec/backend dicts from bench_kernels)
            if hasattr(mod, "summary"):
                for line in mod.summary(out):
                    print(line)
        except Exception:
            traceback.print_exc()
            failed.append(name)

    if only is None or "roofline" in only:
        try:
            from . import roofline_report
            rows = roofline_report.load("baseline")
            ok = sum(1 for r in rows if "roofline" in r)
            sk = sum(1 for r in rows if "skipped" in r)
            print(f"roofline/dryrun_results,0.0,{ok}")
            print(f"roofline/dryrun_skipped,0.0,{sk}")
        except Exception:
            traceback.print_exc()
            failed.append("roofline")

    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
