"""Scheduler-policy benchmark: the ρ × U′ sweep the SchedulerSpec opens.

The paper's claim is that scheduling *policy* buys convergence speed:
the ρ-dependency filter keeps parallel CD stable on correlated designs,
and the priority sampling focuses rounds on moving coordinates.  With
the policy now a declarative ``SchedulerSpec`` on the ``ExecutionPlan``,
a policy sweep is literally a dict of plans — no app edits.

For ρ ∈ {0.1, 0.3, 0.6} × U′ ∈ {U, 2U, 4U} on STRADS Lasso (correlated
design, scanned executor), this records rounds/sec (compile excluded,
interleaved best-of-3) AND the objective-vs-round curve, plus a
round-robin baseline for context.  Tighter ρ / larger U′ costs schedule
time (bigger Gram psum, stricter filter) but buys per-round progress —
the artifact captures both sides so the trade-off is data, not
assertion.

Writes ``benchmarks/results/BENCH_sched.json`` (each sweep point embeds
the exact plan + scheduler-spec dicts) for the cross-PR trajectory;
uploaded as a CI artifact by the bench-sched job.
"""
from __future__ import annotations

import json

from .common import run_sub, save

_CODE = """
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.apps import lasso
from repro.core import ExecutionPlan, SchedulerSpec, worker_mesh

U, R, BS = {workers}, {rounds}, 16
rng = np.random.default_rng(0)
X, y, _ = lasso.synthetic_correlated(rng, n={rows}, J={feats}, corr=0.9,
                                     k_true=10)
cfg = lasso.LassoConfig(num_features={feats}, lam=0.02, block_size=BS)
mesh = worker_mesh(U)
eng = lasso.make_engine(cfg, mesh)
data = eng.shard_data({{"X": jnp.asarray(X), "y": jnp.asarray(y)}})
init = lambda: eng.init_state(jax.random.key(0), y=y)
collect = eng.app.objective_collect()

# The sweep is a dict of ExecutionPlans — policy lives in the plan.
plans = {{"round_robin": ExecutionPlan(
    executor="scan", rounds=R,
    scheduler=SchedulerSpec(kind="round_robin", block_size=BS))}}
for rho in (0.1, 0.3, 0.6):
    for uprime in (BS, 2 * BS, 4 * BS):
        spec = SchedulerSpec(kind="dynamic_priority", block_size=BS,
                             num_candidates=uprime, rho=rho, eta=1e-3)
        plans[f"rho{{rho}}_U{{uprime}}"] = ExecutionPlan(
            executor="scan", rounds=R, scheduler=spec)

run = lambda st, plan: eng.execute(st, data, jax.random.key(1), plan).state

for plan in plans.values():                  # compile warmup, all first
    run(init(), plan)

# Interleaved best-of-3: a slow minute on a shared box hits every
# config, not whichever happened to be measured during it.
best = {{name: 0.0 for name in plans}}
for _ in range(3):
    for name, plan in plans.items():
        st = init()
        t0 = time.time()
        jax.block_until_ready(run(st, plan))
        best[name] = max(best[name], R / (time.time() - t0))

out = {{}}
stride = max(1, R // 20)
for name, plan in plans.items():
    tplan = ExecutionPlan(executor="scan", rounds=R, collect_every=1,
                          scheduler=plan.scheduler)
    rep = eng.execute(init(), data, jax.random.key(1), tplan,
                      collect=collect)
    obj = np.asarray(rep.trace)
    out[name] = {{
        "rounds_per_sec": best[name],
        "objective": [float(v) for v in obj[::stride]] + [float(obj[-1])],
        "plan": tplan.to_json(),
        "scheduler": tplan.scheduler.to_json(),
    }}
print("PAYLOAD:" + json.dumps(out))
"""


def run(quick: bool = True):
    rounds = 60 if quick else 300
    rows, feats = (256, 512) if quick else (2048, 2048)
    out = {"rounds": rounds, "rows": rows, "feats": feats, "workers": {}}
    for U in (1, 4):
        stdout = run_sub(_CODE.format(workers=U, rounds=rounds,
                                      rows=rows, feats=feats),
                         devices=U, timeout=560)
        payload = json.loads(
            stdout.strip().splitlines()[-1][len("PAYLOAD:"):])
        out["workers"][U] = payload
    save("BENCH_sched", out)
    return out


def rows(out):
    for U, p in out["workers"].items():
        for name, rec in p.items():
            rps = rec["rounds_per_sec"]
            yield (f"sched/U{U}/{name}_us_per_round", 1e6 / rps,
                   round(rps, 2))
            yield (f"sched/U{U}/{name}_final_objective", 0.0,
                   round(rec["objective"][-1], 4))
