"""Paper Fig 5 + Fig 8/9 (left): LDA.

* Fig 5 — the s-error Δ_t of the word-rotation schedule stays tiny
  (paper: ≤ 0.002 at 64 machines).  We measure the same Δ_t (eq. 1) on a
  4-worker mesh; the rotation keeps workers on disjoint word blocks so the
  error stays ≈0 by construction.
* Fig 8/9 — log-likelihood trajectories, STRADS model-parallel Gibbs vs a
  YahooLDA-style data-parallel baseline with a replicated word-topic
  table (which goes stale between syncs).
"""
from __future__ import annotations

import json

from .common import run_sub, save

_CODE = """
import json
import numpy as np, jax
from repro.apps import lda
from repro.core import worker_mesh

U = {workers}
cfg = lda.LDAConfig(num_workers=U, vocab={vocab}, num_topics={topics},
                    tokens_per_worker={tpw}, docs_per_worker={dpw})
rng = np.random.default_rng(0)
words, docs, z0 = lda.synthetic_corpus(rng, cfg)
mesh = worker_mesh(U)
out = {{}}
st, trace, s_errs = lda.fit(cfg, words, docs, z0, mesh, {rounds},
                            trace_every=4)
out["strads"] = trace
out["s_err"] = s_errs
st2, trace2, _ = lda.fit(cfg, words, docs, z0, mesh, {rounds},
                         baseline=True, trace_every=4)
out["baseline"] = trace2
print("PAYLOAD:" + json.dumps(out))
"""


def run(quick: bool = True):
    workers = 4
    params = dict(workers=workers, vocab=200 if quick else 1000,
                  topics=8 if quick else 20,
                  tpw=1500 if quick else 8000,
                  dpw=30 if quick else 100,
                  rounds=24 if quick else 60)
    stdout = run_sub(_CODE.format(**params), devices=workers, timeout=560)
    payload = json.loads(stdout.strip().splitlines()[-1][len("PAYLOAD:"):])
    out = dict(params, **payload)
    out["max_s_err"] = max((v for _, v in out["s_err"]), default=0.0)
    save("bench_lda", out)
    return out


def rows(out):
    yield ("lda/strads/final_loglik", 0.0, out["strads"][-1][1])
    yield ("lda/baseline/final_loglik", 0.0, out["baseline"][-1][1])
    yield ("lda/max_s_error", 0.0, out["max_s_err"])
