"""Paper §3.3 + Fig 8/9 (right): scheduling on *correlated* Lasso designs.

With 65 % of adjacent feature pairs strongly correlated, naive parallel
CD over contiguous blocks (cyclic) **diverges** — the objective explodes
by orders of magnitude, exactly the failure mode Bradley et al. [2011]
identified and the reason STRADS filters co-scheduled coordinates by
|x_jᵀx_k| < ρ.  Random scheduling (Lasso-RR) avoids the worst case by
luck; the STRADS dynamic schedule is *guaranteed* stable by the ρ-filter
and prioritizes fast-converging coefficients on top.

    PYTHONPATH=src python examples/lasso_vs_rr.py [--rounds 200]
"""
import argparse
import math

import numpy as np

from repro.apps import lasso
from repro.core import single_device_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--features", type=int, default=400)
    ap.add_argument("--corr", type=float, default=0.35,
                    help="P(fresh noise); lower = more correlated design")
    args = ap.parse_args()

    rng = np.random.default_rng(1)
    X, y, _ = lasso.synthetic_correlated(rng, n=200, J=args.features,
                                         corr=args.corr, k_true=20)
    mesh = single_device_mesh()

    results = {}
    print(f"{'scheduler':12s} {'U':>4s} {'final objective':>18s} "
          f"{'nnz(beta)':>10s}")
    for scheduler in ("strads", "rr", "cyclic"):
        for U in (8, 32):
            cfg = lasso.LassoConfig(
                num_features=args.features, lam=0.05, block_size=U,
                num_candidates=4 * U, rho=0.3, scheduler=scheduler)
            state, trace = lasso.fit(cfg, X, y, mesh,
                                     num_rounds=args.rounds,
                                     trace_every=args.rounds - 1)
            obj = trace[-1][1]
            beta = np.asarray(state["beta"])
            results[(scheduler, U)] = obj
            print(f"{scheduler:12s} {U:4d} {obj:18.4g} "
                  f"{int((np.abs(beta) > 1e-6).sum()):10d}")

    diverged = [k for k, v in results.items()
                if not math.isfinite(v) or v > 1e3]
    print(f"\ndiverged runs: {diverged or 'none'}")
    assert all("strads" != k[0] for k in diverged), \
        "the rho-filtered schedule must never diverge"
    assert any(k[0] == "cyclic" for k in diverged), \
        "naive contiguous parallel CD should diverge on this design"
    print("cyclic parallel CD diverges on the correlated design; the "
          "STRADS ρ-filter keeps every run stable — the paper's safety "
          "claim. (Lasso-RR survives by luck; on adversarial designs it "
          "diverges too — see tests/test_lasso.py.)")


if __name__ == "__main__":
    main()
