"""End-to-end training example: train a ~20M-param reduced MiniCPM on the
synthetic pipeline for a few hundred steps, with the WSD schedule the
MiniCPM paper uses, then do the same with STRADS block-coordinate
scheduling and compare trajectories.

    PYTHONPATH=src python examples/train_transformer.py [--steps 200]

(The launcher this wraps — repro.launch.train — drives the same pjit
train_step the 256/512-chip dry-run lowers; on TPU pods the only change
is the mesh.)
"""
import argparse

from repro.launch import train as train_launcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="minicpm-2b")
    args = ap.parse_args()

    common = ["--arch", args.arch, "--preset", "reduced",
              "--steps", str(args.steps), "--batch", "8", "--seq", "128",
              "--log-every", str(max(args.steps // 10, 1))]

    print("=== dense AdamW training (all blocks every step) ===")
    hist = train_launcher.main(common)
    full_first, full_last = hist[0]["loss"], hist[-1]["loss"]

    print("\n=== STRADS block-coordinate training (schedule/push/pull) ===")
    hist2 = train_launcher.main(common + ["--strads"])
    s_first, s_last = hist2[0]["loss"], hist2[-1]["loss"]

    print(f"\nloss: dense {full_first:.3f}→{full_last:.3f}   "
          f"STRADS-blocks {s_first:.3f}→{s_last:.3f}")
    assert full_last < full_first and s_last < s_first
    print("both trainers converge; the STRADS variant updates only the "
          "scheduled blocks per step (≈half the optimizer work).")


if __name__ == "__main__":
    main()
