"""Quickstart: the STRADS primitives in ~60 lines.

Solves a small correlated Lasso with the paper's dynamic schedule
(priority ∝ |Δβ| + η, ρ-dependency filter), then shows the same app
with the filter disabled (the Lasso-RR / Shotgun baseline) failing to
match it — the paper's Fig 9 (right) in miniature.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.apps import lasso
from repro.core import single_device_mesh


def main():
    rng = np.random.default_rng(0)
    # correlated design (adjacent features ~0.9-correlated): the regime
    # where naive parallel CD diverges [Bradley et al. 2011]
    X, y, beta_star = lasso.synthetic_correlated(rng, n=150, J=300,
                                                 corr=0.9, k_true=12)
    mesh = single_device_mesh()

    base = dict(num_features=300, lam=0.05, block_size=16,
                num_candidates=64, rho=0.3)
    results = {}
    for name, scheduler in (("STRADS (dynamic)", "strads"),
                            ("Lasso-RR (random)", "rr")):
        cfg = lasso.LassoConfig(scheduler=scheduler, **base)
        state, trace = lasso.fit(cfg, X, y, mesh, num_rounds=120,
                                 trace_every=20)
        results[name] = trace
        print(f"\n{name}")
        for t, obj in trace:
            print(f"  round {t:4d}   objective {obj:10.4f}")

    s_final = results["STRADS (dynamic)"][-1][1]
    r_final = results["Lasso-RR (random)"][-1][1]
    print(f"\nfinal objective — STRADS {s_final:.4f}  vs  RR {r_final:.4f}")
    assert s_final <= r_final + 1e-6, "dynamic schedule should win"
    print("dynamic scheduling converged faster, as in paper Fig 9 (right)")


if __name__ == "__main__":
    main()
