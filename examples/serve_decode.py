"""Serving example: batched prefill + autoregressive decode for three
architecture families — attention (ring-buffer KV cache), hybrid
SSM+shared-attention (recurrent state + windowed cache), and xLSTM
(pure recurrent state, no KV cache at all).

    PYTHONPATH=src python examples/serve_decode.py

The LM decode driver lives at ``repro.launch.serve_lm`` (the
``repro.launch.serve`` path now hosts the STRADS bounded-staleness
serving CLI, whose flags are ``--engine``/``--rounds``/...).
"""
from repro.launch import serve_lm as serve_launcher


def main():
    for arch, extra in (
        ("granite-3-2b", ["--window", "48"]),   # sliding-window ring buffer
        ("zamba2-2.7b", []),                    # Mamba2 + shared attention
        ("xlstm-125m", []),                     # recurrent state only
    ):
        print(f"\n=== {arch} ===")
        serve_launcher.main(["--arch", arch, "--preset", "reduced",
                             "--batch", "2", "--prompt-len", "48",
                             "--gen", "16"] + extra)


if __name__ == "__main__":
    main()
