"""Deterministic fallback for `hypothesis` when it isn't installed.

CI installs the real hypothesis via the ``test`` extra in pyproject.toml;
this stub only kicks in on bare environments (no network, no extras) so
the suite still collects and the property tests still run — each
``@given`` test executes ``max_examples`` deterministic samples drawn
from a fixed-seed RNG.  It implements exactly the subset this repo's
tests use: ``given``, ``settings``, and ``strategies.integers / floats /
booleans / sampled_from``.

Activated by ``conftest.py`` installing this module under the name
``hypothesis`` in ``sys.modules``; it must never shadow the real package.
"""
from __future__ import annotations

import inspect
import sys
import types

import numpy as np

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, sampler):
        self._sampler = sampler

    def sample(self, rng: np.random.Generator):
        return self._sampler(rng)


def _integers(min_value, max_value):
    # hypothesis integers: both bounds inclusive
    return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)))


def _floats(min_value, max_value):
    return _Strategy(lambda r: float(r.uniform(min_value, max_value)))


def _booleans():
    return _Strategy(lambda r: bool(r.integers(0, 2)))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: elements[int(r.integers(len(elements)))])


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        def wrapper(*fixture_args, **fixture_kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            rng = np.random.default_rng(0)
            for _ in range(n):
                vals = [s.sample(rng) for s in strats]
                fn(*fixture_args, *vals, **fixture_kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._stub_max_examples = getattr(fn, "_stub_max_examples",
                                             DEFAULT_MAX_EXAMPLES)
        # Hide the strategy-filled parameters from pytest's fixture
        # resolution: only leading params (fixtures) remain visible.
        params = list(inspect.signature(fn).parameters.values())
        remaining = params[:max(0, len(params) - len(strats))]
        wrapper.__signature__ = inspect.Signature(remaining)
        return wrapper
    return deco


def install() -> None:
    """Register this module as ``hypothesis`` (no-op if the real one is
    importable)."""
    if "hypothesis" in sys.modules:         # pragma: no cover
        return
    mod = sys.modules[__name__]
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
