"""The pluggable scheduler subsystem (ISSUE 4 acceptance).

Contract under test:
  * ``SchedulerSpec`` is a frozen, hashable value; invalid
    kind/parameter combinations raise at construction (mirroring
    ``ExecutionPlan``), and ``to_json → from_json`` round-trips exactly,
    defaults included — standalone and nested in a plan;
  * ``dependency_filter`` property (hypothesis): every kept pair has
    |gram| < ρ, at most ``block_size`` kept, candidate 0 always admitted
    — for both gram backends (data Gram and structural distance);
  * a plan carrying an explicit ``SchedulerSpec`` equal to the app's old
    default is bit-identical to the default run on all four executors,
    and ``fit(plan=...)`` swaps policy without touching app config;
  * the scheduler carry is engine-owned: it returns in
    ``EngineCarry.sched_carry`` / ``SSPCarry.sched_carry`` and the SSP
    in-flight exclusion runs on it;
  * ``repro.core.schedulers`` / ``repro.core.block_scheduler`` still
    import, with a DeprecationWarning (the PR 3 shim pattern).
"""
import importlib
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.apps import lasso, lda, mf
from repro.core import ExecutionPlan, single_device_mesh
from repro.sched import (BlockStructuralScheduler, Scheduler, SchedulerSpec,
                         build_scheduler, dependency_filter,
                         sample_candidates, structural_gram)
from repro.sched.block import BlockScheduleConfig, select_blocks


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


def _bit_identical(a_state, b_state):
    assert set(a_state) == set(b_state)
    for k in a_state:
        a, b = np.asarray(a_state[k]), np.asarray(b_state[k])
        assert (a == b).all(), (k, np.max(np.abs(a - b)))


def _dyn_spec(**kw):
    base = dict(kind="dynamic_priority", block_size=4, num_candidates=8,
                rho=0.3, eta=1e-6)
    base.update(kw)
    return SchedulerSpec(**base)


# ---------------------------------------------------------------------------
# construction-time validation (mirrors tests/test_plan.py)
# ---------------------------------------------------------------------------

def test_spec_is_hashable_value():
    a, b = _dyn_spec(), _dyn_spec()
    assert a == b and hash(a) == hash(b) and len({a, b}) == 1


def test_spec_rejects_unknown_kind_with_canonical_message():
    with pytest.raises(ValueError, match="scheduler kind must be "
                                         "'round_robin', 'random'"):
        SchedulerSpec(kind="warp", block_size=4)


@pytest.mark.parametrize("kw", [
    dict(kind="round_robin"),                       # needs block_size
    dict(kind="round_robin", block_size=4, rho=0.3),  # rho is dynamic-only
    dict(kind="random", block_size=0),
    dict(kind="random", block_size=4, num_candidates=8),
    dict(kind="rotation", block_size=4),            # rotation takes nothing
    dict(kind="dynamic_priority", block_size=8, num_candidates=4,
         rho=0.3, eta=1e-6),                        # U' < U
    dict(kind="dynamic_priority", block_size=4, num_candidates=8,
         rho=0.0, eta=1e-6),                        # needs rho > 0
    dict(kind="dynamic_priority", block_size=4, num_candidates=8,
         rho=-0.3, eta=1e-6),
    dict(kind="block_structural", block_size=2, num_candidates=4,
         rho=0.5, eta=-1e-3, min_distance=2, ema=0.9),  # eta >= 0
    dict(kind="dynamic_priority", block_size=4, num_candidates=8,
         rho=0.3, eta=1e-6, min_distance=2),        # structural-only
    dict(kind="block_structural", block_size=2, num_candidates=4,
         rho=0.5, eta=1e-3, min_distance=0, ema=0.9),  # needs distance >= 1
    dict(kind="block_structural", block_size=2, num_candidates=4,
         rho=0.5, eta=1e-3, min_distance=2, ema=1.0),  # ema < 1
    dict(kind="dynamic_priority", block_size=-1, num_candidates=8,
         rho=0.3, eta=1e-6),
])
def test_invalid_spec_combinations_raise_at_construction(kw):
    with pytest.raises(ValueError):
        SchedulerSpec(**kw)


# ---------------------------------------------------------------------------
# JSON round-trip (standalone and nested in ExecutionPlan)
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip_exact_including_defaults():
    specs = [
        SchedulerSpec(kind="rotation"),
        SchedulerSpec(kind="round_robin", block_size=8),
        _dyn_spec(rho=0.6, num_candidates=64),
        SchedulerSpec(kind="block_structural", block_size=2,
                      num_candidates=4, rho=0.5, eta=1e-3,
                      min_distance=2, ema=0.9),
    ]
    for s in specs:
        d = s.to_json()
        assert SchedulerSpec.from_json(d) == s
        assert SchedulerSpec.from_json(json.dumps(d)) == s
    with pytest.raises(ValueError, match="unknown SchedulerSpec field"):
        SchedulerSpec.from_json({"kind": "random", "blocksize": 4})


def test_plan_json_roundtrips_with_and_without_scheduler():
    with_spec = ExecutionPlan(executor="ssp", rounds=12, staleness=2,
                              scheduler=_dyn_spec())
    without = ExecutionPlan(executor="ssp", rounds=12, staleness=2)
    for p in (with_spec, without):
        d = p.to_json()
        assert ExecutionPlan.from_json(d) == p
        assert ExecutionPlan.from_json(json.dumps(d)) == p
    # the nested spec serializes as a plain dict (JSON-safe all the way)
    assert with_spec.to_json()["scheduler"]["kind"] == "dynamic_priority"
    assert without.to_json()["scheduler"] is None
    # invalid nested specs raise through from_json (construction-time)
    with pytest.raises(ValueError, match="needs rho > 0"):
        ExecutionPlan.from_json({"executor": "scan", "rounds": 4,
                                 "scheduler": {"kind": "dynamic_priority",
                                               "block_size": 4,
                                               "num_candidates": 8,
                                               "eta": 1e-6}})
    # previously-legal degenerate configs stay constructible: eta=0
    # (no exploration floor) and rho>1 (filter disabled)
    assert SchedulerSpec.from_json(
        {"kind": "dynamic_priority", "block_size": 4,
         "num_candidates": 8, "rho": 1.5, "eta": 0.0}).rho == 1.5
    with pytest.raises(ValueError, match="SchedulerSpec"):
        ExecutionPlan(executor="scan", rounds=4, scheduler="dynamic")


# ---------------------------------------------------------------------------
# the dependency filter property (both gram backends)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.floats(0.05, 0.95), st.integers(1, 8),
       st.integers(0, 2**31 - 1))
def test_dependency_filter_invariant(u, rho, max_sel, seed):
    """Every kept pair satisfies |gram| < ρ, at most ``max_select`` are
    kept, and candidate 0 is always admitted (greedy over an empty set)."""
    r = np.random.default_rng(seed)
    A = r.normal(size=(20, u)).astype(np.float32)
    A /= np.linalg.norm(A, axis=0, keepdims=True)
    gram = jnp.asarray(A.T @ A)
    keep = np.asarray(dependency_filter(gram, rho=rho, max_select=max_sel))
    assert keep.sum() <= max_sel
    assert keep[0]                       # greedy always admits the first
    kept = np.where(keep)[0]
    g = np.abs(np.asarray(gram))
    for a in kept:
        for b in kept:
            if a < b:
                assert g[a, b] < rho


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 10), st.integers(1, 4), st.integers(1, 6),
       st.integers(0, 2**31 - 1))
def test_structural_backend_is_the_same_filter(u, min_dist, max_sel, seed):
    """The block scheduler's distance rule is literally
    ``dependency_filter`` fed the structural gram: every kept pair is
    ``min_distance`` apart, ≤ max_select kept, candidate 0 admitted."""
    r = np.random.default_rng(seed)
    cand = jnp.asarray(r.choice(32, size=u, replace=False).astype(np.int32))
    keep = np.asarray(dependency_filter(
        structural_gram(cand, min_dist), 0.5, max_sel))
    assert keep.sum() <= max_sel
    assert keep[0]
    kept = np.asarray(cand)[np.where(keep)[0]]
    for a in kept:
        for b in kept:
            if a != b:
                assert abs(int(a) - int(b)) >= min_dist


def test_select_blocks_goes_through_shared_filter():
    """The (num_blocks,) trainer mask equals the shared-filter keep set
    scattered onto candidate positions (no parallel f₂ implementation)."""
    cfg = BlockScheduleConfig(num_blocks=16, blocks_per_step=4,
                              candidates_per_step=8, min_distance=3)
    rng = jax.random.key(7)
    mask = np.asarray(select_blocks(cfg, jnp.ones(16), rng))
    cand = np.asarray(sample_candidates(rng, jnp.ones(16) + cfg.eta, 8))
    keep = np.asarray(dependency_filter(
        structural_gram(jnp.asarray(cand), 3), cfg.rho, 4))
    want = np.zeros(16, np.float32)
    want[cand] = keep.astype(np.float32)
    assert (mask == want).all()


# ---------------------------------------------------------------------------
# spec → scheduler construction and the protocol surface
# ---------------------------------------------------------------------------

def test_build_scheduler_dispatch_and_protocol():
    for spec, carryful in [
            (SchedulerSpec(kind="round_robin", block_size=4), False),
            (SchedulerSpec(kind="random", block_size=4), False),
            (SchedulerSpec(kind="rotation"), False),
            (_dyn_spec(), True),
            (SchedulerSpec(kind="block_structural", block_size=2,
                           num_candidates=4, rho=0.5, eta=1e-3,
                           min_distance=2, ema=0.9), True)]:
        sched = build_scheduler(spec, num_vars=20, num_workers=2)
        assert isinstance(sched, Scheduler), spec.kind
        carry = sched.init_carry()
        assert (carry is not None) == carryful, spec.kind
    with pytest.raises(TypeError, match="SchedulerSpec"):
        build_scheduler("dynamic_priority", num_vars=8, num_workers=1)


def test_block_structural_scheduler_respects_distance():
    sched = BlockStructuralScheduler(num_blocks=24, block_size=4,
                                     num_candidates=12, min_distance=3)
    carry = sched.init_carry()
    cand = sched.propose(carry, jax.random.key(0))
    idx, mask = sched.finalize(cand)
    idx, mask = np.asarray(idx), np.asarray(mask)
    kept = idx[mask]
    assert 1 <= len(kept) <= 4
    for a in kept:
        for b in kept:
            if a != b:
                assert abs(int(a) - int(b)) >= 3
    # carry update only moves scheduled entries
    new = np.asarray(sched.update_carry(carry, jnp.asarray(idx),
                                        jnp.asarray(mask),
                                        10.0 * jnp.ones(len(idx))))
    untouched = np.setdiff1d(np.arange(24), kept)
    assert (new[untouched] == 1.0).all()
    assert (new[kept] != 1.0).all()


def test_apps_declare_default_specs():
    assert lda.StradsLDA(lda.LDAConfig(
        vocab=30, num_topics=4, num_workers=1, tokens_per_worker=8,
        docs_per_worker=2)).default_scheduler_spec() == \
        SchedulerSpec(kind="rotation")
    assert mf.StradsMF(mf.MFConfig(
        num_rows=8, num_cols=6, rank=4,
        ranks_per_round=2)).default_scheduler_spec() == \
        SchedulerSpec(kind="round_robin", block_size=2)


# ---------------------------------------------------------------------------
# plan-carried policy ≡ app default (the acceptance bit-identity), and
# policy swaps without app edits
# ---------------------------------------------------------------------------

def test_explicit_default_spec_is_bit_identical_all_executors(mesh, rng):
    """A plan carrying an explicit SchedulerSpec equal to the app's
    default must run bit-identically to the spec-less plan on every
    executor (the redesign moved the policy without moving the math)."""
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    spec = eng.app.default_scheduler_spec()

    for name, s in [("loop", 0), ("scan", 0), ("pipelined", 0),
                    ("ssp", 1)]:
        base = ExecutionPlan(executor=name, rounds=8, staleness=s)
        withspec = ExecutionPlan(executor=name, rounds=8, staleness=s,
                                 scheduler=spec)
        a = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                        jax.random.key(1), base)
        b = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                        jax.random.key(1), withspec)
        _bit_identical(a.state, b.state)
        assert (np.asarray(a.carry.sched_carry)
                == np.asarray(b.carry.sched_carry)).all(), name


def test_plan_swaps_policy_without_touching_app_config(mesh, rng):
    """fit(plan=...) with a different SchedulerSpec must override the
    config policy — and reproduce the config that names that policy."""
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    strads = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                               num_candidates=8, rho=0.3)
    cyclic = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                               scheduler="cyclic")
    # strads config + round_robin plan == cyclic config, bit for bit
    s_plan, _ = lasso.fit(strads, X, y, mesh, plan=ExecutionPlan(
        executor="scan", rounds=8,
        scheduler=SchedulerSpec(kind="round_robin", block_size=4)))
    s_cfg, _ = lasso.fit(cyclic, X, y, mesh,
                         plan=ExecutionPlan(executor="scan", rounds=8))
    _bit_identical(s_plan, s_cfg)
    # and a rho sweep point differs from the default (the knob is live)
    s_rho, _ = lasso.fit(strads, X, y, mesh, plan=ExecutionPlan(
        executor="scan", rounds=8,
        scheduler=SchedulerSpec(kind="dynamic_priority", block_size=4,
                                num_candidates=8, rho=0.05, eta=1e-6)))
    s_def, _ = lasso.fit(strads, X, y, mesh,
                         plan=ExecutionPlan(executor="scan", rounds=8))
    assert not (np.asarray(s_rho["beta"])
                == np.asarray(s_def["beta"])).all()


def test_mf_takes_injected_policy_via_plan(mesh, rng):
    """The rank dispatch is swappable too: a random-rank plan runs (and
    differs from round-robin), with no MF config surface involved — and
    stochastic policies still pair the two halves of each H/W cycle
    (the proposal key derives from the cycle index)."""
    A, mask = mf.synthetic_ratings(rng, 20, 15, true_rank=3, density=0.5)
    cfg = mf.MFConfig(num_rows=20, num_cols=15, rank=3, lam=0.05)
    # 12 rounds = 6 cycles: the cycle-keyed random draws provably leave
    # the round-robin sequence by cycle 5 (at 4 cycles they coincide)
    rr, _ = mf.fit(cfg, A, mask, mesh,
                   plan=ExecutionPlan(executor="scan", rounds=12))
    rnd, _ = mf.fit(cfg, A, mask, mesh, plan=ExecutionPlan(
        executor="scan", rounds=12,
        scheduler=SchedulerSpec(kind="random", block_size=1)))
    assert not (np.asarray(rr["H"]) == np.asarray(rnd["H"])).all()

    eng = mf.make_engine(cfg, mesh)
    eng.set_scheduler(SchedulerSpec(kind="random", block_size=1))
    data = eng.shard_data({"A": jnp.asarray(A), "mask": jnp.asarray(mask)})
    st = eng.init_state(jax.random.key(0), A=jnp.asarray(A),
                        mask=jnp.asarray(mask))
    sc = eng.init_sched_carry()
    ranks = []
    for t in range(6):
        out = eng.run_round(st, data, jax.random.key(t), t,
                            sched_carry=sc)
        st, sc = out.state, out.sched_carry
        ranks.append(int(np.asarray(out.sched["ranks"])[0]))
    assert all(ranks[2 * i] == ranks[2 * i + 1] for i in range(3)), ranks


def test_ssp_in_flight_exclusion_runs_on_the_carry(mesh, rng):
    """At s >= 1 the window's later proposals must not re-pick the
    coordinates already in flight: propose from the marked carry never
    overlaps the first proposal (device-checked via the scheduler's own
    mark_scheduled semantics)."""
    spec = _dyn_spec(num_candidates=6, block_size=3)
    sched = build_scheduler(spec, num_vars=12, num_workers=1)
    carry = 10.0 * jnp.ones(12)                 # strong, uniform priority
    c1 = sched.propose(carry, jax.random.key(0))
    marked = sched.mark_scheduled(carry, c1)
    assert (np.asarray(marked)[np.asarray(c1)] == 0).all()
    # with eta tiny, the 6 unmarked coordinates win every draw
    c2 = np.asarray(sched.propose(marked, jax.random.key(1)))
    assert not set(c2.tolist()) & set(np.asarray(c1).tolist())


def test_engine_constructor_spec_outranks_app_default(mesh, rng):
    """StradsEngine(..., scheduler=spec) must actually govern plan-less
    and scheduler-less-plan runs (plan > constructor > app default)."""
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    rr_spec = SchedulerSpec(kind="random", block_size=4)
    eng = lasso.make_engine(cfg, mesh, scheduler=rr_spec)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    got = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1),
                      ExecutionPlan(executor="scan", rounds=8)).state

    want = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1),
                       ExecutionPlan(executor="scan", rounds=8,
                                     scheduler=rr_spec)).state
    _bit_identical(got, want)
    assert eng.scheduler_spec == rr_spec


def test_stale_aot_handle_rebinds_its_spec(mesh, rng):
    """A scanned_fn/ssp_fn handle fetched under spec A must run policy A
    even if set_scheduler switched to B before the handle first traced
    (lazy tracing must not bake B into A's cache slot)."""
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    spec_a = eng.app.default_scheduler_spec()          # dynamic_priority
    fn_a = eng.scanned_fn(4, donate=False)             # untraced handle
    eng.set_scheduler(SchedulerSpec(kind="random", block_size=4))
    carry_a = jnp.ones((20,), jnp.float32)             # A's init carry
    got = fn_a(eng.init_state(jax.random.key(0), y=y), data,
               jax.random.key(1), jnp.int32(0), carry_a)[0]
    assert eng.scheduler_spec == spec_a                # handle rebound A
    want = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1),
                       ExecutionPlan(executor="scan", rounds=4,
                                     scheduler=spec_a,
                                     donate=False)).state
    _bit_identical(got, want)


def test_ssp_carry_returned_and_resumable(mesh, rng):
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    full = eng.run_ssp(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1), 8, staleness=1)
    st, carry = eng.run_ssp(eng.init_state(jax.random.key(0), y=y), data,
                            jax.random.key(1), 4, staleness=1,
                            return_carry=True)
    assert carry.sched_carry is not None
    resumed = eng.run_ssp(st, data, carry.rng, 4, staleness=1,
                          t0=int(carry.t), clocks=carry.clocks,
                          sched_carry0=carry.sched_carry)
    _bit_identical(full, resumed)


def test_incompatible_app_policy_pairs_rejected_at_injection(mesh, rng):
    """A plan naming a kind the app cannot consume must fail at
    set_scheduler time with a readable error — never mid-trace."""
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    state = eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="scan", rounds=4,
                         scheduler=SchedulerSpec(kind="rotation"))
    with pytest.raises(ValueError, match="cannot consume a 'rotation'"):
        eng.execute(state, data, jax.random.key(1), plan)
    # U' larger than the schedulable-variable count is caught too
    with pytest.raises(ValueError, match="num_candidates"):
        eng.set_scheduler(_dyn_spec(num_candidates=64, block_size=4))


def test_resume_with_mismatched_scheduler_spec_rejected(mesh, rng):
    """A checkpointed carry only resumes under the policy that produced
    it: stateless-carry → stateful-plan (and the reverse) error upfront
    instead of crashing mid-trace or silently threading stale state."""
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    rr_spec = SchedulerSpec(kind="random", block_size=4)
    rr_carry = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                           jax.random.key(1),
                           ExecutionPlan(executor="scan", rounds=4,
                                         scheduler=rr_spec)).carry
    dyn_carry = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                            jax.random.key(1),
                            ExecutionPlan(executor="scan",
                                          rounds=4)).carry
    state = eng.init_state(jax.random.key(0), y=y)
    with pytest.raises(ValueError, match="sched_carry is None"):
        eng.execute(state, data, None,
                    ExecutionPlan(executor="scan", rounds=8),
                    carry=rr_carry)
    with pytest.raises(ValueError, match="stateless"):
        eng.execute(state, data, None,
                    ExecutionPlan(executor="scan", rounds=8,
                                  scheduler=rr_spec),
                    carry=dyn_carry)


# ---------------------------------------------------------------------------
# deprecation shims (the PR 3 pattern)
# ---------------------------------------------------------------------------

def test_old_import_paths_warn_but_work():
    import repro.core.schedulers as old_s
    import repro.core.block_scheduler as old_b
    with pytest.warns(DeprecationWarning, match="moved to repro.sched"):
        importlib.reload(old_s)
    with pytest.warns(DeprecationWarning, match="moved to repro.sched"):
        importlib.reload(old_b)
    from repro.sched.schedulers import DynamicPriorityScheduler
    from repro.sched.block import BlockScheduleConfig as NewCfg
    assert old_s.DynamicPriorityScheduler is DynamicPriorityScheduler
    assert old_b.BlockScheduleConfig is NewCfg


def test_core_package_import_does_not_warn():
    """Importing repro.core (or repro.sched) must NOT trip the shim
    warnings — only the legacy module paths do."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        importlib.import_module("repro.core")
        importlib.import_module("repro.sched")
