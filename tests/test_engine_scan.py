"""The pipelined multi-round executor (engine.run_scanned).

Contract under test (PR 1 acceptance):
  * ``run_scanned(..., pipeline_depth=0)`` is bit-identical to the host
    loop ``run`` on all three paper apps — same PRNG stream, same op
    order, one XLA program instead of R dispatches.
  * ``pipeline_depth=1`` (schedule prefetch, one-round-stale schedules —
    the paper's §pipelining) still monotonically decreases the Lasso
    objective on a correlated design.
  * phase-period handling: apps whose round structure cycles (MF's H/W
    alternation, LDA's U-round rotation) scan a full cycle per step, and
    a non-divisible round count falls back to the host loop for the tail.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import lasso, lda, mf
from repro.core import ExecutionPlan, single_device_mesh


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


def _bit_identical(a_state, b_state):
    for k in a_state:
        a, b = np.asarray(a_state[k]), np.asarray(b_state[k])
        assert (a == b).all(), (k, np.max(np.abs(a - b)))


# ---------------------------------------------------------------------------
# depth 0: bit-identical to the host loop
# ---------------------------------------------------------------------------

def test_lasso_scan_matches_host_loop(mesh, rng):
    X, y, _ = lasso.synthetic_correlated(rng, n=60, J=30, k_true=4)
    cfg = lasso.LassoConfig(num_features=30, lam=0.02, block_size=4,
                            num_candidates=12, rho=0.3)
    s_loop, _ = lasso.fit(cfg, X, y, mesh, num_rounds=20)
    s_scan, _ = lasso.fit(cfg, X, y, mesh,
                          plan=ExecutionPlan(executor="scan", rounds=20))
    _bit_identical(s_loop, s_scan)


def test_lasso_scan_trace_matches_host_trace(mesh, rng):
    X, y, _ = lasso.synthetic_correlated(rng, n=60, J=30, k_true=4)
    cfg = lasso.LassoConfig(num_features=30, lam=0.02, block_size=4,
                            num_candidates=12, rho=0.3)
    _, tr_loop = lasso.fit(cfg, X, y, mesh, num_rounds=10, trace_every=2)
    _, tr_scan = lasso.fit(cfg, X, y, mesh,
                           plan=ExecutionPlan(executor="scan", rounds=10,
                                              collect_every=2))
    assert [t for t, _ in tr_loop] == [t for t, _ in tr_scan]
    for (_, a), (_, b) in zip(tr_loop, tr_scan):
        assert a == pytest.approx(b, rel=1e-6)


def test_mf_scan_matches_host_loop_including_tail(mesh, rng):
    """9 rounds with phase_period=2: 4 scanned H/W cycles + 1 host-loop
    tail round must still match the pure host loop exactly."""
    A, mask = mf.synthetic_ratings(rng, 40, 30, true_rank=4, density=0.5)
    cfg = mf.MFConfig(num_rows=40, num_cols=30, rank=4, lam=0.05)
    s_loop, _ = mf.fit(cfg, A, mask, mesh, num_rounds=9)
    s_scan, _ = mf.fit(cfg, A, mask, mesh,
                       plan=ExecutionPlan(executor="scan", rounds=9))
    _bit_identical(s_loop, s_scan)


def test_lda_scan_matches_host_loop(mesh, rng):
    cfg = lda.LDAConfig(vocab=30, num_topics=4, num_workers=1,
                        tokens_per_worker=200, docs_per_worker=5)
    words, docs, z0 = lda.synthetic_corpus(rng, cfg, true_topics=4)
    s_loop, _, _ = lda.fit(cfg, words, docs, z0, mesh, num_rounds=6)
    s_scan, _, _ = lda.fit(cfg, words, docs, z0, mesh,
                           plan=ExecutionPlan(executor="scan", rounds=6))
    _bit_identical(s_loop, s_scan)


# ---------------------------------------------------------------------------
# depth 1: pipelined (one-round-stale schedules)
# ---------------------------------------------------------------------------

def test_pipelined_lasso_objective_monotone_on_correlated_design(mesh):
    """The STRADS stale-schedule guarantee: with the schedule computed one
    round behind (prefetched during the previous round's push/pull), the
    ρ-filtered dynamic schedule still descends every round on a strongly
    correlated design."""
    r = np.random.default_rng(3)
    X, y, _ = lasso.synthetic_correlated(r, n=120, J=80, corr=0.9,
                                         k_true=8)
    cfg = lasso.LassoConfig(num_features=80, lam=0.02, block_size=8,
                            num_candidates=32, rho=0.3, eta=1e-3)
    _, tr = lasso.fit(cfg, X, y, mesh,
                      plan=ExecutionPlan(executor="pipelined", rounds=40,
                                         collect_every=1))
    vals = [v for _, v in tr]
    assert len(vals) == 40
    for a, b in zip(vals, vals[1:]):
        assert b <= a + 1e-4                    # monotone descent
    assert vals[-1] < vals[0] * 0.7             # and real progress


def test_pipelined_lasso_matches_depth0_rng_stream(mesh, rng):
    """Depth 1 uses the same per-round schedule PRNG keys as depth 0 —
    only the state it reads is staler.  At round 0 there is no staleness
    yet, so the first-round schedules must coincide exactly."""
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    s0, _ = lasso.fit(cfg, X, y, mesh,
                      plan=ExecutionPlan(executor="scan", rounds=1))
    s1, _ = lasso.fit(cfg, X, y, mesh,
                      plan=ExecutionPlan(executor="pipelined", rounds=1))
    _bit_identical(s0, s1)


def test_pipelined_lda_conserves_counts(mesh, rng):
    """Count conservation is a per-round invariant of the Gibbs kernel and
    must survive pipelining (the schedule carries no counts)."""
    cfg = lda.LDAConfig(vocab=30, num_topics=4, num_workers=1,
                        tokens_per_worker=200, docs_per_worker=5)
    words, docs, z0 = lda.synthetic_corpus(rng, cfg, true_topics=4)
    state, tr, _ = lda.fit(cfg, words, docs, z0, mesh,
                           plan=ExecutionPlan(executor="pipelined",
                                              rounds=8, collect_every=4))
    n_tok = int((words >= 0).sum())
    assert float(jnp.sum(state["B"])) == n_tok
    assert float(jnp.sum(state["D"])) == n_tok
    assert bool(jnp.allclose(state["s"], jnp.sum(state["B"], axis=0)))
    assert tr[-1][1] > tr[0][1]                 # likelihood still climbs


def test_pipelined_mf_objective_decreases(mesh, rng):
    A, mask = mf.synthetic_ratings(rng, 40, 30, true_rank=4, density=0.5)
    cfg = mf.MFConfig(num_rows=40, num_cols=30, rank=4, lam=0.05)
    _, tr = mf.fit(cfg, A, mask, mesh,
                   plan=ExecutionPlan(executor="pipelined", rounds=20,
                                      collect_every=1))
    vals = [v for _, v in tr]
    assert vals[-1] < vals[0] * 0.6


# ---------------------------------------------------------------------------
# executor plumbing
# ---------------------------------------------------------------------------

def test_pipelined_rejects_non_divisible_rounds(mesh, rng):
    A, mask = mf.synthetic_ratings(rng, 20, 15, true_rank=3, density=0.5)
    cfg = mf.MFConfig(num_rows=20, num_cols=15, rank=3, lam=0.05)
    with pytest.raises(ValueError, match="divisible"):
        mf.fit(cfg, A, mask, mesh,
               plan=ExecutionPlan(executor="pipelined", rounds=7))


def test_run_scanned_without_collect_returns_state_only(mesh, rng):
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    state = eng.app.init_state(jax.random.key(0), y=y)
    out = eng.run_scanned(state, data, jax.random.key(0), 4)
    assert isinstance(out, dict) and set(out) == {"beta", "r"}


def test_run_scanned_collect_trace_has_one_entry_per_round(mesh, rng):
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    state = eng.app.init_state(jax.random.key(0), y=y)
    state, ys = eng.run_scanned(state, data, jax.random.key(0), 6,
                                collect=eng.app.objective_collect(),
                                donate=False)
    assert np.asarray(ys).shape == (6,)


def test_scanned_fn_is_aot_lowerable(mesh, rng):
    """launch/dryrun.py --engine relies on .lower().compile() of the
    scanned program; keep that path working."""
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    state = eng.app.init_state(jax.random.key(0), y=y)
    fn = eng.scanned_fn(4, pipeline_depth=1)
    compiled = fn.lower(state, data, jax.random.key(1), jnp.int32(0),
                        eng.init_sched_carry()).compile()
    assert compiled.cost_analysis() is not None
