"""Launch-layer tests: mesh builders, input specs, skip logic, roofline
HLO analyzer (validated against a hand-computable program), and a
small-mesh end-to-end sharded train step in a subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch import roofline as RL
from repro.launch.specs import skip_reason

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 540) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# skip logic / shape coverage
# ---------------------------------------------------------------------------

def test_skip_matrix():
    skips = {(a, s) for a in ARCHS for s in INPUT_SHAPES
             if skip_reason(a, s)}
    assert skips == {("hubert-xlarge", "decode_32k"),
                     ("hubert-xlarge", "long_500k")}


def test_input_shape_table():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


# ---------------------------------------------------------------------------
# roofline HLO analyzer
# ---------------------------------------------------------------------------

def test_hlo_analyzer_loop_and_collectives():
    """Loop-dependent matmul in a fori_loop on an 8-device mesh: the
    analyzer must charge flops × trip count and all-reduce wire bytes
    × trip count (XLA:CPU cost_analysis famously counts the body once)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.roofline import analyze_hlo
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        L, M, K, N = 7, 64, 128, 256
        def f(x, w):
            def body(i, acc):
                return acc + jnp.sum((x + i) @ w)
            return jax.lax.fori_loop(0, L, body, 0.0)
        xs = jax.ShapeDtypeStruct((M, K), jnp.float32)
        ws = jax.ShapeDtypeStruct((K, N), jnp.float32)
        lo = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P(None, None)),
            NamedSharding(mesh, P(None, "model")))).lower(xs, ws)
        ana = analyze_hlo(lo.compile().as_text(), 8)
        print(json.dumps({"flops": ana.flops,
                          "wire": ana.wire_bytes,
                          "count": ana.collective_count}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["flops"] == 2 * 64 * 128 * (256 // 4) * 7
    assert res["count"] == 7
    assert res["wire"] == pytest.approx(7 * 2 * 4 * 3 / 4)


def test_shape_bytes_and_groups():
    assert RL._shape_bytes("bf16[2,3,4]{2,1,0}") == 48
    assert RL._shape_bytes("(f32[10], s32[2])") == 48
    assert RL._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}, x", 99) == 4
    assert RL._group_size("replica_groups=[32,16]<=[512]", 99) == 16
    assert RL._group_size("no groups here", 7) == 7


def test_parse_instr_handles_tuple_comments():
    ln = ("  %while.34 = (s32[], bf16[65,2,512,1,64]{4,3,2,1,0}, "
          "/*index=5*/ f32[2,2064,2,64]{3,2,1,0}) while(%tuple.1), "
          "condition=%c, body=%b")
    name, typestr, op = RL._parse_instr(ln)
    assert name == "while.34" and op == "while"
    assert RL._shape_bytes(typestr) > 0


def test_model_flops_kinds():
    from repro.configs import get_config
    cfg = get_config("granite-3-2b")
    tr = RL.model_flops(cfg, INPUT_SHAPES["train_4k"])
    pf = RL.model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    dc = RL.model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr == pytest.approx(3 * pf, rel=1e-6)  # 6ND vs 2ND, same tokens
    assert dc < pf / 1000                         # one token per sequence
    # MoE: active ≈ 6.6B of 42B total (nameplate)
    from repro.models.model import num_params
    moe = get_config("phi3.5-moe-42b-a6.6b")
    assert 30e9 < num_params(moe) < 60e9
    assert RL.active_params(moe) < 12e9


# ---------------------------------------------------------------------------
# the serving CLI split: launch/serve.py (STRADS bounded-staleness
# serving) vs launch/serve_lm.py (model-zoo LM decode) parse disjoint
# flag sets — examples/serve_decode.py broke once when serve grew the
# STRADS flags, so pin each CLI to its own surface
# ---------------------------------------------------------------------------

def test_serve_cli_flag_sets_are_disjoint():
    from repro.launch import serve, serve_lm
    # the STRADS serving CLI knows nothing about LM decode flags...
    with pytest.raises(SystemExit):
        serve.main(["--engine", "lasso", "--arch", "granite-3-2b"])
    # ...and the LM decode CLI knows nothing about STRADS flags
    with pytest.raises(SystemExit):
        serve_lm.main(["--arch", "granite-3-2b", "--engine", "lasso"])


def test_serve_cli_stream_flags_require_stream():
    from repro.launch import serve
    with pytest.raises(SystemExit, match="--stream"):
        serve.main(["--engine", "lasso", "--ingest-every", "2"])
    with pytest.raises(SystemExit, match="--stream"):
        serve.main(["--engine", "lasso", "--stream-kind", "extend"])


# ---------------------------------------------------------------------------
# sharded end-to-end step on a small forced mesh
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The pjit'd train step on a 4×2 mesh must agree numerically with the
    1-device run (same params, same batch) — SPMD must be semantics-free."""
    out = run_sub("""
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.models import model as M
        from repro.sharding.rules import activation_mesh
        from repro.train import TrainConfig, make_train_step
        from repro.train.step import init_train_state
        from repro.data import SyntheticLMConfig, make_batch

        cfg = get_config("granite-3-2b").reduced()
        tc = TrainConfig()
        state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
        dc = SyntheticLMConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               batch_size=8)
        batch = make_batch(dc, 0)

        # single-logical-device result
        s1, m1 = jax.jit(make_train_step(cfg, tc))(
            jax.tree.map(lambda x: x, state), batch)

        # sharded result
        mesh = make_test_mesh()
        assert mesh.size == 8, mesh
        pspecs = M.param_specs(cfg, mesh)
        put = lambda t, s: jax.device_put(t, s)
        state2 = {
            "params": jax.tree.map(put, state["params"], pspecs),
            "opt": {"m": jax.tree.map(put, state["opt"]["m"], pspecs),
                    "v": jax.tree.map(put, state["opt"]["v"], pspecs),
                    "count": state["opt"]["count"]},
            "step": state["step"],
        }
        with activation_mesh(mesh):
            s2, m2 = jax.jit(make_train_step(cfg, tc))(state2, batch)
        print(json.dumps({"l1": float(m1["loss"]), "l2": float(m2["loss"]),
                          "g1": float(m1["grad_norm"]),
                          "g2": float(m2["grad_norm"])}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["l1"] == pytest.approx(res["l2"], rel=2e-3)
    assert res["g1"] == pytest.approx(res["g2"], rel=2e-2)
