"""The unified observability subsystem (repro/obs).

Contract under test (ISSUE 7 acceptance):
  * telemetry is **bit-neutral**: every executor × every paper app
    produces the exact same final state with telemetry off, with device
    counters, and with the full trace recorder — instrumentation rides
    outside the primitives and can never change what a round computes.
  * the device-counter identities hold for arbitrary runs (hypothesis
    property): per-phase round totals sum to the run's rounds and the
    ρ-filter ledger balances (``accepted + killed == proposed``).
  * all four executors return a populated
    :class:`~repro.obs.report.RunReport` in
    ``ExecutionReport.telemetry`` carrying the resolved spec.
  * the Chrome-trace export is valid JSON whose spans are strictly
    nested with non-negative durations (``validate_spans``).
  * counters are bit-exact through ``checkpoint_every`` chunking and
    through a checkpoint/restore resume (``EngineCarry.obs`` rides the
    npz payload like every other carry leaf).
  * the plan shim: ``telemetry=True`` still parses (DeprecationWarning →
    ``TelemetrySpec(kind="counters")``), non-SSP executors no longer
    reject it, and plans round-trip through JSON with specs intact.
"""
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.apps import lasso, lda, mf
from repro.checkpoint import restore_checkpoint
from repro.core import ExecutionPlan, single_device_mesh
from repro.obs import (Recorder, RunReport, TelemetrySpec, chrome_trace,
                       report_from_json, validate_spans)
from repro.launch.trace import check_report, extract_report_dicts


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


def _bit_identical(a_state, b_state):
    assert set(a_state) == set(b_state)
    for k in a_state:
        a, b = np.asarray(a_state[k]), np.asarray(b_state[k])
        assert (a == b).all(), (k, np.max(np.abs(a - b)))


def _lasso_engine(rng, mesh, n=40, J=20):
    X, y, _ = lasso.synthetic_correlated(rng, n=n, J=J, k_true=3)
    cfg = lasso.LassoConfig(num_features=J, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    return eng, data, y


def _plan(executor, rounds, telemetry):
    kw = {"staleness": 1} if executor == "ssp" else {}
    return ExecutionPlan(executor=executor, rounds=rounds,
                         telemetry=telemetry, **kw)


# ---------------------------------------------------------------------------
# bit-neutrality: telemetry on ≡ off, every executor × every paper app
# ---------------------------------------------------------------------------

EXECUTORS = ("loop", "scan", "pipelined", "ssp")
SPECS = (False, TelemetrySpec(kind="counters"), TelemetrySpec(kind="trace"))


def _run_all_specs(eng, state, data, executor, rounds):
    """Final states for off / counters / trace runs of the same plan
    (fresh state copy per run — executors donate buffers)."""
    return [eng.execute(jax.tree.map(jnp.copy, state), data,
                        jax.random.key(1),
                        _plan(executor, rounds, t)).state
            for t in SPECS]


@pytest.mark.parametrize("executor", EXECUTORS)
def test_lasso_telemetry_is_bit_neutral(executor, mesh, rng):
    eng, data, y = _lasso_engine(rng, mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    states = _run_all_specs(eng, state, data, executor, 8)
    _bit_identical(states[0], states[1])
    _bit_identical(states[0], states[2])


@pytest.mark.parametrize("executor", EXECUTORS)
def test_lda_telemetry_is_bit_neutral(executor, mesh, rng):
    cfg = lda.LDAConfig(vocab=30, num_topics=4, num_workers=1,
                        tokens_per_worker=200, docs_per_worker=5)
    words, docs, z0 = lda.synthetic_corpus(rng, cfg, true_topics=4)
    eng = lda.make_engine(cfg, mesh)
    data = eng.shard_data({"words": jnp.asarray(words),
                           "docs": jnp.asarray(docs)})
    state = eng.init_state(jax.random.key(0), words=words, docs=docs,
                           z0=z0)
    states = _run_all_specs(eng, state, data, executor, 6)
    _bit_identical(states[0], states[1])
    _bit_identical(states[0], states[2])


@pytest.mark.parametrize("executor", EXECUTORS)
def test_mf_telemetry_is_bit_neutral(executor, mesh, rng):
    A, mask = mf.synthetic_ratings(rng, 40, 30, true_rank=4, density=0.5)
    cfg = mf.MFConfig(num_rows=40, num_cols=30, rank=4, lam=0.05)
    eng = mf.make_engine(cfg, mesh)
    data = eng.shard_data({"A": jnp.asarray(A),
                           "mask": jnp.asarray(mask)})
    state = eng.init_state(jax.random.key(0), A=jnp.asarray(A),
                           mask=jnp.asarray(mask))
    states = _run_all_specs(eng, state, data, executor, 8)
    _bit_identical(states[0], states[1])
    _bit_identical(states[0], states[2])


# ---------------------------------------------------------------------------
# every executor returns a populated RunReport with the resolved spec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTORS)
def test_every_executor_returns_runreport(executor, mesh, rng):
    eng, data, y = _lasso_engine(rng, mesh)
    spec = TelemetrySpec(kind="trace")
    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1), _plan(executor, 8, spec))
    report = rep.telemetry
    assert isinstance(report, RunReport)
    assert report.spec == spec
    assert report.executor == executor
    assert report.rounds == 8
    c = report.counters
    assert c["rounds"] == 8
    assert sum(c["rounds_per_phase"]) == 8
    assert c["accepted"] + c["killed"] == c["proposed"]
    assert c["sched_size"] > 0
    # every trace run records at least the execute > executor span pair
    names = [e["name"] for e in report.events]
    assert "execute" in names
    assert validate_spans(report.events) is None
    # the SSP staleness section appears exactly for the ssp executor
    assert (report.ssp is not None) == (executor == "ssp")
    # check_report (the trace CLI's offline validator) agrees, both on
    # the live report and after a JSON round-trip
    assert check_report(report) is None
    assert check_report(report_from_json(report.to_json())) is None


def test_no_spec_means_no_report(mesh, rng):
    eng, data, y = _lasso_engine(rng, mesh)
    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1), _plan("scan", 4, False))
    assert rep.telemetry is None


def test_counters_kind_records_no_events(mesh, rng):
    eng, data, y = _lasso_engine(rng, mesh)
    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1),
                      _plan("scan", 4, TelemetrySpec(kind="counters")))
    assert rep.telemetry.events == []
    assert rep.telemetry.counters["rounds"] == 4


# ---------------------------------------------------------------------------
# the counter identities, as a property over run shapes (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=4),
       st.sampled_from(["loop", "scan", "ssp"]),
       st.sampled_from(["strads", "rr", "cyclic"]))
def test_counter_identities_hold(steps, executor, scheduler):
    """Σ per-phase rounds == rounds and accepted + killed == proposed,
    for random (length, executor, scheduler-policy) configurations."""
    mesh = single_device_mesh()
    r = np.random.default_rng(steps * 13 + len(executor))
    X, y, _ = lasso.synthetic_correlated(r, n=24, J=12, k_true=3)
    cfg = lasso.LassoConfig(num_features=12, lam=0.02, block_size=3,
                            num_candidates=6, rho=0.5,
                            scheduler=scheduler)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    R = 2 * steps
    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1),
                      _plan(executor, R, TelemetrySpec(kind="counters")))
    c = rep.telemetry.counters
    assert c["rounds"] == R
    assert sum(c["rounds_per_phase"]) == R
    assert all(v >= 0 for v in c["rounds_per_phase"])
    assert c["accepted"] + c["killed"] == c["proposed"]
    assert 0 <= c["accepted"] <= c["proposed"]
    assert c["sched_size"] == c["accepted"]
    if scheduler == "strads":
        # the dynamic-priority policy ρ-filters num_candidates per round
        assert c["proposed"] == R * cfg.num_candidates
    else:
        # rr/cyclic schedule fixed blocks: nothing proposed gets killed
        assert c["killed"] == 0 and c["proposed"] == c["accepted"]


# ---------------------------------------------------------------------------
# the Chrome-trace export: valid JSON, strictly nested spans
# ---------------------------------------------------------------------------

def test_chrome_trace_export_is_valid_and_nested(tmp_path, mesh, rng):
    eng, data, y = _lasso_engine(rng, mesh)
    plan = ExecutionPlan(executor="ssp", rounds=8, staleness=1,
                         checkpoint_every=4,
                         telemetry=TelemetrySpec(kind="trace"))
    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1), plan,
                      ckpt_dir=str(tmp_path / "ck"))
    events = rep.telemetry.events
    # chunking makes a real hierarchy: execute > {ssp × 2, checkpoint × 2}
    names = [e["name"] for e in events if e.get("ph") == "X"]
    assert names.count("ssp") == 2
    assert names.count("checkpoint") == 2
    assert validate_spans(events) is None

    out = rep.telemetry.write_chrome_trace(str(tmp_path / "t.json"))
    with open(out) as f:
        doc = json.load(f)                      # must parse
    assert doc["displayTimeUnit"] == "ms"
    tev = doc["traceEvents"]
    assert len(tev) == len(events)
    spans = [e for e in tev if e["ph"] == "X"]
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in spans)
    # strict nesting: any two overlapping spans contain one another
    for a in spans:
        for b in spans:
            if a is b:
                continue
            a0, a1 = a["ts"], a["ts"] + a["dur"]
            b0, b1 = b["ts"], b["ts"] + b["dur"]
            overlap = max(a0, b0) < min(a1, b1)
            nested = (a0 <= b0 and b1 <= a1) or (b0 <= a0 and a1 <= b1)
            assert not overlap or nested, (a["name"], b["name"])


def test_validate_spans_flags_violations():
    ok = [{"name": "a", "ph": "X", "ts": 0.0, "dur": 10.0, "args": {}},
          {"name": "b", "ph": "X", "ts": 2.0, "dur": 3.0, "args": {}}]
    assert validate_spans(ok) is None
    crossing = ok + [{"name": "c", "ph": "X", "ts": 4.0, "dur": 10.0,
                      "args": {}}]
    assert validate_spans(crossing) is not None
    negative = [{"name": "a", "ph": "X", "ts": 0.0, "dur": -1.0,
                 "args": {}}]
    assert validate_spans(negative) is not None


def test_recorder_span_stack_discipline():
    rec = Recorder()
    with rec.span("outer", k=1):
        rec.instant("tick")
        with rec.span("inner"):
            pass
    ev = rec.to_json_events()
    assert [e["name"] for e in ev] == ["outer", "tick", "inner"]
    assert validate_spans(ev) is None
    doc = chrome_trace(ev)
    assert {e["name"] for e in doc["traceEvents"]} == \
        {"outer", "tick", "inner"}


# ---------------------------------------------------------------------------
# counters survive chunking and checkpoint/resume bit-exactly
# ---------------------------------------------------------------------------

def test_counters_bit_exact_through_chunking_and_resume(tmp_path, mesh,
                                                        rng):
    eng, data, y = _lasso_engine(rng, mesh)
    spec = TelemetrySpec(kind="counters")

    full = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1), _plan("scan", 8, spec))

    plan = ExecutionPlan(executor="scan", rounds=8, telemetry=spec,
                         checkpoint_every=4)
    chunked = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                          jax.random.key(1), plan,
                          ckpt_dir=str(tmp_path))
    _bit_identical(full.state, chunked.state)
    assert chunked.telemetry.counters == full.telemetry.counters

    # EngineCarry.obs rides the npz payload: restore the mid checkpoint
    # and resume — the final counters must match the uninterrupted run
    template = {"state": jax.tree.map(jnp.copy, chunked.state),
                "carry": chunked.carry}
    restored = restore_checkpoint(str(tmp_path), 4, template)
    mid = restored["carry"]
    assert mid.obs is not None
    assert int(np.asarray(mid.obs["rounds"]).sum()) == 4
    resumed = eng.execute(restored["state"], data, jax.random.key(99),
                          plan, carry=mid,
                          ckpt_dir=str(tmp_path / "resumed"))
    _bit_identical(full.state, resumed.state)
    assert resumed.telemetry.counters == full.telemetry.counters


def test_ssp_counters_bit_exact_through_chunking(tmp_path, mesh, rng):
    eng, data, y = _lasso_engine(rng, mesh)
    spec = TelemetrySpec(kind="counters")
    full = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1), _plan("ssp", 8, spec))
    plan = ExecutionPlan(executor="ssp", rounds=8, staleness=1,
                         telemetry=spec, checkpoint_every=4)
    chunked = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                          jax.random.key(1), plan,
                          ckpt_dir=str(tmp_path))
    _bit_identical(full.state, chunked.state)
    assert chunked.telemetry.counters == full.telemetry.counters
    # the per-chunk SSP staleness summaries merge into one section
    assert chunked.telemetry.ssp is not None
    assert (np.asarray(chunked.telemetry.ssp.hist)
            == np.asarray(full.telemetry.ssp.hist)).all()
    assert chunked.telemetry.ssp.flushes == full.telemetry.ssp.flushes


# ---------------------------------------------------------------------------
# the plan surface: spec field, bool shim, JSON round-trip
# ---------------------------------------------------------------------------

def test_plan_bool_true_shims_to_counters_spec_with_warning():
    with pytest.warns(DeprecationWarning, match="TelemetrySpec"):
        plan = ExecutionPlan(executor="ssp", rounds=4, staleness=1,
                             telemetry=True)
    assert plan.telemetry == TelemetrySpec(kind="counters")


def test_plan_bool_false_stays_falsy():
    plan = ExecutionPlan(executor="scan", rounds=4, telemetry=False)
    assert plan.telemetry is False
    assert (plan.telemetry or None) is None


def test_plan_rejects_non_spec_telemetry():
    with pytest.raises(ValueError, match="telemetry"):
        ExecutionPlan(executor="scan", rounds=4, telemetry="counters")


def test_plan_json_roundtrips_spec():
    plan = ExecutionPlan(executor="ssp", rounds=8, staleness=1,
                         telemetry=TelemetrySpec(kind="trace",
                                                 profiler=True))
    back = ExecutionPlan.from_json(json.loads(json.dumps(plan.to_json())))
    assert back == plan
    assert back.telemetry == TelemetrySpec(kind="trace", profiler=True)
    # and the legacy serialized-bool shape still parses
    off = ExecutionPlan.from_json(
        ExecutionPlan(executor="scan", rounds=4).to_json())
    assert off.telemetry is False


def test_non_ssp_executor_accepts_telemetry(mesh, rng):
    """PR-2 behavior (`telemetry=True` + scan raises) is gone: every
    executor takes a spec now."""
    eng, data, y = _lasso_engine(rng, mesh)
    with pytest.warns(DeprecationWarning):
        plan = ExecutionPlan(executor="scan", rounds=4, telemetry=True)
    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1), plan)
    assert rep.telemetry.counters["rounds"] == 4


# ---------------------------------------------------------------------------
# TelemetrySpec validation + serialization
# ---------------------------------------------------------------------------

def test_spec_rejects_unknown_kind():
    with pytest.raises(ValueError, match="kind"):
        TelemetrySpec(kind="metrics")


def test_spec_rejects_profiler_for_counters():
    with pytest.raises(ValueError, match="profiler"):
        TelemetrySpec(kind="counters", profiler=True)


def test_spec_json_roundtrip_and_unknown_keys():
    s = TelemetrySpec(kind="trace", profiler=True)
    assert TelemetrySpec.from_json(s.to_json()) == s
    assert TelemetrySpec.from_json(json.dumps(s.to_json())) == s
    with pytest.raises(ValueError, match="unknown"):
        TelemetrySpec.from_json({"kind": "trace", "verbosity": 3})
    assert TelemetrySpec.default_for("counters") == \
        TelemetrySpec(kind="counters")
    assert not TelemetrySpec(kind="counters").events
    assert TelemetrySpec(kind="trace").events


# ---------------------------------------------------------------------------
# the trace CLI's offline validator
# ---------------------------------------------------------------------------

def _valid_report_dict():
    return {"spec": {"kind": "counters", "profiler": False},
            "executor": "scan", "rounds": 4,
            "counters": {"rounds": 4, "rounds_per_phase": [4],
                         "sched_size": 12, "proposed": 24,
                         "accepted": 12, "killed": 12},
            "events": [], "ssp": None}


def test_check_report_catches_broken_identities():
    assert check_report(report_from_json(_valid_report_dict())) is None

    unbalanced = _valid_report_dict()
    unbalanced["counters"]["killed"] = 13
    assert "ledger" in check_report(report_from_json(unbalanced))

    phases = _valid_report_dict()
    phases["counters"]["rounds_per_phase"] = [3]
    assert "phase" in check_report(report_from_json(phases))

    negative = _valid_report_dict()
    negative["counters"]["sched_size"] = -1
    assert "negative" in check_report(report_from_json(negative))

    crossing = _valid_report_dict()
    crossing["spec"] = {"kind": "trace", "profiler": False}
    crossing["events"] = [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 10.0, "args": {}},
        {"name": "b", "ph": "X", "ts": 5.0, "dur": 10.0, "args": {}}]
    assert check_report(report_from_json(crossing)) is not None


def test_extract_report_dicts_walks_nested_artifacts():
    rep = _valid_report_dict()
    artifact = {"engine": "lasso", "run_report": rep,
                "ssp": {"2": {"telemetry": rep}},
                "rows": [{"telemetry": rep}]}
    found = extract_report_dicts(artifact)
    assert len(found) == 3
    assert extract_report_dicts({"no": "reports"}) == []
    # a bare to_json() dump is itself the report
    assert extract_report_dicts(rep) == [rep]
