"""System-level invariants, property-tested across families.

* Causality: perturbing future tokens must not change past logits — for
  every decoder family (attention masks, Mamba scans, xLSTM recurrences,
  MoE routing are all causal paths).
* STRADS block masking: unscheduled blocks must not move under the
  block-coordinate trainer.
* RoPE decode consistency: rotating at absolute positions makes logits
  depend only on relative offsets within a window.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model as M

CAUSAL_ARCHS = [a for a in ARCHS if not get_config(a).encoder_only]


@pytest.mark.parametrize("arch", CAUSAL_ARCHS)
def test_causality(arch):
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        # token drops couple tokens within a dispatch group via capacity
        # ranking; causality holds in the no-drop regime
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    prm = M.init_params(cfg, key)
    B, S, cut = 2, 20, 11
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32) * 0.02
    l1, _ = M.forward(cfg, prm, batch)
    toks2 = toks.at[:, cut:].set((toks[:, cut:] + 7) % cfg.vocab_size)
    l2, _ = M.forward(cfg, prm, dict(batch, tokens=toks2))
    np.testing.assert_allclose(
        np.asarray(l1[:, :cut], np.float32),
        np.asarray(l2[:, :cut], np.float32), rtol=0, atol=1e-3,
        err_msg=f"{arch}: future tokens leaked into past logits")
    # and the perturbation is actually visible at/after the cut
    assert float(jnp.max(jnp.abs(
        l1[:, cut:].astype(jnp.float32)
        - l2[:, cut:].astype(jnp.float32)))) > 1e-4


def test_hubert_is_bidirectional():
    cfg = get_config("hubert-xlarge").reduced()
    key = jax.random.PRNGKey(0)
    prm = M.init_params(cfg, key)
    frames = jax.random.normal(key, (1, 16, cfg.d_model), jnp.float32)
    l1, _ = M.encode_step(cfg, prm, {"frames": frames})
    frames2 = frames.at[:, -1].set(-frames[:, -1] * 5.0)
    l2, _ = M.encode_step(cfg, prm, {"frames": frames2})
    # encoder attention is non-causal: early positions see the change
    assert float(jnp.max(jnp.abs(
        l1[:, 0].astype(jnp.float32)
        - l2[:, 0].astype(jnp.float32)))) > 1e-6


def test_strads_unscheduled_blocks_do_not_move():
    from repro.sched.block import BlockScheduleConfig
    from repro.data import SyntheticLMConfig, make_batch
    from repro.train import TrainConfig
    from repro.train.step import init_strads_state, make_strads_train_step

    cfg = get_config("granite-3-2b").reduced()
    tc = TrainConfig(adamw=dataclasses.replace(tc_default(), weight_decay=0.0))
    sched = BlockScheduleConfig(num_blocks=3, blocks_per_step=1,
                                candidates_per_step=2, min_distance=1)
    state = init_strads_state(cfg, tc, sched, jax.random.PRNGKey(0))
    before = jax.tree_util.tree_map(lambda x: x, state["params"])
    step = jax.jit(make_strads_train_step(cfg, tc, sched))
    dc = SyntheticLMConfig(vocab_size=cfg.vocab_size, seq_len=32,
                           batch_size=4)
    state, metrics = step(state, make_batch(dc, 0))
    assert float(metrics["blocks_active"]) <= sched.blocks_per_step
    # per-layer stacked leaves: layers whose mask was 0 must be unchanged
    moved = []
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(before["params"]
                                                 if "params" in before
                                                 else before)[0],
            jax.tree_util.tree_flatten_with_path(state["params"])[0]):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if name.startswith("layers/") and a.ndim >= 1 \
                and a.shape[0] == 2:                    # stacked 2 layers
            per_layer = np.asarray(jnp.sum(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)),
                axis=tuple(range(1, a.ndim))))
            moved.append(per_layer > 0)
    moved = np.stack(moved)                              # (leaves, 2)
    layer_moved = moved.any(axis=0)
    # exactly the scheduled layer block(s) moved — at most 1 of 2 here
    assert layer_moved.sum() <= 1, layer_moved


def tc_default():
    from repro.optim import AdamWConfig
    return AdamWConfig()


def test_window_limits_receptive_field():
    """With window W, logits at position t are invariant to tokens more
    than W positions back."""
    cfg = dataclasses.replace(get_config("granite-3-2b").reduced())
    key = jax.random.PRNGKey(2)
    prm = M.init_params(cfg, key)
    B, S, W = 1, 24, 4
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    l1, _ = M.forward(cfg, prm, {"tokens": toks}, window=W)
    toks2 = toks.at[:, 0:2].set((toks[:, 0:2] + 3) % cfg.vocab_size)
    l2, _ = M.forward(cfg, prm, {"tokens": toks2}, window=W)
    np.testing.assert_allclose(
        np.asarray(l1[:, 10:], np.float32),
        np.asarray(l2[:, 10:], np.float32), rtol=0, atol=1e-3)
