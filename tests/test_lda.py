"""STRADS LDA: count conservation, likelihood ascent, s-error bounds,
single-worker exactness."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import lda
from repro.core import single_device_mesh


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


@pytest.fixture(scope="module")
def setup():
    r = np.random.default_rng(0)
    cfg = lda.LDAConfig(vocab=50, num_topics=6, num_workers=1,
                        tokens_per_worker=1200, docs_per_worker=15)
    words, docs, z0 = lda.synthetic_corpus(r, cfg, true_topics=6)
    return cfg, words, docs, z0


def test_likelihood_increases(mesh, setup):
    cfg, words, docs, z0 = setup
    _, trace, _ = lda.fit(cfg, words, docs, z0, mesh, num_rounds=16,
                          trace_every=4)
    assert trace[-1][1] > trace[0][1] + 100    # clear ascent


def test_count_conservation(mesh, setup):
    """Token counts are conserved by every Gibbs round: ΣB = ΣD = #tokens
    and s = colsums(B)."""
    cfg, words, docs, z0 = setup
    state, _, _ = lda.fit(cfg, words, docs, z0, mesh, num_rounds=8)
    n_tok = int((words >= 0).sum())
    assert float(jnp.sum(state["B"])) == n_tok
    assert float(jnp.sum(state["D"])) == n_tok
    assert bool(jnp.allclose(state["s"], jnp.sum(state["B"], axis=0)))
    assert bool(jnp.all(state["B"] >= 0)) and bool(jnp.all(state["D"] >= 0))


def test_single_worker_zero_s_error(mesh, setup):
    """With one worker there is no staleness: Δ_t must be exactly 0 —
    the sampler is the exact sequential collapsed Gibbs sampler."""
    cfg, words, docs, z0 = setup
    _, _, serrs = lda.fit(cfg, words, docs, z0, mesh, num_rounds=6,
                          trace_every=1)
    assert all(v == 0.0 for _, v in serrs)


def test_assignments_in_range(mesh, setup):
    cfg, words, docs, z0 = setup
    state, _, _ = lda.fit(cfg, words, docs, z0, mesh, num_rounds=4)
    z = np.asarray(state["z"])
    assert ((0 <= z) & (z < cfg.num_topics)).all()


def test_baseline_runs_and_improves(mesh, setup):
    cfg, words, docs, z0 = setup
    _, trace, _ = lda.fit(cfg, words, docs, z0, mesh, num_rounds=8,
                          baseline=True, trace_every=2)
    assert trace[-1][1] > trace[0][1]


def test_block_partition_covers_vocab():
    cfg = lda.LDAConfig(vocab=53, num_topics=4, num_workers=4,
                        tokens_per_worker=10, docs_per_worker=2)
    # padded vocab divisible into equal blocks covering every real word
    assert cfg.padded_vocab >= cfg.vocab
    assert cfg.padded_vocab == cfg.block_vocab * cfg.num_workers
    blocks = np.arange(cfg.vocab) // cfg.block_vocab
    assert blocks.max() < cfg.num_workers
