"""The bounded-staleness parameter-server subsystem (repro/ps).

Contract under test (ISSUE 2 acceptance):
  * ``run_ssp(staleness=0)`` is bit-identical to
    ``run_scanned(pipeline_depth=0)`` on all three paper apps — the
    correctness anchor for the whole subsystem.
  * the staleness invariant: no read is ever served more than ``s``
    clocks stale, asserted over the *device-observed* telemetry for
    random schedules (hypothesis property; deterministic stub fallback).
  * ``s >= 1`` still converges (Lasso objective, LDA count conservation)
    — the SSP trade-off is error, never corruption.
  * KV-store wiring: placement + byte accounting flow from
    ``StradsEngine.place_state`` / ``core.kvstore``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.apps import lasso, lda, mf
from repro.core import ExecutionPlan, single_device_mesh
from repro.ps import ParameterServer, StaleCache, init_clocks


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


def _bit_identical(a_state, b_state):
    assert set(a_state) == set(b_state)
    for k in a_state:
        a, b = np.asarray(a_state[k]), np.asarray(b_state[k])
        assert (a == b).all(), (k, np.max(np.abs(a - b)))


def _lasso_problem(rng, n=60, J=30):
    X, y, _ = lasso.synthetic_correlated(rng, n=n, J=J, k_true=4)
    cfg = lasso.LassoConfig(num_features=J, lam=0.02, block_size=4,
                            num_candidates=12, rho=0.3)
    return cfg, X, y


# ---------------------------------------------------------------------------
# staleness 0: bit-identical to the BSP scan (hence to the host loop)
# ---------------------------------------------------------------------------

def test_lasso_ssp0_bit_identical_to_scan(mesh, rng):
    cfg, X, y = _lasso_problem(rng)
    s_scan, _ = lasso.fit(cfg, X, y, mesh,
                          plan=ExecutionPlan(executor="scan", rounds=20))
    s_ssp, _ = lasso.fit(cfg, X, y, mesh,
                         plan=ExecutionPlan(executor="ssp", rounds=20,
                                            staleness=0))
    _bit_identical(s_scan, s_ssp)


def test_lasso_ssp0_trace_matches_scan_trace(mesh, rng):
    cfg, X, y = _lasso_problem(rng)
    _, tr_scan = lasso.fit(cfg, X, y, mesh,
                           plan=ExecutionPlan(executor="scan", rounds=10,
                                              collect_every=2))
    _, tr_ssp = lasso.fit(cfg, X, y, mesh,
                          plan=ExecutionPlan(executor="ssp", rounds=10,
                                             staleness=0, collect_every=2))
    assert tr_scan == tr_ssp


def test_lda_ssp0_bit_identical_to_scan(mesh, rng):
    cfg = lda.LDAConfig(vocab=30, num_topics=4, num_workers=1,
                        tokens_per_worker=200, docs_per_worker=5)
    words, docs, z0 = lda.synthetic_corpus(rng, cfg, true_topics=4)
    s_scan, _, _ = lda.fit(cfg, words, docs, z0, mesh,
                           plan=ExecutionPlan(executor="scan", rounds=6))
    s_ssp, _, _ = lda.fit(cfg, words, docs, z0, mesh,
                          plan=ExecutionPlan(executor="ssp", rounds=6,
                                             staleness=0))
    _bit_identical(s_scan, s_ssp)


def test_mf_ssp0_bit_identical_to_scan(mesh, rng):
    A, mask = mf.synthetic_ratings(rng, 40, 30, true_rank=4, density=0.5)
    cfg = mf.MFConfig(num_rows=40, num_cols=30, rank=4, lam=0.05)
    s_scan, _ = mf.fit(cfg, A, mask, mesh,
                       plan=ExecutionPlan(executor="scan", rounds=8))
    s_ssp, _ = mf.fit(cfg, A, mask, mesh,
                      plan=ExecutionPlan(executor="ssp", rounds=8,
                                         staleness=0))
    _bit_identical(s_scan, s_ssp)


def test_mf_ssp1_window_equals_full_cycle_is_exact(mesh, rng):
    """At s=1 the MF window is exactly one H/W cycle: the H push reads a
    fresh snapshot and the W commit recomputes from flush-time state, so
    SSP introduces *zero* staleness error — bit-identical to BSP."""
    A, mask = mf.synthetic_ratings(rng, 40, 30, true_rank=4, density=0.5)
    cfg = mf.MFConfig(num_rows=40, num_cols=30, rank=4, lam=0.05)
    s_scan, _ = mf.fit(cfg, A, mask, mesh,
                       plan=ExecutionPlan(executor="scan", rounds=8))
    s_ssp, _ = mf.fit(cfg, A, mask, mesh,
                      plan=ExecutionPlan(executor="ssp", rounds=8,
                                         staleness=1))
    _bit_identical(s_scan, s_ssp)


# ---------------------------------------------------------------------------
# the staleness invariant (property over random schedules)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=4),
       st.integers(min_value=1, max_value=3),
       st.sampled_from(["strads", "rr", "cyclic"]))
def test_read_staleness_never_exceeds_bound(staleness, steps, scheduler):
    """max observed read-staleness ≤ s, asserted over the device-side
    telemetry the compiled program actually recorded — for random
    (staleness, length, scheduler) configurations."""
    mesh = single_device_mesh()
    r = np.random.default_rng(staleness * 7 + steps)
    X, y, _ = lasso.synthetic_correlated(r, n=24, J=12, k_true=3)
    cfg = lasso.LassoConfig(num_features=12, lam=0.02, block_size=3,
                            num_candidates=6, rho=0.5,
                            scheduler=scheduler)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    state = eng.init_state(jax.random.key(0), y=y)
    R = (staleness + 1) * steps
    # invoked through the unified plan surface (ISSUE 3 acceptance);
    # .telemetry is a uniform RunReport now, with the staleness story
    # in its .ssp section (ISSUE 7)
    from repro.obs import RunReport, TelemetrySpec
    plan = ExecutionPlan(executor="ssp", rounds=R, staleness=staleness,
                         telemetry=TelemetrySpec(kind="counters"))
    report = eng.execute(state, data, jax.random.key(1), plan).telemetry
    assert isinstance(report, RunReport)
    assert report.counters["rounds"] == R
    telem = report.ssp
    assert telem.max_staleness <= staleness
    assert telem.hist.sum() == R == telem.rounds
    # each window serves exactly one read at every staleness 0..s
    assert (telem.hist == steps).all()
    assert telem.flushes == steps
    assert (telem.clocks == R).all()


def test_ssp_rejects_non_divisible_rounds(mesh, rng):
    cfg, X, y = _lasso_problem(rng)
    with pytest.raises(ValueError, match="multiple"):
        lasso.fit(cfg, X, y, mesh,
                  plan=ExecutionPlan(executor="ssp", rounds=5,
                                     staleness=1))


# ---------------------------------------------------------------------------
# s >= 1: bounded error, not corruption
# ---------------------------------------------------------------------------

def test_lasso_converges_under_staleness(mesh):
    r = np.random.default_rng(3)
    X, y, _ = lasso.synthetic_correlated(r, n=120, J=80, corr=0.9,
                                         k_true=8)
    cfg = lasso.LassoConfig(num_features=80, lam=0.02, block_size=8,
                            num_candidates=32, rho=0.3, eta=1e-3)
    _, tr = lasso.fit(cfg, X, y, mesh,
                      plan=ExecutionPlan(executor="ssp", rounds=42,
                                         staleness=2, collect_every=1))
    vals = [v for _, v in tr]
    assert len(vals) == 42
    assert vals[-1] < vals[0] * 0.7             # real progress under s=2


def test_lda_ssp_conserves_counts_and_sync(mesh, rng):
    """Deferred s-sync must still leave s == colsums(B) and conserve the
    token count at every flush boundary (the run ends on one)."""
    cfg = lda.LDAConfig(vocab=30, num_topics=4, num_workers=1,
                        tokens_per_worker=200, docs_per_worker=5)
    words, docs, z0 = lda.synthetic_corpus(rng, cfg, true_topics=4)
    state, tr, _ = lda.fit(cfg, words, docs, z0, mesh,
                           plan=ExecutionPlan(executor="ssp", rounds=8,
                                              staleness=1, collect_every=4))
    n_tok = int((words >= 0).sum())
    assert float(jnp.sum(state["B"])) == n_tok
    assert float(jnp.sum(state["D"])) == n_tok
    assert bool(jnp.allclose(state["s"], jnp.sum(state["B"], axis=0)))
    assert tr[-1][1] > tr[0][1]                 # likelihood still climbs


# ---------------------------------------------------------------------------
# parameter-server plumbing (server split, cache gate, KV-store wiring)
# ---------------------------------------------------------------------------

def test_server_split_and_byte_accounting(mesh, rng):
    cfg, X, y = _lasso_problem(rng, n=40, J=20)
    eng = lasso.make_engine(cfg, mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    # engine placement now goes through the KV store; the Δβ priority
    # history is the engine-owned scheduler carry, not a state leaf
    assert eng.kvstore is not None
    assert set(eng.kvstore.specs) == {"beta", "r"}
    assert eng.kvstore.total_bytes() == (20 + 40) * 4
    srv = ParameterServer.from_state(eng.mesh, state, eng._sspec(state))
    assert srv.shared_names == {"beta"}              # r is worker-local
    assert srv.shared_nbytes() == 20 * 4
    snap = srv.snapshot(state)
    assert set(snap) == {"beta"}
    merged = srv.merge(state, snap)
    _bit_identical(merged, state)


def test_stale_cache_gate():
    c = StaleCache(values={"x": jnp.zeros(3)}, clock=jnp.int32(4))
    assert int(c.staleness(6)) == 2
    assert bool(c.fresh_enough(6, 2)) and not bool(c.fresh_enough(7, 2))
    c2 = c.refresh({"x": jnp.ones(3)}, 7)
    assert int(c2.staleness(7)) == 0


def test_init_clocks_lockstep():
    clocks = init_clocks(4)
    assert clocks.shape == (4,) and int(clocks.sum()) == 0
