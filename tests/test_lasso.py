"""STRADS Lasso: correctness against the single-machine CD oracle, the
paper's divergence/convergence claims, and property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.apps import lasso
from repro.core import single_device_mesh


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


def _objective(X, y, b, lam):
    return 0.5 * np.sum((y - X @ b) ** 2) + lam * np.sum(np.abs(b))


def test_soft_threshold():
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    out = np.asarray(lasso.soft_threshold(x, 1.0))
    assert np.allclose(out, [-1.0, 0.0, 0.0, 0.0, 1.0])


def test_converges_to_reference_objective(mesh, rng):
    X, y, _ = lasso.synthetic_correlated(rng, n=150, J=60, k_true=5)
    lam = 0.02
    cfg = lasso.LassoConfig(num_features=60, lam=lam, block_size=8,
                            num_candidates=32, rho=0.3, eta=1e-2)
    state, _ = lasso.fit(cfg, X, y, mesh, num_rounds=400)
    ref = lasso.reference_cd(X, y, lam, 100)
    got = _objective(X, y, np.asarray(state["beta"]), lam)
    want = _objective(X, y, ref, lam)
    assert got <= want * 1.05 + 1e-6     # within 5% of the CD optimum


def test_single_coordinate_update_matches_oracle(mesh, rng):
    """One masked-single-coordinate round == one oracle CD step (exactness
    of the push/pull partial-sum aggregation)."""
    X, y, _ = lasso.synthetic_correlated(rng, n=50, J=10, k_true=3)
    lam = 0.05
    cfg = lasso.LassoConfig(num_features=10, lam=lam, block_size=1,
                            scheduler="cyclic")
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    state = eng.app.init_state(jax.random.key(0), y=y)
    out = eng.run_round(state, data, jax.random.key(1), t=0)
    # oracle: coordinate 0 from beta=0: beta_0 = S(x_0^T y, lam)
    z0 = X[:, 0] @ y
    want = np.sign(z0) * max(abs(z0) - lam, 0.0)
    assert np.isclose(float(out.state["beta"][0]), want, rtol=1e-5)
    # residual consistency: r == y - X beta
    r_want = y - X @ np.asarray(out.state["beta"])
    assert np.allclose(np.asarray(out.state["r"]), r_want, atol=1e-5)


def test_rr_diverges_strads_converges(mesh):
    """The paper's central Lasso claim (§3.3 / Fig 9): naive random
    parallel CD diverges on correlated designs at large U; the ρ-filtered
    dynamic schedule converges."""
    r = np.random.default_rng(1)
    X, y, _ = lasso.synthetic_correlated(r, n=100, J=200, corr=0.1, k_true=5)
    lam = 0.02
    rr = lasso.LassoConfig(num_features=200, lam=lam, block_size=64,
                           scheduler="rr")
    _, tr_rr = lasso.fit(rr, X, y, mesh, num_rounds=60, trace_every=59)
    sd = lasso.LassoConfig(num_features=200, lam=lam, block_size=64,
                           num_candidates=128, rho=0.1, eta=1e-2,
                           scheduler="strads")
    _, tr_sd = lasso.fit(sd, X, y, mesh, num_rounds=60, trace_every=59)
    obj0 = _objective(X, y, np.zeros(200, np.float32), lam)
    rr_final = tr_rr[-1][1]
    sd_final = tr_sd[-1][1]
    assert not np.isfinite(rr_final) or rr_final > obj0   # diverged
    assert np.isfinite(sd_final) and sd_final < obj0      # converged


def test_priority_beats_cyclic_early(mesh):
    """Dynamic prioritization reaches a lower objective in the same number
    of rounds than cyclic round-robin (the paper's convergence-speed
    claim, laptop scale)."""
    r = np.random.default_rng(2)
    X, y, _ = lasso.synthetic_correlated(r, n=200, J=400, corr=0.9,
                                         k_true=8)
    lam = 0.02
    kw = dict(num_features=400, lam=lam, block_size=8)
    dyn = lasso.LassoConfig(**kw, num_candidates=64, rho=0.3, eta=1e-3,
                            scheduler="strads")
    cyc = lasso.LassoConfig(**kw, scheduler="cyclic")
    _, tr_d = lasso.fit(dyn, X, y, mesh, num_rounds=50, trace_every=49)
    _, tr_c = lasso.fit(cyc, X, y, mesh, num_rounds=50, trace_every=49)
    assert tr_d[-1][1] < tr_c[-1][1]


def test_schedule_respects_rho(mesh, rng):
    """Property: every pair of *applied* updates in a round has sample
    correlation below ρ."""
    X, y, _ = lasso.synthetic_correlated(rng, n=80, J=50, corr=0.1,
                                         k_true=5)
    cfg = lasso.LassoConfig(num_features=50, lam=0.02, block_size=8,
                            num_candidates=24, rho=0.2)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    state = eng.app.init_state(jax.random.key(0), y=y)
    sc = eng.init_sched_carry()          # the Δβ priority history
    for t in range(5):
        out = eng.run_round(state, data, jax.random.key(t), t=t,
                            sched_carry=sc)
        idx = np.asarray(out.sched["idx"])
        mask = np.asarray(out.sched["mask"])
        kept = idx[mask]
        G = np.abs(X[:, kept].T @ X[:, kept])
        np.fill_diagonal(G, 0)
        assert (G < 0.2 + 1e-5).all()
        state, sc = out.state, out.sched_carry


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.01, 0.2))
def test_objective_never_increases_single_updates(seed, lam):
    """Property: with U=1 (pure sequential CD), the Lasso objective is
    non-increasing — CD on a convex objective descends every step."""
    mesh = single_device_mesh()
    r = np.random.default_rng(seed)
    X, y, _ = lasso.synthetic_correlated(r, n=40, J=12, k_true=3)
    cfg = lasso.LassoConfig(num_features=12, lam=lam, block_size=1,
                            scheduler="cyclic")
    _, trace = lasso.fit(cfg, X, y, mesh, num_rounds=24, trace_every=1)
    vals = [v for _, v in trace]
    for a, b in zip(vals, vals[1:]):
        assert b <= a + 1e-4
