"""Engine checkpoint/resume: ``(state, scheduler carry, round counter,
SSP clocks)`` round-trip through ``checkpoint/npz`` — a resumed run must
match an uninterrupted one bit-for-bit (PRNG keys are serialized as key
data and re-wrapped, so the random stream continues exactly).  Also the
trainer-level ``launch/train.py --resume`` path.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.apps import lasso
from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.core import single_device_mesh


def _bit_identical(a_state, b_state):
    for k in a_state:
        a, b = np.asarray(a_state[k]), np.asarray(b_state[k])
        assert (a == b).all(), (k, np.max(np.abs(a - b)))


def _setup(rng):
    mesh = single_device_mesh()
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    return eng, data, y


def test_ssp_resume_matches_uninterrupted(tmp_path, rng):
    eng, data, y = _setup(rng)
    s = 1

    # uninterrupted: 8 rounds in one go
    full = eng.run_ssp(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1), 8, staleness=s)

    # interrupted: 4 rounds, checkpoint the full run state, restore into
    # a fresh template, continue 4 more
    st, carry = eng.run_ssp(eng.init_state(jax.random.key(0), y=y), data,
                            jax.random.key(1), 4, staleness=s,
                            return_carry=True)
    save_checkpoint(str(tmp_path), 4, {"state": st, "carry": carry})
    assert latest_step(str(tmp_path)) == 4

    template = {"state": jax.tree.map(jnp.copy, st), "carry": carry}
    restored = restore_checkpoint(str(tmp_path), 4, template)
    c = restored["carry"]
    assert int(c.t) == 4 and (np.asarray(c.clocks) == 4).all()
    resumed = eng.run_ssp(restored["state"], data, c.rng, 4, staleness=s,
                          t0=int(c.t), clocks=c.clocks)
    _bit_identical(full, resumed)


def test_scanned_state_roundtrips_through_npz(tmp_path, rng):
    """The scheduler carry (Δx history) rides the state pytree, so a
    plain state round-trip preserves the dynamic schedule exactly."""
    eng, data, y = _setup(rng)
    st = eng.run_scanned(eng.init_state(jax.random.key(0), y=y), data,
                         jax.random.key(1), 4)
    save_checkpoint(str(tmp_path), 4, st)
    back = restore_checkpoint(str(tmp_path), 4,
                              jax.tree.map(jnp.zeros_like, st))
    _bit_identical(st, back)


def test_ssp_resume_rejects_misaligned_t0(rng):
    eng, data, y = _setup(rng)
    st = eng.init_state(jax.random.key(0), y=y)
    with pytest.raises(ValueError, match="t0"):
        eng.run_ssp(st, data, jax.random.key(1), 4, staleness=1, t0=3)


@pytest.mark.slow
def test_train_resume_matches_uninterrupted(tmp_path):
    """launch/train.py --resume: full-state checkpoints make the resumed
    run reproduce the uninterrupted loss exactly (deterministic synthetic
    batches are indexed by global step)."""
    from repro.launch import train

    common = ["--arch", "xlstm-125m", "--preset", "reduced",
              "--steps", "4", "--batch", "2", "--seq", "16",
              "--log-every", "1", "--seed", "7"]
    full = train.main(common)

    d = str(tmp_path / "ck")
    train.main(common + ["--ckpt-dir", d, "--ckpt-every", "2"])
    assert latest_step(d) == 4
    # wipe the final checkpoint so --resume restarts mid-run (step 2)
    import os
    os.remove(os.path.join(d, "step_00000004.npz"))
    resumed = train.main(common + ["--ckpt-dir", d, "--resume"])

    assert resumed[-1]["step"] == full[-1]["step"] == 3
    assert resumed[-1]["loss"] == pytest.approx(full[-1]["loss"],
                                                rel=1e-6, abs=0)
