"""Engine checkpoint/resume: ``(state, scheduler carry, round counter,
SSP clocks)`` round-trip through ``checkpoint/npz`` — a resumed run must
match an uninterrupted one bit-for-bit (PRNG keys are serialized as key
data and re-wrapped, so the random stream continues exactly).  Also the
plan path (``StradsEngine.execute`` chunked by ``plan.checkpoint_every``,
``ExecutionReport.carry`` round-trips) and the trainer-level
``launch/train.py --resume --plan`` path.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.apps import lasso
from repro.checkpoint import (latest_step, load_flat, restore_checkpoint,
                              save_checkpoint)
from repro.core import ExecutionPlan, single_device_mesh
from repro.stream import LassoDriftSource, StreamSpec, replay_data


def _bit_identical(a_state, b_state):
    for k in a_state:
        a, b = np.asarray(a_state[k]), np.asarray(b_state[k])
        assert (a == b).all(), (k, np.max(np.abs(a - b)))


def _setup(rng):
    mesh = single_device_mesh()
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    return eng, data, y


def test_ssp_resume_matches_uninterrupted(tmp_path, rng):
    eng, data, y = _setup(rng)
    s = 1

    # uninterrupted: 8 rounds in one go
    full = eng.run_ssp(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1), 8, staleness=s)

    # interrupted: 4 rounds, checkpoint the full run state, restore into
    # a fresh template, continue 4 more
    st, carry = eng.run_ssp(eng.init_state(jax.random.key(0), y=y), data,
                            jax.random.key(1), 4, staleness=s,
                            return_carry=True)
    save_checkpoint(str(tmp_path), 4, {"state": st, "carry": carry})
    assert latest_step(str(tmp_path)) == 4

    template = {"state": jax.tree.map(jnp.copy, st), "carry": carry}
    restored = restore_checkpoint(str(tmp_path), 4, template)
    c = restored["carry"]
    assert int(c.t) == 4 and (np.asarray(c.clocks) == 4).all()
    # the scheduler carry (Δβ priority history) is part of SSPCarry now
    # and must resume with the rest of the carry for bit-exactness
    assert c.sched_carry is not None
    resumed = eng.run_ssp(restored["state"], data, c.rng, 4, staleness=s,
                          t0=int(c.t), clocks=c.clocks,
                          sched_carry0=c.sched_carry)
    _bit_identical(full, resumed)


def test_scanned_sched_carry_roundtrips_through_npz(tmp_path, rng):
    """The scheduler carry (Δx history) is an explicit EngineCarry field
    now — ``{"state", "carry"}`` round-trips it through checkpoint/npz,
    and resuming from it continues the dynamic schedule bit-exactly."""
    eng, data, y = _setup(rng)

    full, full_carry = eng.run_scanned(
        eng.init_state(jax.random.key(0), y=y), data, jax.random.key(1),
        8, return_carry=True)

    st, carry = eng.run_scanned(eng.init_state(jax.random.key(0), y=y),
                                data, jax.random.key(1), 4,
                                return_carry=True)
    assert carry.sched_carry is not None        # the Δβ priority history
    save_checkpoint(str(tmp_path), 4, {"state": st, "carry": carry})
    template = {"state": jax.tree.map(jnp.copy, st), "carry": carry}
    back = restore_checkpoint(str(tmp_path), 4, template)
    c = back["carry"]
    assert (np.asarray(c.sched_carry)
            == np.asarray(carry.sched_carry)).all()
    resumed, res_carry = eng.run_scanned(back["state"], data, c.rng, 4,
                                         t0=int(c.t), donate=False,
                                         sched_carry0=c.sched_carry,
                                         return_carry=True)
    _bit_identical(full, resumed)
    # the final carries of full vs chunked runs agree exactly
    assert (np.asarray(full_carry.sched_carry)
            == np.asarray(res_carry.sched_carry)).all()
    # the carry is load-bearing: resuming with a FRESH carry (uniform
    # priorities) must diverge from the uninterrupted dynamic schedule —
    # and omitting it at t0>0 warns about exactly that
    with pytest.warns(UserWarning, match="without sched_carry0"):
        fresh = eng.run_scanned(back["state"], data, c.rng, 4,
                                t0=int(c.t), donate=False)
    assert not (np.asarray(fresh["beta"])
                == np.asarray(full["beta"])).all()


def test_execute_plan_checkpoint_chunks_match_uninterrupted(tmp_path,
                                                            rng):
    """The plan path: ``execute(plan(checkpoint_every=4), ckpt_dir=...)``
    chunks an 8-round SSP run into two compiled spans with a full
    ``{"state", "carry"}`` checkpoint between them — and matches the
    unchunked run bit-for-bit; restoring the mid checkpoint and resuming
    via ``execute(..., carry=...)`` does too."""
    eng, data, y = _setup(rng)

    full = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1),
                       ExecutionPlan(executor="ssp", rounds=8,
                                     staleness=1)).state

    plan = ExecutionPlan(executor="ssp", rounds=8, staleness=1,
                         checkpoint_every=4)
    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1), plan, ckpt_dir=str(tmp_path))
    _bit_identical(full, rep.state)
    assert latest_step(str(tmp_path)) == 8
    assert int(rep.carry.t) == 8

    # ExecutionReport.carry round-trips through checkpoint/npz: restore
    # the mid-run checkpoint and continue the same plan.
    template = {"state": jax.tree.map(jnp.copy, rep.state),
                "carry": rep.carry}
    restored = restore_checkpoint(str(tmp_path), 4, template)
    assert int(restored["carry"].t) == 4
    resumed = eng.execute(restored["state"], data, jax.random.key(99),
                          plan, carry=restored["carry"],
                          ckpt_dir=str(tmp_path / "resumed"))
    _bit_identical(full, resumed.state)


def test_streamed_resume_matches_uninterrupted(tmp_path, rng):
    """Mid-stream checkpoint/resume: the ``"stream"`` cursor payload
    rides the checkpoint beside ``"state"``/``"carry"``, and a resumed
    streamed run — data rebuilt with :func:`repro.stream.replay_data`,
    cursor restored via ``stream_state=`` — continues bit-exactly.
    (ingest-at-top/checkpoint-at-bottom: the checkpoint at t precedes
    the ingest at t, so the resume re-ingests boundary t exactly like
    the uninterrupted run did)."""
    eng, data, y = _setup(rng)
    spec = StreamSpec(kind="replace", ingest_every=2)
    src = lambda: LassoDriftSource(num_rows=40, num_features=20,
                                   rows_per_ingest=4, seed=3)

    full = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1),
                       ExecutionPlan(executor="ssp", rounds=8,
                                     staleness=1),
                       stream=spec, source=src()).state

    plan = ExecutionPlan(executor="ssp", rounds=8, staleness=1,
                         checkpoint_every=4)
    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1), plan, ckpt_dir=str(tmp_path),
                      stream=spec, source=src())
    _bit_identical(full, rep.state)
    assert rep.stream is not None and int(rep.stream["rows_in"]) > 0

    # the mid checkpoint carries the cursor as a "stream" subtree
    flat = load_flat(str(tmp_path), 4)
    stream_state = {k.split("/", 1)[1]: v for k, v in flat.items()
                    if k.startswith("stream/")}
    assert set(stream_state) == {"cursor", "rows_in", "rows_dropped",
                                 "fill0"}

    # a resumed process no longer holds the streamed data: rebuild it
    # from the deterministic source, verified against the cursor
    data4, _ = replay_data(eng, data, spec, src(), 4,
                           stream_state=stream_state)

    template = {"state": jax.tree.map(jnp.copy, rep.state),
                "carry": rep.carry}
    restored = restore_checkpoint(str(tmp_path), 4, template)
    assert int(restored["carry"].t) == 4
    resumed = eng.execute(restored["state"], data4, jax.random.key(99),
                          plan, carry=restored["carry"],
                          ckpt_dir=str(tmp_path / "resumed"),
                          stream=spec, source=src(),
                          stream_state=stream_state)
    _bit_identical(full, resumed.state)
    # the resumed leg's final cursor agrees with the uninterrupted one
    assert int(resumed.stream["rows_in"]) == int(rep.stream["rows_in"])


def test_execute_pipelined_carry_resumes_inflight_schedule(tmp_path, rng):
    """Chunking the pipelined executor must carry the prefetched
    in-flight schedule across the chunk boundary (EngineCarry.sched) —
    without it, the resumed schedule would be fresh instead of one round
    stale and the runs would diverge."""
    eng, data, y = _setup(rng)

    plan_full = ExecutionPlan(executor="pipelined", rounds=8)
    full = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1), plan_full).state

    plan = ExecutionPlan(executor="pipelined", rounds=8,
                         checkpoint_every=4)
    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1), plan, ckpt_dir=str(tmp_path))
    _bit_identical(full, rep.state)
    assert rep.carry.sched is not None          # the in-flight schedule

    template = {"state": jax.tree.map(jnp.copy, rep.state),
                "carry": rep.carry}
    restored = restore_checkpoint(str(tmp_path), 4, template)
    resumed = eng.execute(restored["state"], data, jax.random.key(99),
                          plan, carry=restored["carry"],
                          ckpt_dir=str(tmp_path / "resumed"))
    _bit_identical(full, resumed.state)


def test_execute_chunked_honors_callback_early_stop(tmp_path, rng):
    """A callback stop inside a checkpoint chunk must end the whole run
    (no skipped rounds, no further chunks) and checkpoint at the round
    actually reached."""
    eng, data, y = _setup(rng)
    plan = ExecutionPlan(executor="loop", rounds=6, checkpoint_every=2)
    seen = []

    def cb(t, s, out):
        seen.append(t)
        return t == 2                           # stop mid-chunk 2

    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1), plan, callback=cb,
                      ckpt_dir=str(tmp_path))
    assert seen == [0, 1, 2]
    assert int(rep.carry.t) == 3
    assert latest_step(str(tmp_path)) == 3

    # ... including when the stop lands exactly on a chunk boundary
    seen2 = []
    d2 = tmp_path / "boundary"
    rep2 = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1), plan,
                       callback=lambda t, s, o: (seen2.append(t),
                                                 t == 1)[1],
                       ckpt_dir=str(d2))
    assert seen2 == [0, 1]
    assert int(rep2.carry.t) == 2
    assert latest_step(str(d2)) == 2


def test_execute_chunked_rejects_unrunnable_final_chunk(tmp_path, rng):
    """pipelined/ssp plans whose rounds don't tile the step length must
    fail before any chunk runs (without ckpt_dir the executor itself
    rejects them upfront — chunking must not defer that to the last
    chunk, after checkpoints were already written)."""
    eng, data, y = _setup(rng)
    state = eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="ssp", rounds=7, staleness=1,
                         checkpoint_every=2)    # 7 % 2 != 0
    with pytest.raises(ValueError, match="plan.rounds"):
        eng.execute(state, data, jax.random.key(1), plan,
                    ckpt_dir=str(tmp_path))
    assert latest_step(str(tmp_path)) is None


def test_execute_rejects_foreign_carry_types(tmp_path, rng):
    """Resuming a plan with a carry from a different executor must error,
    not silently diverge from the uninterrupted run."""
    eng, data, y = _setup(rng)

    ssp_rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                          jax.random.key(1),
                          ExecutionPlan(executor="ssp", rounds=4,
                                        staleness=1))
    scan_rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                           jax.random.key(1),
                           ExecutionPlan(executor="scan", rounds=4))
    state = eng.init_state(jax.random.key(0), y=y)
    # SSPCarry into a pipelined plan: no .sched
    with pytest.raises(ValueError, match="EngineCarry"):
        eng.execute(state, data, None,
                    ExecutionPlan(executor="pipelined", rounds=8),
                    carry=ssp_rep.carry)
    # depth-0 EngineCarry into a pipelined plan: sched is None
    with pytest.raises(ValueError, match="in-flight schedule"):
        eng.execute(state, data, None,
                    ExecutionPlan(executor="pipelined", rounds=8),
                    carry=scan_rep.carry)
    # EngineCarry into an ssp plan: no .clocks
    with pytest.raises(ValueError, match="SSPCarry"):
        eng.execute(state, data, None,
                    ExecutionPlan(executor="ssp", rounds=8, staleness=1),
                    carry=scan_rep.carry)


def test_execute_rejects_ckpt_dir_without_cadence(tmp_path, rng):
    """ckpt_dir with checkpoint_every=0 would be a silent no-op — reject
    it so a crash mid-run can't lose progress the caller believed was
    being checkpointed."""
    eng, data, y = _setup(rng)
    state = eng.init_state(jax.random.key(0), y=y)
    with pytest.raises(ValueError, match="checkpoint_every"):
        eng.execute(state, data, jax.random.key(1),
                    ExecutionPlan(executor="scan", rounds=4),
                    ckpt_dir=str(tmp_path))
    # ... and the converse: a checkpointing cadence without anywhere to
    # write would silently never checkpoint
    with pytest.raises(ValueError, match="ckpt_dir"):
        eng.execute(state, data, jax.random.key(1),
                    ExecutionPlan(executor="scan", rounds=4,
                                  checkpoint_every=2))


def test_execute_rejects_misaligned_checkpoint_cadence(tmp_path, rng):
    """checkpoint_every must tile the executor step length — rejected
    upfront, before any chunk runs or checkpoint is written."""
    eng, data, y = _setup(rng)
    state = eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="ssp", rounds=8, staleness=1,
                         checkpoint_every=3)    # SSP window is 2
    with pytest.raises(ValueError, match="checkpoint_every"):
        eng.execute(state, data, jax.random.key(1), plan,
                    ckpt_dir=str(tmp_path))
    assert latest_step(str(tmp_path)) is None


def test_ssp_resume_rejects_misaligned_t0(rng):
    eng, data, y = _setup(rng)
    st = eng.init_state(jax.random.key(0), y=y)
    with pytest.raises(ValueError, match="t0"):
        eng.run_ssp(st, data, jax.random.key(1), 4, staleness=1, t0=3)


@pytest.mark.slow
def test_train_resume_matches_uninterrupted(tmp_path):
    """launch/train.py --resume --plan: full-state checkpoints make the
    resumed run reproduce the uninterrupted loss exactly (deterministic
    synthetic batches are indexed by global step); the interrupted +
    resumed legs are driven by a checked-in-style ExecutionPlan JSON
    (rounds → steps, checkpoint_every → ckpt cadence)."""
    from repro.launch import train

    common = ["--arch", "xlstm-125m", "--preset", "reduced",
              "--steps", "4", "--batch", "2", "--seq", "16",
              "--log-every", "1", "--seed", "7"]
    full = train.main(common)

    plan = ExecutionPlan(executor="loop", rounds=4, checkpoint_every=2)
    plan_path = str(tmp_path / "plan.json")
    with open(plan_path, "w") as f:
        json.dump(plan.to_json(), f)

    d = str(tmp_path / "ck")
    train.main(common + ["--plan", plan_path, "--ckpt-dir", d])
    assert latest_step(d) == 4
    # wipe the final checkpoint so --resume restarts mid-run (step 2)
    import os
    os.remove(os.path.join(d, "step_00000004.npz"))
    resumed = train.main(common + ["--plan", plan_path, "--ckpt-dir", d,
                                   "--resume"])

    assert resumed[-1]["step"] == full[-1]["step"] == 3
    assert resumed[-1]["loss"] == pytest.approx(full[-1]["loss"],
                                                rel=1e-6, abs=0)
