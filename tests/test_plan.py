"""The unified ExecutionPlan surface (ISSUE 3 acceptance).

Contract under test:
  * plans are frozen, hashable values; invalid executor/kwarg
    combinations raise at construction, never at trace time, with the
    executor-name message living in exactly one place;
  * ``to_json → from_json`` round-trips exactly, defaults included, and
    the checked-in ``examples/plans/*.json`` files parse;
  * ``StradsEngine.execute(plan)`` drives all four executors and is
    bit-identical to the legacy entry points (``run`` / ``run_scanned`` /
    ``run_ssp``) on Lasso — the per-app equivalence lives in
    tests/test_engine_scan.py and tests/test_ssp.py;
  * the deprecated ``fit(executor=..., staleness=...)`` shim warns and
    produces bit-identical results to ``fit(plan=...)``;
  * the derived v2 SSP behavior replaced the per-app ``ssp_*`` hook
    overrides (they are gone from the apps), while legacy hooks on a
    user app still run behind a DeprecationWarning.
"""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import lasso, lda, mf
from repro.core import (ExecutionPlan, ExecutionReport, StradsEngine,
                        single_device_mesh)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


def _bit_identical(a_state, b_state):
    assert set(a_state) == set(b_state)
    for k in a_state:
        a, b = np.asarray(a_state[k]), np.asarray(b_state[k])
        assert (a == b).all(), (k, np.max(np.abs(a - b)))


def _lasso_setup(rng, n=40, J=20):
    X, y, _ = lasso.synthetic_correlated(rng, n=n, J=J, k_true=3)
    cfg = lasso.LassoConfig(num_features=J, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    return cfg, X, y


# ---------------------------------------------------------------------------
# construction-time validation (the single source of truth)
# ---------------------------------------------------------------------------

def test_plan_is_hashable_value():
    a = ExecutionPlan(executor="ssp", rounds=8, staleness=2)
    b = ExecutionPlan(executor="ssp", rounds=8, staleness=2)
    assert a == b and hash(a) == hash(b)
    assert len({a, b}) == 1


def test_plan_rejects_unknown_executor_with_canonical_message():
    with pytest.raises(ValueError, match="executor must be 'loop', "
                                         "'scan', 'pipelined' or 'ssp'"):
        ExecutionPlan(executor="warp", rounds=4)
    # 'loop' really is acceptable (the drifted apps/_exec.scan_depth
    # message claimed so but raised — ISSUE 3 satellite)
    assert ExecutionPlan(executor="loop", rounds=4).depth == 0


@pytest.mark.parametrize("kw", [
    dict(executor="scan", staleness=1),         # staleness needs ssp
    dict(executor="scan", pipeline_depth=1),    # depth>0 needs pipelined
    dict(executor="pipelined", pipeline_depth=0),
    dict(executor="scan", rounds=0),
    dict(executor="scan", rounds=1, staleness=-1),
    dict(executor="loop", rounds=4, phase_unroll=2),
    dict(executor="ssp", rounds=4, phase_unroll=2),
    dict(executor="scan", rounds=4, telemetry="counters"),  # not a spec
    dict(executor="scan", rounds=4, workers=0),
    dict(executor="scan", rounds=4, collect_every=-1),
])
def test_invalid_combinations_raise_at_construction(kw):
    with pytest.raises(ValueError):
        ExecutionPlan(**kw)


def test_plan_depth_derivation():
    assert ExecutionPlan(executor="scan", rounds=2).depth == 0
    assert ExecutionPlan(executor="pipelined", rounds=2).depth == 1
    assert ExecutionPlan(executor="pipelined", rounds=2,
                         pipeline_depth=1).depth == 1


# ---------------------------------------------------------------------------
# JSON round-trip
# ---------------------------------------------------------------------------

def test_plan_json_roundtrip_exact_including_defaults():
    plans = [
        ExecutionPlan(),
        ExecutionPlan(executor="loop", rounds=3, collect_every=2),
        ExecutionPlan(executor="pipelined", rounds=8, phase_unroll=2,
                      donate=False),
        ExecutionPlan(executor="ssp", rounds=12, staleness=2,
                      telemetry=True, checkpoint_every=6, workers=4),
    ]
    for p in plans:
        d = p.to_json()
        assert ExecutionPlan.from_json(d) == p
        # and through an actual JSON string
        assert ExecutionPlan.from_json(json.dumps(d)) == p


def test_plan_from_json_partial_and_unknown_keys():
    p = ExecutionPlan.from_json({"executor": "ssp", "rounds": 4,
                                 "staleness": 1})
    assert p == ExecutionPlan(executor="ssp", rounds=4, staleness=1)
    with pytest.raises(ValueError, match="unknown ExecutionPlan field"):
        ExecutionPlan.from_json({"executor": "scan", "depth": 1})
    # invalid combinations raise through from_json too (construction-time)
    with pytest.raises(ValueError, match="requires executor='ssp'"):
        ExecutionPlan.from_json({"executor": "scan", "rounds": 4,
                                 "staleness": 2})


def test_checked_in_example_plans_parse():
    paths = sorted(glob.glob(os.path.join(ROOT, "examples", "plans",
                                          "*.json")))
    assert len(paths) >= 2, "examples/plans/ must ship example plans"
    names = {os.path.basename(p) for p in paths}
    assert "ssp_s2.json" in names          # the CI dry-run smoke plan
    for path in paths:
        with open(path) as f:
            raw = json.load(f)
        plan = ExecutionPlan.from_json(raw)
        assert plan.to_json() == raw       # files are exact to_json dumps


# ---------------------------------------------------------------------------
# execute(plan) == the legacy entry points, all four executors
# ---------------------------------------------------------------------------

def test_execute_matches_legacy_entry_points_all_executors(mesh, rng):
    cfg, X, y = _lasso_setup(rng)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})

    def init():
        return eng.init_state(jax.random.key(0), y=y)

    legacy = {
        "loop": lambda: eng.run(init(), data, jax.random.key(1), 8),
        "scan": lambda: eng.run_scanned(init(), data, jax.random.key(1),
                                        8, pipeline_depth=0),
        "pipelined": lambda: eng.run_scanned(init(), data,
                                             jax.random.key(1), 8,
                                             pipeline_depth=1),
        "ssp": lambda: eng.run_ssp(init(), data, jax.random.key(1), 8,
                                   staleness=1),
    }
    for name, run in legacy.items():
        plan = ExecutionPlan(executor=name, rounds=8,
                             staleness=1 if name == "ssp" else 0)
        rep = eng.execute(init(), data, jax.random.key(1), plan)
        assert isinstance(rep, ExecutionReport)
        assert rep.plan is plan and rep.carry is not None
        assert int(rep.carry.t) == 8
        _bit_identical(run(), rep.state)


def test_execute_validates_workers_and_callback(mesh, rng):
    cfg, X, y = _lasso_setup(rng)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    state = eng.init_state(jax.random.key(0), y=y)
    with pytest.raises(ValueError, match="plan.workers"):
        eng.execute(state, data, jax.random.key(1),
                    ExecutionPlan(executor="scan", rounds=2, workers=7))
    with pytest.raises(ValueError, match="callback"):
        eng.execute(state, data, jax.random.key(1),
                    ExecutionPlan(executor="scan", rounds=2),
                    callback=lambda t, s, o: False)


def test_execute_phase_unroll_is_bit_identical(mesh, rng):
    cfg, X, y = _lasso_setup(rng)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})

    def run(unroll):
        plan = ExecutionPlan(executor="scan", rounds=8,
                             phase_unroll=unroll, donate=False)
        return eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                           jax.random.key(1), plan).state

    _bit_identical(run(1), run(4))


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_fit_legacy_kwargs_warn_and_match_plan(mesh, rng):
    cfg, X, y = _lasso_setup(rng)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        s_legacy, _ = lasso.fit(cfg, X, y, mesh, num_rounds=9,
                                executor="ssp", staleness=2)
    s_plan, _ = lasso.fit(cfg, X, y, mesh,
                          plan=ExecutionPlan(executor="ssp", rounds=9,
                                             staleness=2))
    _bit_identical(s_legacy, s_plan)


def test_fit_default_path_does_not_warn(mesh, rng):
    import warnings as W
    cfg, X, y = _lasso_setup(rng)
    with W.catch_warnings():
        W.simplefilter("error", DeprecationWarning)
        lasso.fit(cfg, X, y, mesh, num_rounds=2)


def test_fit_rejects_plan_plus_legacy_kwargs(mesh, rng):
    cfg, X, y = _lasso_setup(rng)
    plan = ExecutionPlan(executor="scan", rounds=4)
    with pytest.raises(ValueError, match="not both"):
        lasso.fit(cfg, X, y, mesh, executor="scan", plan=plan)
    with pytest.raises(ValueError, match="contradicts"):
        lasso.fit(cfg, X, y, mesh, num_rounds=5, plan=plan)


def test_fit_rejects_plan_fields_it_cannot_honor(mesh, rng):
    """fit() has no telemetry/checkpoint surface — silently dropping
    those plan fields would lie to the caller, so they are rejected."""
    cfg, X, y = _lasso_setup(rng)
    with pytest.raises(ValueError, match="telemetry"):
        lasso.fit(cfg, X, y, mesh,
                  plan=ExecutionPlan(executor="ssp", rounds=4,
                                     staleness=1, telemetry=True))
    with pytest.raises(ValueError, match="checkpoint"):
        lasso.fit(cfg, X, y, mesh,
                  plan=ExecutionPlan(executor="scan", rounds=4,
                                     checkpoint_every=2))


def test_run_zero_rounds_is_a_noop(mesh, rng):
    """run_scanned's num_rounds>=1 error directs callers to the host
    loop for zero-round calls — keep that escape hatch working."""
    cfg, X, y = _lasso_setup(rng)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    state = eng.init_state(jax.random.key(0), y=y)
    out = eng.run(state, data, jax.random.key(1), 0)
    _bit_identical(out, state)


# ---------------------------------------------------------------------------
# v2 protocol: hooks are gone from the apps, legacy hooks still honored
# ---------------------------------------------------------------------------

def test_apps_define_no_v1_ssp_hooks():
    for app_cls in (lasso.StradsLasso, lda.StradsLDA, mf.StradsMF):
        for hook in ("ssp_commit_local", "ssp_defer_local",
                     "ssp_commit_shared", "ssp_mark_scheduled"):
            assert not hasattr(app_cls, hook), (app_cls.__name__, hook)


def test_lasso_default_scheduler_specs_follow_config(rng):
    """The app's policy is declarative now: cfg.scheduler maps onto a
    default SchedulerSpec (and no state leaf carries priorities — the
    Δβ history is the engine-owned scheduler carry)."""
    from repro.sched import SchedulerSpec
    cfg, X, y = _lasso_setup(rng)
    assert lasso.StradsLasso(cfg).default_scheduler_spec() == \
        SchedulerSpec(kind="dynamic_priority", block_size=4,
                      num_candidates=8, rho=0.3, eta=1e-6)
    rr = lasso.LassoConfig(num_features=20, scheduler="rr")
    assert lasso.StradsLasso(rr).default_scheduler_spec() == \
        SchedulerSpec(kind="random", block_size=8)
    assert lasso.StradsLasso(cfg).var_roles() == {}


def test_legacy_ssp_hooks_still_run_with_deprecation_warning(mesh, rng):
    """A user app carrying v1 hook overrides keeps working (the shim in
    repro.ps.ssp), warns, and — when the hooks replicate the old
    defaults — matches the derived path bit-for-bit.  Uses the "rr"
    scheduler so neither path applies in-flight exclusion (the strads
    priority masking has no legacy counterpart in this minimal app)."""
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=20, k_true=3)
    cfg = lasso.LassoConfig(num_features=20, lam=0.02, block_size=4,
                            scheduler="rr")

    class LegacyLasso(lasso.StradsLasso):
        def ssp_commit_shared(self, state, sched, z, local, data, phase):
            return self.pull(state, sched, z, local, data, phase)

    eng_legacy = StradsEngine(LegacyLasso(cfg), mesh,
                              data_specs=LegacyLasso(cfg).data_specs(),
                              state_specs=LegacyLasso(cfg).state_specs())
    data = eng_legacy.shard_data({"X": jnp.asarray(X),
                                  "y": jnp.asarray(y)})
    st0 = eng_legacy.init_state(jax.random.key(0), y=y)
    with pytest.warns(DeprecationWarning, match="v1 SSP hook"):
        s_legacy = eng_legacy.run_ssp(st0, data, jax.random.key(1), 8,
                                      staleness=1)

    eng = lasso.make_engine(cfg, mesh)
    s_derived = eng.run_ssp(eng.init_state(jax.random.key(0), y=y),
                            eng.shard_data({"X": jnp.asarray(X),
                                            "y": jnp.asarray(y)}),
                            jax.random.key(1), 8, staleness=1)
    _bit_identical(s_legacy, s_derived)
