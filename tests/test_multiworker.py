"""Multi-worker integration tests.

Run the STRADS apps on real multi-device meshes (4 forced host devices) in
subprocesses, since the parent test process must keep the default single
device (see conftest).  These exercise the actual collective paths:
psum pull aggregation and the LDA rotation ppermute.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=540)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_lasso_4workers_matches_single_worker():
    """The psum partial aggregation must make the 4-shard run numerically
    equivalent to the 1-shard run (same schedule RNG ⇒ same updates)."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.apps import lasso
        from repro.core import worker_mesh, single_device_mesh
        r = np.random.default_rng(0)
        X, y, _ = lasso.synthetic_correlated(r, n=80, J=40, k_true=5)
        cfg = lasso.LassoConfig(num_features=40, lam=0.02, block_size=4,
                                num_candidates=16, rho=0.3)
        s4, _ = lasso.fit(cfg, X, y, worker_mesh(4), num_rounds=30)
        s1, _ = lasso.fit(cfg, X, y, single_device_mesh(), num_rounds=30)
        b4, b1 = np.asarray(s4["beta"]), np.asarray(s1["beta"])
        d = float(np.max(np.abs(b4 - b1)))
        print("MAXDIFF", d)
        assert d < 1e-4, d
    """)
    assert "MAXDIFF" in out


@pytest.mark.slow
def test_mf_4workers_objective_decreases():
    run_sub("""
        import numpy as np
        from repro.apps import mf
        from repro.core import worker_mesh
        r = np.random.default_rng(0)
        A, mask = mf.synthetic_ratings(r, 64, 40, true_rank=6, density=0.5)
        cfg = mf.MFConfig(num_rows=64, num_cols=40, rank=6, lam=0.05)
        _, tr = mf.fit(cfg, A, mask, worker_mesh(4), num_rounds=40,
                       trace_every=39)
        assert tr[-1][1] < tr[0][1] * 0.5, tr
    """)


@pytest.mark.slow
def test_lda_rotation_4workers():
    """Rotation over 4 workers: counts conserved, small s-error, rising
    likelihood — the paper's Fig-5 setting in miniature."""
    run_sub("""
        import numpy as np, jax.numpy as jnp
        from repro.apps import lda
        from repro.core import worker_mesh
        r = np.random.default_rng(0)
        cfg = lda.LDAConfig(vocab=64, num_topics=8, num_workers=4,
                            tokens_per_worker=600, docs_per_worker=8)
        words, docs, z0 = lda.synthetic_corpus(r, cfg, true_topics=8)
        state, tr, serr = lda.fit(cfg, words, docs, z0, worker_mesh(4),
                                  num_rounds=16, trace_every=4)
        assert float(jnp.sum(state["B"])) == int((words >= 0).sum())
        assert bool(jnp.allclose(state["s"], jnp.sum(state["B"], 0)))
        assert tr[-1][1] > tr[0][1]
        # s-error small (paper: <= 0.002 at scale; tiny corpus => <= 0.05)
        assert all(v <= 0.05 for _, v in serr), serr
    """)


@pytest.mark.slow
def test_lasso_memory_partitioning():
    """Fig-3 style check: per-device residual/data bytes shrink 4x on a
    4-worker mesh (addressable shard inspection)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.apps import lasso
        from repro.core import worker_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = worker_mesh(4)
        X = np.zeros((64, 16), np.float32)
        Xs = jax.device_put(X, NamedSharding(mesh, P("data")))
        shard_bytes = Xs.addressable_shards[0].data.nbytes
        assert shard_bytes * 4 == X.nbytes, (shard_bytes, X.nbytes)
    """)
