"""Shared test fixtures.

NOTE: XLA_FLAGS device-count forcing is deliberately NOT set here — smoke
tests and benches see whatever devices the environment provides (CI runs
the suite twice: once single-device, once with 4 forced host devices).
Multi-worker tests spawn subprocesses (see helpers in
test_multiworker.py) or use mesh size 1.

If `hypothesis` is not installed (bare container, no test extra), a
deterministic stub is registered so the property tests still collect and
run — see tests/_hypothesis_stub.py.
"""
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_stub
    _hypothesis_stub.install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
