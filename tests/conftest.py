"""Shared test fixtures.

NOTE: XLA_FLAGS device-count forcing is deliberately NOT set here — smoke
tests and benches see the real single device.  Multi-worker tests spawn
subprocesses (see helpers in test_multiworker.py) or use mesh size 1.
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
