"""Model-substrate correctness: decode/forward parity, attention variants,
MoE dispatch equivalence, chunked-scan equivalence, sharding helpers.

The decode-parity tests are the strongest invariant in the system: running
prefill + N decode steps must reproduce the same logits as one full
forward pass, for every family (attention ring buffers, SSM states, xLSTM
matrix memories)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, get_config
from repro.kernels import ref
from repro.models import model as M
from repro.models.layers import _chunked_attention, _sdpa_grouped
from repro.models.scan_utils import chunked_scan, default_chunk
from repro.sharding import rules


# ---------------------------------------------------------------------------
# decode parity: prefill + decode steps == full forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).encoder_only])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        # capacity accounting is per dispatch group, so drop patterns
        # differ between a 24-token forward and a 1-token decode; parity
        # is only defined in the no-drop regime.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    prm = M.init_params(cfg, key)
    B, S, T = 2, 24, 4                      # prompt 24, decode 4
    toks = jax.random.randint(key, (B, S + T), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    n_front = 0
    if cfg.frontend == "vision":
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32) * 0.02
        n_front = cfg.frontend_tokens

    full_logits, _ = M.forward(cfg, prm, batch)            # (B, S+T, Vp)

    pre = dict(batch, tokens=toks[:, :S])
    lg, cache = M.prefill(cfg, prm, pre, cache_len=S + T + n_front)
    got = [lg]
    for t in range(T - 1):
        lg, cache = M.decode_step(cfg, prm, cache, toks[:, S + t],
                                  jnp.int32(S + t + n_front))
        got.append(lg)
    want = full_logits[:, S - 1:S + T - 1]
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0, atol=2e-2)


def test_decode_ring_buffer_window_matches_forward():
    """Sliding-window decode with a ring-buffer cache smaller than the
    sequence must equal windowed full attention."""
    cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                              window=8)
    key = jax.random.PRNGKey(1)
    prm = M.init_params(cfg, key)
    B, S, T, W = 2, 12, 6, 8
    toks = jax.random.randint(key, (B, S + T), 0, cfg.vocab_size)
    full_logits, _ = M.forward(cfg, prm, {"tokens": toks}, window=W)
    lg, cache = M.prefill(cfg, prm, {"tokens": toks[:, :S]}, cache_len=W,
                          window=W)
    got = [lg]
    for t in range(T - 1):
        lg, cache = M.decode_step(cfg, prm, cache, toks[:, S + t],
                                  jnp.int32(S + t), window=W)
        got.append(lg)
    want = full_logits[:, S - 1:S + T - 1]
    np.testing.assert_allclose(np.asarray(jnp.stack(got, 1), np.float32),
                               np.asarray(want, np.float32),
                               rtol=0, atol=2e-2)


# ---------------------------------------------------------------------------
# attention variants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("window", [None, 7])
def test_grouped_sdpa_matches_ref(hq, hkv, window):
    key = jax.random.PRNGKey(0)
    B, S, D = 2, 33, 16
    q = jax.random.normal(key, (B, S, hq, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, hkv, D))
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    got = _sdpa_grouped(q, k, v, causal=True, window=window, q_offset=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5)


@pytest.mark.parametrize("sq,skv", [(64, 64), (40, 40), (16, 48)])
def test_chunked_attention_matches_full(sq, skv):
    key = jax.random.PRNGKey(3)
    B, H, K, D = 2, 4, 2, 8
    q = jax.random.normal(key, (B, sq, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, skv, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, skv, K, D))
    want = ref.attention_ref(q, k, v, causal=True)
    got = _chunked_attention(q, k, v, causal=True, window=None, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# chunked_scan (sqrt remat) equivalence, incl. gradients
# ---------------------------------------------------------------------------

@given(st.integers(5, 70))
@settings(max_examples=10, deadline=None)
def test_chunked_scan_matches_plain(S):
    xs = jnp.sin(jnp.arange(S * 3, dtype=jnp.float32)).reshape(S, 3)

    def step(c, x):
        c = 0.9 * c + x
        return c, c * 2.0

    c0 = jnp.zeros((3,))
    want_c, want_y = jax.lax.scan(step, c0, xs)
    got_c, got_y = chunked_scan(step, c0, xs)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-6)

    g1 = jax.grad(lambda x: jax.lax.scan(step, c0, x)[1].sum())(xs)
    g2 = jax.grad(lambda x: chunked_scan(step, c0, x)[1].sum())(xs)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(g1), rtol=1e-5)


def test_default_chunk_divides():
    for s in (1, 7, 64, 100, 4096, 32768):
        k = default_chunk(s)
        assert s % k == 0 and k >= 1


# ---------------------------------------------------------------------------
# MoE dispatch equivalence (einsum vs sort) and capacity drops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["phi3.5-moe-42b-a6.6b",
                                  "llama4-maverick-400b-a17b"])
def test_moe_einsum_equals_sort_dispatch(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              capacity_factor=8.0)   # no drops
    key = jax.random.PRNGKey(0)
    prm = M.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    l1, a1 = M.forward(cfg, prm, batch)
    l2, a2 = M.forward(dataclasses.replace(cfg, moe_impl="sort"),
                       prm, batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=0, atol=2e-2)
    assert abs(float(a1) - float(a2)) < 1e-6


def test_moe_capacity_drops_tokens_not_nan():
    cfg = dataclasses.replace(get_config("phi3.5-moe-42b-a6.6b").reduced(),
                              capacity_factor=0.25)  # force overflow
    key = jax.random.PRNGKey(0)
    prm = M.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    logits, aux = M.forward(cfg, prm, batch)
    assert not bool(jnp.isnan(logits).any())


# ---------------------------------------------------------------------------
# Mamba2 SSD chunked form (§Perf variant) == sequential scan oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(256, 64), (128, 128), (96, 32)])
def test_ssd_chunked_matches_scan(S, chunk):
    from repro.kernels.ref import ssm_scan_ref
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(0)
    B, H, hd, N = 2, 3, 32, 16
    C = H * hd
    x = jax.random.normal(key, (B, S, C), jnp.float32)
    dt = jnp.repeat(jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(key, 1), (B, S, H))),
        hd, axis=-1)
    A = jnp.repeat(-jnp.exp(
        jax.random.normal(jax.random.fold_in(key, 2), (H,))), hd)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N))
    h0 = jax.random.normal(jax.random.fold_in(key, 5), (B, C, N))
    y1, h1 = ssm_scan_ref(x, dt, A, Bm, Cm, h0)
    y2, h2 = ssd_chunked(x, dt, A, Bm, Cm, h0, head_dim=hd, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=0, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h1),
                               rtol=0, atol=2e-3)


def test_zamba_ssd_variant_matches_scan_model_level():
    cfg = get_config("zamba2-2.7b").reduced()
    key = jax.random.PRNGKey(0)
    prm = M.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 64), 0,
                                          cfg.vocab_size)}
    l1, _ = M.forward(cfg, prm, batch)
    l2, _ = M.forward(dataclasses.replace(cfg, ssm_impl="ssd"), prm, batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=0, atol=3e-2)


# ---------------------------------------------------------------------------
# chunkwise-parallel mLSTM (§Perf xlstm iteration) == sequential cell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(256, 64), (128, 128)])
def test_mlstm_chunkwise_matches_sequential(S, chunk):
    from repro.models.xlstm import _mlstm_cell, mlstm_chunkwise
    key = jax.random.PRNGKey(0)
    B, H, hd = 2, 3, 32
    qf = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    kf = jax.random.normal(jax.random.fold_in(key, 1),
                           (B, S, H, hd)) * hd ** -0.5
    vf = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    ig = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H)) * 2
    fg = jax.random.normal(jax.random.fold_in(key, 4), (B, S, H)) * 2 + 1
    state = (jnp.zeros((B, H, hd, hd)), jnp.zeros((B, H, hd)),
             jnp.full((B, H), -jnp.inf))

    def step(c, x):
        h, c = _mlstm_cell(*x, c)
        return c, h
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qf, kf, vf, ig, fg))
    (c1, n1, m1), hs1 = jax.lax.scan(step, state, xs)
    hs1 = jnp.moveaxis(hs1, 0, 1)
    hs2, (c2, n2, m2) = mlstm_chunkwise(qf, kf, vf, ig, fg, state,
                                        chunk=chunk)
    np.testing.assert_allclose(np.asarray(hs2), np.asarray(hs1),
                               rtol=0, atol=2e-3)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(c1),
                               rtol=0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(m1),
                               rtol=0, atol=1e-4)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_padded_heads():
    assert rules.padded_heads(40, 8) == (48, 8)      # llama4
    assert rules.padded_heads(36, 36) == (48, 48)    # minicpm
    assert rules.padded_heads(32, 2) == (32, 2)      # chatglm
    assert rules.padded_heads(32, 32) == (32, 32)
    hq, kv = rules.padded_heads(14, 2)               # internvl
    assert hq % 16 == 0 and hq % kv == 0


def test_padded_vocab_is_shardable():
    for v in (504, 32000, 49155, 65024, 122753, 151655, 202048):
        vp = rules.padded_vocab(v)
        assert vp >= v and vp % (128 * rules.MODEL_AXIS_SIZE) == 0


def test_resolve_drops_nondivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 1-way mesh: everything divides, spec resolves without error
    spec = rules.resolve(mesh, (rules.BATCH, rules.TENSOR), (4, 6))
    assert spec is not None


def test_vocab_padding_masked_in_loss():
    from repro.train.losses import cross_entropy
    B, S, V, VP = 2, 3, 5, 8
    logits = jnp.zeros((B, S, VP))
    # put huge mass on a padded class: loss must ignore it
    logits = logits.at[..., V + 1].set(100.0)
    labels = jnp.zeros((B, S), jnp.int32)
    loss, _ = cross_entropy(logits, labels, V)
    assert float(loss) == pytest.approx(np.log(V), abs=1e-4)
