"""The dynamic partitioning subsystem (`repro/part`):

* ``PartitionerSpec`` — construction validation (kinds, per-kind field
  rejection) and exact JSON round-trips;
* ``Assignment`` — validation, accounting, payload/JSON round-trips,
  and the static-assignment ≡ rotation-bounds consistency guarantee;
* the three policies behind ``build_partitioner`` (greedy balance
  determinism, EMA measurement, rebalance gating);
* engine wiring — ``plan.partitioner`` resolution, app×kind
  compatibility, ``PartitionerSpec(kind="static")`` (and
  ``partitioner=None``) bit-identical to the pre-subsystem behavior on
  every executor, and the chunk-boundary rebalance +
  ``{"state", "carry", "assignment"}`` checkpoint/resume path being
  bit-exact with the assignment restored.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.apps import lasso, lda, mf
from repro.checkpoint import restore_checkpoint
from repro.core import ExecutionPlan, single_device_mesh
from repro.part import (Assignment, PartitionerSpec, build_partitioner,
                        contiguous_assignment, greedy_balance)
from repro.sched.schedulers import RotationScheduler


# ---------------------------------------------------------------------------
# PartitionerSpec
# ---------------------------------------------------------------------------

def test_spec_valid_kinds_and_json_roundtrip():
    for spec in (PartitionerSpec(kind="static"),
                 PartitionerSpec(kind="size_balanced"),
                 PartitionerSpec(kind="load_balanced", rebalance_every=8,
                                 ema=0.5, imbalance_threshold=0.25)):
        d = spec.to_json()
        assert PartitionerSpec.from_json(d) == spec
        # every field present, defaults included (exact dumps — plan
        # files and BENCH_part.json rely on it)
        assert set(d) == {"kind", "rebalance_every", "ema",
                          "imbalance_threshold"}


def test_spec_rejects_bad_kind_and_foreign_fields():
    with pytest.raises(ValueError, match="kind"):
        PartitionerSpec(kind="dynamic")
    # static/size_balanced consume no fields — a knob that would be
    # silently ignored is rejected at construction
    for kind in ("static", "size_balanced"):
        with pytest.raises(ValueError, match="does not apply"):
            PartitionerSpec(kind=kind, ema=0.5)
        with pytest.raises(ValueError, match="does not apply"):
            PartitionerSpec(kind=kind, rebalance_every=4)
    with pytest.raises(ValueError, match="ema"):
        PartitionerSpec(kind="load_balanced", ema=1.0)
    with pytest.raises(ValueError, match="rebalance_every"):
        PartitionerSpec(kind="load_balanced", rebalance_every=-1)
    with pytest.raises(ValueError, match="unknown"):
        PartitionerSpec.from_json({"kind": "static", "rho": 0.3})


def test_spec_default_for_matches_validation():
    for kind in ("static", "size_balanced", "load_balanced"):
        spec = PartitionerSpec.default_for(kind)
        assert spec.kind == kind
    assert PartitionerSpec.default_for(
        "load_balanced", imbalance_threshold=0.5).imbalance_threshold == 0.5


# ---------------------------------------------------------------------------
# Assignment
# ---------------------------------------------------------------------------

def test_assignment_validation_and_accounting():
    a = Assignment(owner=(0, 0, 1, 1), num_workers=2)
    assert a.num_vars == 4
    assert list(a.counts()) == [2, 2]
    loads = a.loads([1.0, 2.0, 3.0, 4.0])
    assert list(loads) == [3.0, 7.0]
    assert a.spread([1.0, 2.0, 3.0, 4.0]) == pytest.approx(4.0 / 5.0)
    assert a.spread([0.0, 0.0, 0.0, 0.0]) == 0.0
    with pytest.raises(ValueError, match="worker ids"):
        Assignment(owner=(0, 2), num_workers=2)
    with pytest.raises(ValueError, match="shape"):
        a.loads([1.0, 2.0])
    # hashable: usable as a compiled-program cache key
    assert hash(a) == hash(Assignment(owner=[0, 0, 1, 1], num_workers=2))
    assert a != Assignment(owner=(0, 0, 1, 1), num_workers=2, version=1)


def test_assignment_payload_and_json_roundtrip():
    a = Assignment(owner=(1, 0, 2, 1), num_workers=3, version=5)
    assert Assignment.from_json(a.to_json()) == a
    back = Assignment.from_payload(a.payload())
    assert back == a
    # the payload is flat numpy — exactly what checkpoint/npz stores
    p = a.payload()
    assert p["owner"].dtype == np.int32
    assert int(p["version"]) == 5


def test_static_assignment_matches_rotation_bounds():
    """The static partition and the LDA rotation scheduler must share
    one variable→worker map — a disagreement would desync the schedule's
    ppermute pattern from the ownership accounting."""
    # incl. a vocab-scale J where float32 vs float64 linspace rounding
    # diverges — the assignment must follow the scheduler's float32 path
    for J, U in ((10, 4), (16, 4), (7, 3), (5, 8), (1000003, 7)):
        a = contiguous_assignment(J, U)
        bounds = np.asarray(RotationScheduler(J, U).bounds)
        expect = np.searchsorted(bounds[1:], np.arange(J), side="right")
        assert a.owner == tuple(int(o) for o in expect)


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

def test_greedy_balance_is_deterministic_and_capacity_bounded():
    w = np.array([10.0, 9.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0])
    a = greedy_balance(w, 2)
    assert a == greedy_balance(w, 2)            # deterministic
    counts = a.counts()
    assert counts.max() - counts.min() <= 1     # balanced bins
    # the two heavy variables land on different workers (contiguous
    # static would pile both onto worker 0)
    assert a.owner[0] != a.owner[1]
    assert a.spread(w) < contiguous_assignment(8, 2).spread(w)


def test_size_balanced_uses_sizes():
    spec = PartitionerSpec(kind="size_balanced")
    part = build_partitioner(spec, num_vars=4, num_workers=2,
                             sizes=[100.0, 1.0, 1.0, 98.0])
    a = part.init_assignment()
    assert a.owner[0] != a.owner[3]             # big ones split
    assert not part.should_rebalance(part.init_stats(), a, 0)


def test_load_balanced_measure_and_rebalance_gating():
    spec = PartitionerSpec(kind="load_balanced", rebalance_every=4,
                           ema=0.5, imbalance_threshold=0.1)
    part = build_partitioner(spec, num_vars=4, num_workers=2)
    a = part.init_assignment()
    assert a == contiguous_assignment(4, 2)     # starts static
    stats = part.init_stats()
    # nothing measured yet → never rebalance
    assert not part.should_rebalance(stats, a, 4)
    act = np.array([8.0, 8.0, 0.0, 0.0])        # all load on worker 0
    stats = part.measure(stats, a, act)
    assert np.allclose(stats["ema"], 0.5 * act)
    stats = part.measure(stats, a, act)
    assert np.allclose(stats["ema"], 0.75 * act)
    # cadence gate: t=2 not a multiple of rebalance_every=4
    assert not part.should_rebalance(stats, a, 2)
    assert part.should_rebalance(stats, a, 4)
    new = part.propose_assignment(stats, a)
    assert new.version == 1
    assert new.spread(stats["ema"]) < a.spread(stats["ema"])
    # activity=None (no app signal) leaves the stats untouched
    assert part.measure(stats, a, None) is stats


def test_build_partitioner_validation():
    with pytest.raises(TypeError, match="PartitionerSpec"):
        build_partitioner({"kind": "static"}, num_vars=4, num_workers=2)
    with pytest.raises(ValueError, match="num_vars"):
        build_partitioner(PartitionerSpec(kind="static"), num_vars=0,
                          num_workers=2)


# ---------------------------------------------------------------------------
# Plan integration
# ---------------------------------------------------------------------------

def test_plan_carries_partitioner_and_roundtrips():
    spec = PartitionerSpec(kind="load_balanced", ema=0.5,
                           imbalance_threshold=0.2)
    plan = ExecutionPlan(executor="scan", rounds=4, partitioner=spec)
    d = plan.to_json()
    assert d["partitioner"]["kind"] == "load_balanced"
    assert ExecutionPlan.from_json(d) == plan
    with pytest.raises(ValueError, match="partitioner"):
        ExecutionPlan(executor="scan", rounds=4,
                      partitioner={"kind": "static"})


# ---------------------------------------------------------------------------
# Engine wiring
# ---------------------------------------------------------------------------

def _lasso_setup(rng, J=20):
    mesh = single_device_mesh()
    X, y, _ = lasso.synthetic_correlated(rng, n=40, J=J, k_true=3)
    cfg = lasso.LassoConfig(num_features=J, lam=0.02, block_size=4,
                            num_candidates=8, rho=0.3)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    return eng, data, y


def test_engine_resolves_app_default_partitioner(rng):
    eng, data, y = _lasso_setup(rng)
    eng.init_state(jax.random.key(0), y=y)
    assert eng.partitioner_spec == PartitionerSpec(kind="static")
    asgn = eng.partition_assignment
    assert asgn is not None and asgn.version == 0
    assert asgn.num_vars == 20 and asgn.num_workers == 1
    # injected into the app too
    assert eng.app.assignment is asgn


@pytest.mark.parametrize("executor,rounds,kw", [
    ("loop", 6, {}), ("scan", 6, {}), ("pipelined", 6, {}),
    ("ssp", 6, {"staleness": 1}),
])
def test_static_partitioner_bit_identical_every_executor(rng, executor,
                                                         rounds, kw):
    """``PartitionerSpec(kind="static")`` — and a plan with
    ``partitioner=None`` resolving the app's static default — must run
    bit-identically to each other on every executor: ownership is
    bookkeeping, never math."""
    eng, data, y = _lasso_setup(rng)
    base = ExecutionPlan(executor=executor, rounds=rounds, **kw)
    explicit = dataclasses.replace(
        base, partitioner=PartitionerSpec(kind="static"))
    st = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                     jax.random.key(1), base).state
    st2 = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1), explicit).state
    for k in st:
        assert (np.asarray(st[k]) == np.asarray(st2[k])).all(), k


def test_app_kind_compatibility_enforced(rng):
    # LDA's rotation owns a frozen contiguous block map — only static
    cfg = lda.LDAConfig(vocab=8, num_topics=2, num_workers=1,
                        tokens_per_worker=8, docs_per_worker=2)
    eng = lda.make_engine(cfg, single_device_mesh())
    with pytest.raises(ValueError, match="cannot host"):
        eng.set_partitioner(PartitionerSpec(kind="load_balanced",
                                            ema=0.5))
    # MF supports every kind (ranks are interchangeable)
    mcfg = mf.MFConfig(num_rows=8, num_cols=6, rank=4)
    meng = mf.make_engine(mcfg, single_device_mesh())
    meng.set_partitioner(PartitionerSpec(kind="load_balanced", ema=0.5))
    assert meng.partition_assignment.num_vars == 4
    # sizes flow from the app into the size_balanced policy
    meng.set_partitioner(PartitionerSpec(kind="size_balanced"))
    assert meng.partitioner.sizes is not None


def test_load_balanced_requires_partition_signal():
    from repro.core import StradsAppBase, StradsEngine

    class NoSignal(StradsAppBase):
        def num_schedulable(self):
            return 4

        def push(self, data, state, sched, phase):
            return None, None

    eng = StradsEngine(NoSignal(), single_device_mesh(), data_specs={})
    with pytest.raises(ValueError, match="partition_signal"):
        eng.set_partitioner(PartitionerSpec(kind="load_balanced",
                                            ema=0.5))


def test_unchunked_load_balanced_plan_warns(rng):
    eng, data, y = _lasso_setup(rng)
    plan = ExecutionPlan(
        executor="scan", rounds=2,
        partitioner=PartitionerSpec(kind="load_balanced", ema=0.5))
    with pytest.warns(UserWarning, match="chunk boundaries"):
        eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                    jax.random.key(1), plan)


def test_misaligned_rebalance_cadence_rejected(rng, tmp_path):
    eng, data, y = _lasso_setup(rng)
    plan = ExecutionPlan(
        executor="scan", rounds=8, checkpoint_every=4,
        partitioner=PartitionerSpec(kind="load_balanced", ema=0.5,
                                    rebalance_every=6))   # 6 % 4 != 0
    with pytest.raises(ValueError, match="rebalance_every"):
        eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                    jax.random.key(1), plan, ckpt_dir=str(tmp_path))


# ---------------------------------------------------------------------------
# Chunk-boundary rebalancing + checkpoint/resume
# ---------------------------------------------------------------------------

def _skewed_lasso(num_workers: int):
    """Power-law column activity concentrated on a contiguous hot block
    — the workload whose static contiguous partition is maximally
    unfair (bench_part's scenario, laptop-sized)."""
    from repro.core import worker_mesh
    rng = np.random.default_rng(0)
    n, J = 80, 32
    X = rng.normal(size=(n, J)).astype(np.float32)
    X -= X.mean(axis=0)
    X /= np.maximum(np.linalg.norm(X, axis=0), 1e-12)
    bstar = np.zeros((J,), np.float32)
    bstar[:8] = 5.0 * np.arange(1, 9, dtype=np.float32) ** -1.2
    y = (X @ bstar).astype(np.float32)
    y -= y.mean()
    cfg = lasso.LassoConfig(num_features=J, lam=0.01, block_size=4,
                            num_candidates=8)
    eng = lasso.make_engine(cfg, worker_mesh(num_workers))
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    return eng, data, y


_LOADBAL = PartitionerSpec(kind="load_balanced", ema=0.5,
                           imbalance_threshold=0.1)


def test_chunked_run_checkpoints_assignment_payload(rng, tmp_path):
    eng, data, y = _skewed_lasso(1)
    plan = ExecutionPlan(executor="scan", rounds=4, checkpoint_every=2,
                         partitioner=_LOADBAL)
    eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                jax.random.key(1), plan, ckpt_dir=str(tmp_path))
    with np.load(str(tmp_path / "step_00000004.npz")) as z:
        keys = set(z.files)
    assert {"assignment/owner", "assignment/num_workers",
            "assignment/version", "assignment/stats_ema"} <= keys


def test_rebalance_fires_and_resumes_bit_exactly(tmp_path):
    """The acceptance path: a mid-run rebalance on the skewed workload,
    resumed from the ``{"state", "carry", "assignment"}`` checkpoint —
    final state AND final assignment/stats must match the uninterrupted
    run exactly.  Multi-worker spreads need >1 device; on a single
    device the partition trajectory still runs (one bin, no moves)."""
    workers = min(4, jax.device_count())
    eng, data, y = _skewed_lasso(workers)
    plan = ExecutionPlan(executor="scan", rounds=8, checkpoint_every=2,
                         partitioner=_LOADBAL)

    rep = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                      jax.random.key(1), plan, ckpt_dir=str(tmp_path))
    final_asgn = eng.partition_assignment
    final_ema = np.array(eng.partition_stats["ema"])
    if workers > 1:
        assert final_asgn.version > 0        # a rebalance actually fired
        ema = eng.partition_stats["ema"]
        assert final_asgn.spread(ema) \
            <= contiguous_assignment(32, workers).spread(ema)

    # resume from the mid checkpoint on a FRESH engine (fresh process
    # stand-in): state + carry + assignment all restored
    eng2, data2, _ = _skewed_lasso(workers)
    st2 = eng2.init_state(jax.random.key(0), y=y)
    eng2.set_partitioner(plan.partitioner)   # resolve before payload tmpl
    template = {"state": jax.tree.map(jnp.copy, st2), "carry": rep.carry,
                "assignment": eng.partition_payload()}
    back = restore_checkpoint(str(tmp_path), 4, template)
    assert int(back["carry"].t) == 4
    resumed = eng2.execute(back["state"], data2, jax.random.key(99), plan,
                           carry=back["carry"],
                           partition=back["assignment"],
                           ckpt_dir=str(tmp_path / "resumed"))
    for k in rep.state:
        assert (np.asarray(rep.state[k])
                == np.asarray(resumed.state[k])).all(), k
    assert eng2.partition_assignment == final_asgn
    assert np.array_equal(np.array(eng2.partition_stats["ema"]),
                          final_ema)


def test_fresh_execute_resets_partition_trajectory(tmp_path):
    """A fresh (carry-less) execute must start from the initial
    assignment — rebalances from a previous run of the same spec cannot
    leak in (runs would otherwise stop being reproducible)."""
    workers = min(4, jax.device_count())
    eng, data, y = _skewed_lasso(workers)
    plan = ExecutionPlan(executor="scan", rounds=8, checkpoint_every=2,
                         partitioner=_LOADBAL)
    eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                jax.random.key(1), plan, ckpt_dir=str(tmp_path / "a"))
    v1 = eng.partition_assignment.version
    rep2 = eng.execute(eng.init_state(jax.random.key(0), y=y), data,
                       jax.random.key(1), plan,
                       ckpt_dir=str(tmp_path / "b"))
    assert eng.partition_assignment.version == v1   # same trajectory
    eng3, data3, _ = _skewed_lasso(workers)
    rep3 = eng3.execute(eng3.init_state(jax.random.key(0), y=y), data3,
                        jax.random.key(1), plan,
                        ckpt_dir=str(tmp_path / "c"))
    for k in rep2.state:
        assert (np.asarray(rep2.state[k])
                == np.asarray(rep3.state[k])).all(), k


def test_restore_partition_rejects_mismatches(rng):
    eng, data, y = _lasso_setup(rng)
    eng.init_state(jax.random.key(0), y=y)
    # static default resolved; a load_balanced payload (with stats) must
    # not silently restore into it
    payload = {"owner": np.zeros((20,), np.int32),
               "num_workers": np.int32(1), "version": np.int32(1),
               "stats_ema": np.zeros((20,), np.float64)}
    with pytest.raises(ValueError, match="PartitionerSpec must match"):
        eng.restore_partition(payload)
    # wrong mesh width
    eng.set_partitioner(PartitionerSpec(kind="load_balanced", ema=0.5))
    bad = dict(payload, num_workers=np.int32(4),
               owner=np.zeros((20,), np.int32))
    with pytest.raises(ValueError, match="workers"):
        eng.restore_partition(bad)
    # wrong model size: a 12-variable assignment into a 20-variable app
    bad2 = dict(payload, owner=np.zeros((12,), np.int32),
                stats_ema=np.zeros((12,), np.float64))
    with pytest.raises(ValueError, match="different model size"):
        eng.restore_partition(bad2)


def test_repartition_keeps_kvstore_accounting_truthful(rng):
    """KVStore.repartition re-derives VarSpec.specs — Fig-3 byte
    accounting must follow a placement move immediately."""
    from jax.sharding import PartitionSpec as P
    eng, data, y = _lasso_setup(rng)
    state = eng.init_state(jax.random.key(0), y=y)
    kv = eng.kvstore
    before = kv.bytes_per_device()
    asgn = contiguous_assignment(20, 1)
    # move the replicated beta to a (1-way) sharded spec: per-device
    # bytes unchanged on 1 device, but the spec must be re-derived
    state2 = kv.repartition(asgn, state,
                            leaf_specs={"beta": P("data")})
    assert kv.specs["beta"].spec == P("data")
    assert kv.assignment is asgn
    assert kv.bytes_per_device() == before          # 1-way shard
    assert (np.asarray(state2["beta"])
            == np.asarray(state["beta"])).all()
    with pytest.raises(ValueError, match="unknown variable"):
        kv.repartition(asgn, state, leaf_specs={"nope": P()})
