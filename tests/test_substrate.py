"""Substrate tests: optimizer, schedules, data pipeline, checkpointing,
losses, serving loop — plus hypothesis property tests on their invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import SyntheticLMConfig, make_batch
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update, \
    cosine_schedule, wsd_schedule
from repro.train import greedy_generate
from repro.train.losses import cross_entropy, token_accuracy


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, 0.05, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.ones(3), atol=1e-2)


def test_adamw_bf16_moments_still_converge():
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=None,
                      moment_dtype="bfloat16")
    params = {"w": jnp.array([4.0])}
    opt = adamw_init(params, cfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, 0.05, cfg)
    assert abs(float(params["w"][0])) < 0.1


def test_adamw_clip_bounds_update():
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, gnorm = adamw_update(huge, opt, params, 1e-3, cfg)
    assert float(gnorm) == pytest.approx(2e6, rel=1e-3)  # pre-clip norm


@given(st.floats(1e-5, 1e-2), st.integers(1, 50), st.integers(60, 200))
@settings(max_examples=15, deadline=None)
def test_schedules_bounded_and_warm(peak, warmup, total):
    for sched in (cosine_schedule(peak, warmup, total),
                  wsd_schedule(peak, warmup, total // 2, total // 4)):
        for s in (0, warmup, total // 2, total, total * 2):
            v = float(sched(s))
            assert 0.0 <= v <= peak * (1 + 1e-6)
    assert float(cosine_schedule(peak, warmup, total)(warmup)) \
        == pytest.approx(peak, rel=1e-5)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

@given(st.integers(2, 40))
@settings(max_examples=10, deadline=None)
def test_cross_entropy_uniform_is_log_v(v):
    logits = jnp.zeros((2, 3, v + 8))        # 8 padded classes
    labels = jnp.zeros((2, 3), jnp.int32)
    loss, denom = cross_entropy(logits, labels, v)
    assert float(loss) == pytest.approx(np.log(v), abs=1e-4)
    assert float(denom) == 6.0


def test_cross_entropy_label_mask():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.array([[1.0, 1.0, 0.0, 0.0]])
    loss, denom = cross_entropy(logits, labels, 8, mask)
    assert float(denom) == 2.0


def test_token_accuracy_perfect():
    logits = jax.nn.one_hot(jnp.array([[1, 2], [3, 0]]), 8) * 10
    labels = jnp.array([[1, 2], [3, 0]])
    assert float(token_accuracy(logits, labels, 8)) == 1.0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_shaped():
    cfg = SyntheticLMConfig(vocab_size=128, seq_len=32, batch_size=4,
                            seed=7)
    b1, b2 = make_batch(cfg, 5), make_batch(cfg, 5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = make_batch(cfg, 6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert b1["tokens"].shape == (4, 32) and b1["labels"].shape == (4, 32)
    # labels are next-token-shifted tokens
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


@given(st.integers(8, 512))
@settings(max_examples=10, deadline=None)
def test_data_tokens_in_vocab(v):
    cfg = SyntheticLMConfig(vocab_size=v, seq_len=16, batch_size=2)
    b = make_batch(cfg, 0)
    assert int(b["tokens"].min()) >= 0
    assert int(b["tokens"].max()) < v


def test_data_frontends():
    cfg = SyntheticLMConfig(vocab_size=64, seq_len=16, batch_size=2)
    audio = make_batch(cfg, 0, d_model=32, frames=True)
    assert audio["frames"].shape == (2, 16, 32) and "tokens" not in audio
    vlm = make_batch(cfg, 0, d_model=32, frontend_tokens=8)
    assert vlm["frontend"].shape == (2, 8, 32) and "tokens" in vlm


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("xlstm-125m").reduced()
    prm = M.init_params(cfg, jax.random.PRNGKey(0))
    ckpt = str(tmp_path / "ck")
    save_checkpoint(ckpt, 3, {"params": prm})
    save_checkpoint(ckpt, 7, {"params": prm})
    assert latest_step(ckpt) == 7
    template = {"params": M.init_params(cfg, jax.random.PRNGKey(1))}
    restored = restore_checkpoint(ckpt, 7, template)
    for a, b in zip(jax.tree_util.tree_leaves(restored["params"]),
                    jax.tree_util.tree_leaves(prm)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt = str(tmp_path / "ck")
    save_checkpoint(ckpt, 1, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        restore_checkpoint(ckpt, 1, {"w": jnp.zeros((4,))})


# ---------------------------------------------------------------------------
# serving loop
# ---------------------------------------------------------------------------

def test_greedy_generate_deterministic():
    cfg = get_config("granite-3-2b").reduced()
    prm = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    out1 = greedy_generate(cfg, prm, {"tokens": toks}, steps=6,
                           cache_len=32)
    out2 = greedy_generate(cfg, prm, {"tokens": toks}, steps=6,
                           cache_len=32)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.vocab_size
