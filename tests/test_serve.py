"""The online serving subsystem (repro/serve).

Contract under test (ISSUE 8 acceptance):
  * ``ServeSpec`` — the house spec rules: kind validation, per-kind
    unused-field rejection, exact JSON round-trip, ``default_for``.
  * the staleness guarantee: every ``ModelView`` read under
    ``kind="stale"`` observes state ≤ ``max_staleness`` rounds old,
    asserted over the *measured* staleness-at-read for random
    (training staleness, serving bound, request interleaving)
    configurations (hypothesis property; deterministic stub fallback).
  * bit-exactness: serving reads never perturb training —
    ``serve_while_training`` final state ≡ plain ``execute`` of the
    same plan, leaf by leaf.
  * the query primitives: lasso ``predict``, MF ``recommend`` top-k,
    LDA ``infer_topics`` fold-in, checked against numpy oracles.
  * the micro-batching frontend: ``max_batch`` assembly, the
    ``batch_window_ms`` partial-batch wait, forced drains.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.apps import lasso, lda, mf
from repro.core import ExecutionPlan, StradsAppBase, single_device_mesh
from repro.obs import Recorder
from repro.serve import (ModelView, ServeFrontend, ServeSpec,
                         StaleReadError, serve_only,
                         serve_while_training)


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


def _bit_identical(a_state, b_state):
    assert set(a_state) == set(b_state)
    for k in a_state:
        a, b = np.asarray(a_state[k]), np.asarray(b_state[k])
        assert (a == b).all(), (k, np.max(np.abs(a - b)))


def _lasso_setup(mesh, seed=0, n=48, J=24):
    r = np.random.default_rng(seed)
    X, y, _ = lasso.synthetic_correlated(r, n=n, J=J, k_true=4)
    cfg = lasso.LassoConfig(num_features=J, lam=0.05, block_size=4,
                            num_candidates=8, rho=0.5)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    return eng, data, X, y


# ---------------------------------------------------------------------------
# ServeSpec: the house spec rules
# ---------------------------------------------------------------------------

def test_spec_rejects_bad_kind():
    with pytest.raises(ValueError, match="serve kind"):
        ServeSpec(kind="fresh")
    with pytest.raises(ValueError, match="serve kind"):
        ServeSpec.default_for("fresh")


def test_spec_rejects_unused_fields_per_kind():
    # max_staleness is a stale-only knob
    with pytest.raises(ValueError, match="does not apply"):
        ServeSpec(kind="snapshot", max_staleness=2)
    # both kinds consume the batching knobs
    ServeSpec(kind="snapshot", max_batch=4, batch_window_ms=1.0)
    ServeSpec(kind="stale", max_staleness=3, max_batch=4,
              batch_window_ms=1.0)


def test_spec_validates_field_types():
    with pytest.raises(ValueError, match="max_staleness"):
        ServeSpec(kind="stale", max_staleness=-1)
    with pytest.raises(ValueError, match="max_staleness"):
        ServeSpec(kind="stale", max_staleness=True)
    with pytest.raises(ValueError, match="max_batch"):
        ServeSpec(kind="stale", max_batch=0)
    with pytest.raises(ValueError, match="batch_window_ms"):
        ServeSpec(kind="stale", batch_window_ms=-0.5)


def test_spec_json_roundtrip_exact():
    for s in (ServeSpec(kind="stale", max_staleness=3, max_batch=16,
                        batch_window_ms=2.5),
              ServeSpec(kind="snapshot", max_batch=4),
              ServeSpec.default_for("stale"),
              ServeSpec.default_for("snapshot")):
        assert ServeSpec.from_json(s.to_json()) == s
        import json
        assert ServeSpec.from_json(json.dumps(s.to_json())) == s


def test_spec_from_json_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown ServeSpec field"):
        ServeSpec.from_json({"kind": "stale", "staleness": 2})


def test_spec_default_for_overrides():
    s = ServeSpec.default_for("stale", max_staleness=7)
    assert s.max_staleness == 7 and s.max_batch == 8


# ---------------------------------------------------------------------------
# the query primitives, against numpy oracles
# ---------------------------------------------------------------------------

def test_lasso_query_predict(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="scan", rounds=8)
    state = eng.execute(state, data, jax.random.key(1), plan).state
    batch = {"x": jnp.asarray(X[:5])}
    out = eng.app.query(state, batch)
    np.testing.assert_allclose(np.asarray(out["y_hat"]),
                               X[:5] @ np.asarray(state["beta"]),
                               rtol=1e-5, atol=1e-6)


def test_mf_query_recommend_topk(mesh):
    r = np.random.default_rng(3)
    A, mask = mf.synthetic_ratings(r, 12, 10, true_rank=2)
    cfg = mf.MFConfig(num_rows=12, num_cols=10, rank=3, top_k=4)
    eng = mf.make_engine(cfg, mesh)
    state = eng.init_state(jax.random.key(0), A=jnp.asarray(A),
                           mask=jnp.asarray(mask))
    out = eng.app.query(state, {"user": jnp.asarray([0, 5], jnp.int32)})
    assert out["items"].shape == (2, 4)
    scores = np.asarray(state["W"]) @ np.asarray(state["H"])
    for b, u in enumerate((0, 5)):
        want = np.argsort(-scores[u])[:4]
        np.testing.assert_array_equal(np.asarray(out["items"][b]), want)
        np.testing.assert_allclose(np.asarray(out["scores"][b]),
                                   scores[u][want], rtol=1e-5)


def test_lda_query_infer_topics(mesh):
    cfg = lda.LDAConfig(vocab=20, num_topics=4, num_workers=1,
                        tokens_per_worker=120, docs_per_worker=5)
    r = np.random.default_rng(7)
    words, docs, z0 = lda.synthetic_corpus(r, cfg, true_topics=4)
    eng = lda.make_engine(cfg, mesh)
    state = eng.init_state(jax.random.key(0), words=words, docs=docs,
                           z0=z0)
    plan = ExecutionPlan(executor="scan", rounds=4)
    data = eng.shard_data({"words": jnp.asarray(words),
                           "docs": jnp.asarray(docs)})
    state = eng.execute(state, data, jax.random.key(1), plan).state
    # -1 padding must be inert: padded and unpadded docs infer the same θ
    doc = np.array([[1, 2, 3, 4, -1, -1]], np.int32)
    out = eng.app.query(state, {"words": jnp.asarray(doc)})
    out2 = eng.app.query(state, {"words": jnp.asarray(doc[:, :4])})
    assert out["theta"].shape == (1, cfg.num_topics)
    np.testing.assert_allclose(np.asarray(out["theta"]).sum(-1), 1.0,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["theta"]),
                               np.asarray(out2["theta"]), rtol=1e-5)


def test_query_default_raises():
    class NoQuery(StradsAppBase):
        pass
    with pytest.raises(NotImplementedError, match="query"):
        NoQuery().query({}, {})


# ---------------------------------------------------------------------------
# ModelView: publish/read semantics
# ---------------------------------------------------------------------------

def test_view_read_before_publish_raises(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    for kind in ("stale", "snapshot"):
        view = ModelView(eng, ServeSpec.default_for(kind))
        with pytest.raises(StaleReadError, match="publish"):
            view.read()


def test_view_stale_gate_refreshes_lazily(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    view = ModelView(eng, ServeSpec(kind="stale", max_staleness=2,
                                    max_batch=1))
    view.publish(state, 0)
    _, s0 = view.read()
    assert s0 == 0
    # clock advances within the bound: the cache is NOT refreshed
    view.publish(state, 2)
    _, s1 = view.read()
    assert s1 == 2
    # beyond the bound: publish refreshes, reads are fresh again
    view.publish(state, 3)
    _, s2 = view.read()
    assert s2 == 0
    assert [r["staleness"] for r in view.reads] == [0, 2, 0]


def test_view_snapshot_pins_at_publish(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    view = ModelView(eng, ServeSpec.default_for("snapshot"))
    view.publish(state, 4)
    pinned, s = view.read()
    assert s == 0
    # the pin is a copy: mutating nothing, but the view must survive the
    # original buffers being donated — same arrays by value, not identity
    _bit_identical(pinned, state)
    assert pinned["beta"] is not state["beta"]


def test_view_stale_serves_mixed_ssp_view(mesh):
    # server-resident leaf (beta) comes from the stale cache; the
    # worker-resident leaf (r) reads live at the boundary — exactly the
    # SSP read semantics (read-my-writes local, ≤s-stale shared)
    eng, data, X, y = _lasso_setup(mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    view = ModelView(eng, ServeSpec(kind="stale", max_staleness=4,
                                    max_batch=1))
    view.publish(state, 0)
    newer = dict(state, beta=state["beta"] + 1.0, r=state["r"] * 2.0)
    view.publish(newer, 3)
    v, s = view.read()
    assert s == 3
    np.testing.assert_array_equal(np.asarray(v["beta"]),
                                  np.asarray(state["beta"]))   # stale
    np.testing.assert_array_equal(np.asarray(v["r"]),
                                  np.asarray(newer["r"]))      # live


# ---------------------------------------------------------------------------
# the micro-batching frontend
# ---------------------------------------------------------------------------

def _fake_clock():
    t = [0.0]

    def clock():
        return t[0]
    clock.advance = lambda dt: t.__setitem__(0, t[0] + dt)
    return clock


def test_frontend_batches_to_max_batch(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    spec = ServeSpec(kind="stale", max_staleness=0, max_batch=3)
    view = ModelView(eng, spec)
    fe = ServeFrontend(eng, view, spec)
    view.publish(state, 0)
    for i in range(7):
        fe.submit({"x": jnp.asarray(X[i])})
    # window 0: everything drains, in batches of ≤ 3 → 3 reads
    assert fe.flush() == 7
    assert fe.pending() == 0
    assert len(view.reads) == 3
    sizes = [len(np.asarray(r.result["y_hat"]).shape) for r in
             fe.responses]
    assert all(s == 0 for s in sizes)          # per-request scalar slices


def test_frontend_window_holds_partial_batches(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    spec = ServeSpec(kind="stale", max_staleness=0, max_batch=4,
                     batch_window_ms=10.0)
    view = ModelView(eng, spec)
    clock = _fake_clock()
    fe = ServeFrontend(eng, view, spec, clock=clock)
    view.publish(state, 0)
    fe.submit({"x": jnp.asarray(X[0])})
    fe.submit({"x": jnp.asarray(X[1])})
    assert fe.flush() == 0                     # partial, window open
    assert fe.pending() == 2
    clock.advance(0.011)                       # 11 ms > the 10 ms window
    assert fe.flush() == 2                     # window expired: served
    fe.submit({"x": jnp.asarray(X[2])})
    assert fe.flush(force=True) == 1           # forced drain ignores it
    assert [r.latency_ms for r in fe.responses][:2] == [11.0, 11.0]


def test_frontend_requires_matching_spec(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    view = ModelView(eng, ServeSpec.default_for("stale"))
    with pytest.raises(ValueError, match="share one ServeSpec"):
        ServeFrontend(eng, view, ServeSpec.default_for("snapshot"))


# ---------------------------------------------------------------------------
# serve_while_training: bit-exactness + the staleness guarantee
# ---------------------------------------------------------------------------

def test_serve_while_training_bit_exact(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    init = lambda: eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="ssp", rounds=12, staleness=2)
    reqs = [(t, {"x": jnp.asarray(X[i % len(X)])})
            for i, t in enumerate((0, 0, 3, 5, 6, 9, 11, 12, 12))]
    srep = serve_while_training(eng, init(), data, jax.random.key(1),
                                plan, requests=reqs)
    assert len(srep.responses) == len(reqs)
    ref = eng.execute(init(), data, jax.random.key(1), plan)
    _bit_identical(srep.report.state, ref.state)
    assert int(srep.report.carry.t) == plan.rounds


def test_serve_while_training_collect_matches_plain(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    init = lambda: eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="ssp", rounds=8, staleness=1,
                         collect_every=1)
    collect = eng.app.objective_collect()
    srep = serve_while_training(eng, init(), data, jax.random.key(1),
                                plan, collect=collect,
                                requests=[(4, {"x": jnp.asarray(X[0])})])
    ref = eng.execute(init(), data, jax.random.key(1), plan,
                      collect=collect)
    np.testing.assert_array_equal(np.asarray(srep.report.trace),
                                  np.asarray(ref.trace))


def test_serve_while_training_snapshot_kind(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    init = lambda: eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="ssp", rounds=6, staleness=1)
    srep = serve_while_training(
        eng, init(), data, jax.random.key(1), plan,
        spec=ServeSpec.default_for("snapshot"),
        requests=[(0, {"x": jnp.asarray(X[0])}),
                  (4, {"x": jnp.asarray(X[1])})])
    # snapshot pins at every boundary → reads always observe the pin
    assert srep.max_staleness_read() == 0
    ref = eng.execute(init(), data, jax.random.key(1), plan)
    _bit_identical(srep.report.state, ref.state)


def test_serve_while_training_records_trace_spans(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="ssp", rounds=6, staleness=2)
    rec = Recorder()
    serve_while_training(eng, state, data, jax.random.key(1), plan,
                         requests=[(3, {"x": jnp.asarray(X[0])})],
                         recorder=rec)
    names = [e["name"] for e in rec.to_json_events()]
    assert "train_chunk" in names
    assert "serve_batch" in names
    assert "serve_read" in names


def test_serve_while_training_rejects_bad_requests(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="ssp", rounds=6, staleness=1)
    with pytest.raises(TypeError, match="t_due"):
        serve_while_training(eng, state, data, jax.random.key(1), plan,
                             requests=[{"x": jnp.asarray(X[0])}])
    with pytest.raises(ValueError, match="due round"):
        serve_while_training(eng, state, data, jax.random.key(1), plan,
                             requests=[(99, {"x": jnp.asarray(X[0])})])


def test_serve_while_training_rejects_misaligned_chunk(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="ssp", rounds=12, staleness=2)  # L = 3
    with pytest.raises(ValueError, match="multiple"):
        serve_while_training(eng, state, data, jax.random.key(1), plan,
                             chunk_rounds=4)


def test_serve_only(mesh):
    eng, data, X, y = _lasso_setup(mesh)
    state = eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="scan", rounds=8)
    trained = eng.execute(state, data, jax.random.key(1), plan).state
    srep = serve_only(eng, trained,
                      requests=[{"x": jnp.asarray(X[i])}
                                for i in range(5)], t=8)
    assert srep.report is None
    assert len(srep.responses) == 5
    assert srep.max_staleness_read() == 0
    got = np.asarray(srep.responses[0].result["y_hat"])
    np.testing.assert_allclose(got, X[0] @ np.asarray(trained["beta"]),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2),
       st.integers(min_value=0, max_value=5),
       st.integers(min_value=1, max_value=3))
def test_read_staleness_never_exceeds_bound(train_s, bound, spread):
    """Every ModelView read under kind="stale" observes state at most
    max_staleness rounds old — over random (training staleness, serving
    bound, request interleaving) configurations, asserted on the
    measured staleness-at-read the view logged."""
    mesh = single_device_mesh()
    eng, data, X, y = _lasso_setup(mesh, seed=train_s * 11 + bound)
    state = eng.init_state(jax.random.key(0), y=y)
    R = 6 * (train_s + 1)                  # whole SSP windows
    plan = ExecutionPlan(executor="ssp", rounds=R, staleness=train_s)
    spec = ServeSpec(kind="stale", max_staleness=bound, max_batch=2)
    reqs = [((i * spread) % (R + 1), {"x": jnp.asarray(X[i % len(X)])})
            for i in range(10)]
    srep = serve_while_training(eng, state, data, jax.random.key(1),
                                plan, spec=spec, requests=reqs)
    assert len(srep.responses) == len(reqs)
    assert srep.reads, "no reads were served"
    for r in srep.reads:
        assert r["staleness"] <= bound, r
    assert srep.max_staleness_read() <= bound
    assert sum(srep.staleness_hist().values()) == len(srep.reads)


def test_serve_while_training_chunk_override(mesh):
    # a coarser publish cadence (2 windows per chunk) still holds the
    # bound and still trains bit-exactly
    eng, data, X, y = _lasso_setup(mesh)
    init = lambda: eng.init_state(jax.random.key(0), y=y)
    plan = ExecutionPlan(executor="ssp", rounds=12, staleness=1)  # L = 2
    srep = serve_while_training(
        eng, init(), data, jax.random.key(1), plan, chunk_rounds=4,
        spec=ServeSpec(kind="stale", max_staleness=4, max_batch=4),
        requests=[(t, {"x": jnp.asarray(X[t])}) for t in (0, 4, 8, 12)])
    assert srep.max_staleness_read() <= 4
    ref = eng.execute(init(), data, jax.random.key(1), plan)
    _bit_identical(srep.report.state, ref.state)


def test_serve_spec_on_plan_json_is_rejected():
    # serving is deliberately NOT an ExecutionPlan field: a plan decides
    # how to *train*; the ServeSpec rides the serve entry points.  A
    # plan file with a "serve" key must fail loudly, not silently drop.
    with pytest.raises(ValueError, match="unknown"):
        ExecutionPlan.from_json(
            {"executor": "ssp", "rounds": 6, "staleness": 1,
             "serve": {"kind": "stale"}})
