"""STRADS MF: exactness of the push/pull CD update (the paper's
"free from parallelization error" claim), convergence, ALS baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import mf
from repro.core import single_device_mesh


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


@pytest.fixture(scope="module")
def problem():
    r = np.random.default_rng(0)
    A, mask = mf.synthetic_ratings(r, 60, 40, true_rank=6, density=0.5)
    return A, mask


def test_h_update_matches_closed_form(mesh, problem):
    """One H-phase round must equal eq. (3) exactly — zero parallelization
    error (claim C4)."""
    A, mask = problem
    cfg = mf.MFConfig(num_rows=60, num_cols=40, rank=6, lam=0.05)
    eng = mf.make_engine(cfg, mesh)
    data = eng.shard_data({"A": jnp.asarray(A), "mask": jnp.asarray(mask)})
    st = eng.app.init_state(jax.random.key(1), A=jnp.asarray(A),
                            mask=jnp.asarray(mask))
    out = eng.run_round(st, data, jax.random.key(2), t=0)
    W, H, R = map(np.asarray, (st["W"], st["H"], st["R"]))
    k = 0
    num = np.einsum("i,ij->j", W[:, k], R * mask) \
        + np.einsum("ij,i->j", mask, W[:, k] ** 2) * H[k]
    den = 0.05 + np.einsum("ij,i->j", mask, W[:, k] ** 2)
    np.testing.assert_allclose(np.asarray(out.state["H"][k]), num / den,
                               rtol=2e-5, atol=2e-5)


def test_residual_consistency(mesh, problem):
    """After several rounds, R must still equal (A − WH)·mask — the
    automatic sync keeps the maintained residual truthful."""
    A, mask = problem
    cfg = mf.MFConfig(num_rows=60, num_cols=40, rank=6, lam=0.05)
    state, _ = mf.fit(cfg, A, mask, mesh, num_rounds=20)
    W, H, R = map(np.asarray, (state["W"], state["H"], state["R"]))
    np.testing.assert_allclose(R, (A - W @ H) * mask, atol=1e-3)


def test_objective_decreases(mesh, problem):
    A, mask = problem
    cfg = mf.MFConfig(num_rows=60, num_cols=40, rank=6, lam=0.05)
    _, trace = mf.fit(cfg, A, mask, mesh, num_rounds=60, trace_every=10)
    vals = [v for _, v in trace]
    assert vals[-1] < vals[0] * 0.2           # big drop
    for a, b in zip(vals, vals[1:]):
        assert b <= a + 1e-3                  # monotone (exact CD)


def test_recovers_low_rank_signal(mesh):
    """With rank ≥ true rank, the masked fit error approaches the noise
    floor."""
    r = np.random.default_rng(3)
    A, mask = mf.synthetic_ratings(r, 80, 50, true_rank=4, density=0.6,
                                   noise=0.01)
    cfg = mf.MFConfig(num_rows=80, num_cols=50, rank=8, lam=0.01)
    state, _ = mf.fit(cfg, A, mask, mesh, num_rounds=200)
    R = np.asarray(state["R"])
    rmse = np.sqrt((R ** 2).sum() / mask.sum())
    assert rmse < 0.1


def test_als_baseline_converges(problem):
    A, mask = problem
    (_, _), trace = mf.als_fit(jnp.asarray(A), jnp.asarray(mask), 6, 0.05,
                               8, jax.random.key(0))
    vals = [v for _, v in trace]
    assert vals[-1] < vals[0] * 0.2
    for a, b in zip(vals, vals[1:]):
        assert b <= a + 1e-3


def test_strads_handles_larger_rank_than_als_budget(mesh):
    """Proxy for the paper's model-size claim: CD cost scales linearly in
    rank while ALS scales cubically (K×K solves).  We check the CD path
    runs rank 64 on a small matrix with a *decreasing* objective."""
    r = np.random.default_rng(4)
    A, mask = mf.synthetic_ratings(r, 60, 40, true_rank=6, density=0.5)
    cfg = mf.MFConfig(num_rows=60, num_cols=40, rank=64, lam=0.1)
    _, trace = mf.fit(cfg, A, mask, mesh, num_rounds=128, trace_every=127)
    assert trace[-1][1] < trace[0][1]
