"""Unit + property tests for the STRADS core primitives."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (DynamicPriorityScheduler, RandomScheduler,
                        RotationScheduler, RoundRobinScheduler,
                        dependency_filter, priority_weights,
                        sample_candidates)
from repro.sched.block import (BlockScheduleConfig, block_norms,
                               init_priority, mask_updates_by_block,
                               select_blocks, update_priority)


# ---------------------------------------------------------------------------
# Static schedulers
# ---------------------------------------------------------------------------

def test_round_robin_covers_all_vars():
    s = RoundRobinScheduler(num_vars=10, block_size=3)
    seen = set()
    for t in range(10):
        seen.update(np.asarray(s(jnp.int32(t))).tolist())
    assert seen == set(range(10))


def test_round_robin_indices_in_range():
    s = RoundRobinScheduler(num_vars=7, block_size=4)
    for t in range(20):
        idx = np.asarray(s(jnp.int32(t)))
        assert ((0 <= idx) & (idx < 7)).all()


def test_random_scheduler_distinct():
    s = RandomScheduler(num_vars=50, block_size=10)
    idx = np.asarray(s(jax.random.key(0)))
    assert len(set(idx.tolist())) == 10


def test_rotation_blocks_disjoint_and_complete():
    """At any round t, the blocks processed by the U workers partition the
    variable space — the LDA conditional-independence requirement."""
    s = RotationScheduler(num_vars=103, num_workers=4)
    b = np.asarray(s.bounds)
    assert b[0] == 0 and b[-1] == 103
    for t in range(4):
        masks = [np.asarray(s.block_mask(s.block_for_worker(p, t)))
                 for p in range(4)]
        total = np.stack(masks).sum(axis=0)
        assert (total == 1).all()       # disjoint cover


def test_rotation_every_worker_touches_every_block():
    s = RotationScheduler(num_vars=16, num_workers=4)
    for p in range(4):
        blocks = {int(s.block_for_worker(p, t)) for t in range(4)}
        assert blocks == {0, 1, 2, 3}


# ---------------------------------------------------------------------------
# Dynamic priority scheduling
# ---------------------------------------------------------------------------

def test_priority_weights_floor():
    w = priority_weights(jnp.zeros(5), eta=0.1)
    assert np.allclose(np.asarray(w), 0.1)


def test_sample_candidates_distinct_and_biased():
    weights = jnp.asarray([100.0, 100.0, 100.0, 0.001, 0.001])
    counts = np.zeros(5)
    for i in range(200):
        idx = np.asarray(sample_candidates(jax.random.key(i), weights, 2))
        assert len(set(idx.tolist())) == 2
        counts[idx] += 1
    # high-weight vars picked far more often
    assert counts[:3].min() > counts[3:].max()


def test_dependency_filter_blocks_correlated():
    # candidates 0 and 1 perfectly correlated: only one survives
    gram = jnp.asarray([[1.0, 0.99, 0.0],
                        [0.99, 1.0, 0.0],
                        [0.0, 0.0, 1.0]])
    keep = np.asarray(dependency_filter(gram, rho=0.5, max_select=3))
    assert keep[0] and not keep[1] and keep[2]


def test_dependency_filter_respects_max_select():
    gram = jnp.eye(8)
    keep = np.asarray(dependency_filter(gram, rho=0.5, max_select=3))
    assert keep.sum() == 3


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.floats(0.05, 0.95), st.integers(1, 8),
       st.integers(0, 2**31 - 1))
def test_dependency_filter_invariant(u, rho, max_sel, seed):
    """Property: every admitted pair has correlation < ρ, and the kept set
    is maximal-greedy (first candidate always admitted)."""
    r = np.random.default_rng(seed)
    A = r.normal(size=(20, u)).astype(np.float32)
    A /= np.linalg.norm(A, axis=0, keepdims=True)
    gram = jnp.asarray(A.T @ A)
    keep = np.asarray(dependency_filter(gram, rho=rho, max_select=max_sel))
    assert keep.sum() <= max_sel
    assert keep[0]                       # greedy always admits the first
    kept = np.where(keep)[0]
    g = np.abs(np.asarray(gram))
    for a in kept:
        for b in kept:
            if a < b:
                assert g[a, b] < rho


def test_finalize_returns_static_shapes():
    dyn = DynamicPriorityScheduler(num_vars=100, num_candidates=16,
                                   block_size=4, rho=0.5)
    cand = dyn.propose(jnp.ones(100), jax.random.key(0))
    gram = jnp.eye(16)
    idx, mask = dyn.finalize(cand, gram)
    assert idx.shape == (4,) and mask.shape == (4,)
    assert mask.sum() <= 4


# ---------------------------------------------------------------------------
# Block scheduler (beyond-paper feature)
# ---------------------------------------------------------------------------

def test_select_blocks_distance_filter():
    cfg = BlockScheduleConfig(num_blocks=10, blocks_per_step=5,
                              candidates_per_step=10, min_distance=2)
    mask = np.asarray(select_blocks(cfg, init_priority(cfg),
                                    jax.random.key(0)))
    sel = np.where(mask > 0)[0]
    assert len(sel) >= 1
    assert len(sel) <= 5
    for a in sel:
        for b in sel:
            if a != b:
                assert abs(a - b) >= 2


def test_update_priority_only_touches_scheduled():
    cfg = BlockScheduleConfig(num_blocks=4, blocks_per_step=2,
                              candidates_per_step=4)
    pri = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    norms = jnp.asarray([10.0, 10.0, 10.0, 10.0])
    sched = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    new = np.asarray(update_priority(cfg, pri, norms, sched))
    assert new[1] == 2.0 and new[3] == 4.0     # unscheduled: unchanged
    assert new[0] > 1.0 and new[2] > 3.0       # scheduled: EMA toward norm


def test_mask_updates_by_block():
    updates = {"layer0": jnp.ones(3), "layer1": jnp.ones(3),
               "embed": jnp.ones(3)}
    block_of = {"layer0": 0, "layer1": 1}
    mask = jnp.asarray([0.0, 1.0])
    out = mask_updates_by_block(updates, block_of, mask)
    assert np.allclose(np.asarray(out["layer0"]), 0)
    assert np.allclose(np.asarray(out["layer1"]), 1)
    assert np.allclose(np.asarray(out["embed"]), 1)   # unmapped: untouched


def test_block_norms():
    updates = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 2.0)}
    block_of = {"a": 0, "b": 1}
    n = np.asarray(block_norms(updates, block_of, 2))
    assert np.isclose(n[0], 6.0) and np.isclose(n[1], 6.0)
