"""The streaming data-ingest subsystem (repro/stream).

Contract under test (ISSUE 9 acceptance):
  * ``StreamSpec`` — the house spec rules: kind validation, per-kind
    unused-field rejection, exact JSON round-trip, ``default_for``.
  * empty-source bit-exactness: ``execute(..., stream=spec,
    source=EmptySource())`` ≡ the unstreamed ``execute()`` leaf by leaf,
    on all four executors × all three apps.
  * the extend ring: appends land in padding slots first (the
    ``ingest_specs()["valid"]`` fill), then wrap around and overwrite
    the oldest rows; a delta larger than the ring keeps only its tail
    and counts the rest dropped; padding rows are exactly inert until a
    delta lands (a capacity-padded run matches the unpadded one).
  * batching invariance: trajectories depend only on the
    (data, delta-schedule) pair — splitting one delta into several at
    the same boundary changes nothing (hypothesis property).
  * the serve loop: ``serve_while_training(..., stream=, source=)``
    trains bit-identically to the engine-streamed run and reports the
    cursor payload.
  * ``SyntheticLMSource`` and ``repro.data.synthetic_batches`` share
    one batch-derivation path.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.apps import lasso, lda, mf
from repro.core import ExecutionPlan, StradsAppBase, single_device_mesh
from repro.data.pipeline import SyntheticLMConfig, make_batch
from repro.obs import TelemetrySpec
from repro.serve import serve_while_training
from repro.stream import (EmptySource, Ingestor, LassoDriftSource,
                          LDADriftSource, MFDriftSource, ScheduledSource,
                          StreamSpec, SyntheticLMSource, replay_data)

EXECUTORS = ("loop", "scan", "pipelined", "ssp")


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


def _bit_identical(a_state, b_state):
    assert set(a_state) == set(b_state)
    for k in a_state:
        a, b = np.asarray(a_state[k]), np.asarray(b_state[k])
        assert (a == b).all(), (k, np.max(np.abs(a - b)))


def _plan(executor, rounds, **kw):
    if executor == "ssp":
        kw.setdefault("staleness", 1)
    return ExecutionPlan(executor=executor, rounds=rounds, **kw)


def _lasso_setup(mesh, seed=0, n=48, J=24):
    r = np.random.default_rng(seed)
    X, y, _ = lasso.synthetic_correlated(r, n=n, J=J, k_true=4)
    cfg = lasso.LassoConfig(num_features=J, lam=0.05, block_size=4,
                            num_candidates=8, rho=0.5)
    eng = lasso.make_engine(cfg, mesh)
    data = eng.shard_data({"X": jnp.asarray(X), "y": jnp.asarray(y)})
    init = lambda: eng.init_state(jax.random.key(0), y=y)
    return eng, data, init, (X, y)


def _lda_setup(mesh, seed=0):
    cfg = lda.LDAConfig(vocab=20, num_topics=4, num_workers=1,
                        tokens_per_worker=24, docs_per_worker=4)
    r = np.random.default_rng(seed)
    words, docs, z0 = lda.synthetic_corpus(r, cfg, true_topics=4)
    eng = lda.make_engine(cfg, mesh)
    data = eng.shard_data({"words": jnp.asarray(words),
                           "docs": jnp.asarray(docs)})
    init = lambda: eng.init_state(jax.random.key(0), words=words,
                                  docs=docs, z0=z0)
    return eng, data, init, cfg


def _mf_setup(mesh, seed=0, N=12, M=10):
    r = np.random.default_rng(seed)
    A, mask = mf.synthetic_ratings(r, N, M, true_rank=2)
    cfg = mf.MFConfig(num_rows=N, num_cols=M, rank=3)
    eng = mf.make_engine(cfg, mesh)
    data = eng.shard_data({"A": jnp.asarray(A), "mask": jnp.asarray(mask)})
    init = lambda: eng.init_state(jax.random.key(0), A=jnp.asarray(A),
                                  mask=jnp.asarray(mask))
    return eng, data, init, (A, mask)


# ---------------------------------------------------------------------------
# StreamSpec: the house spec rules
# ---------------------------------------------------------------------------

def test_spec_rejects_bad_kind():
    with pytest.raises(ValueError, match="stream kind"):
        StreamSpec(kind="append")
    with pytest.raises(ValueError, match="stream kind"):
        StreamSpec.default_for("append")


def test_spec_rejects_unused_fields_per_kind():
    # capacity is an extend-only knob
    with pytest.raises(ValueError, match="does not apply"):
        StreamSpec(kind="replace", capacity=16)
    StreamSpec(kind="extend", ingest_every=2, capacity=16)
    StreamSpec(kind="replace", ingest_every=2)


def test_spec_validates_field_types():
    with pytest.raises(ValueError, match="ingest_every"):
        StreamSpec(kind="replace", ingest_every=0)
    with pytest.raises(ValueError, match="ingest_every"):
        StreamSpec(kind="replace", ingest_every=True)
    with pytest.raises(ValueError, match="capacity"):
        StreamSpec(kind="extend", capacity=-1)
    with pytest.raises(ValueError, match="capacity"):
        StreamSpec(kind="extend", capacity=True)


def test_spec_json_roundtrip_exact():
    for s in (StreamSpec(kind="replace", ingest_every=4),
              StreamSpec(kind="extend", ingest_every=2, capacity=64),
              StreamSpec.default_for("replace"),
              StreamSpec.default_for("extend")):
        assert StreamSpec.from_json(s.to_json()) == s
        assert StreamSpec.from_json(json.dumps(s.to_json())) == s


def test_spec_from_json_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown StreamSpec field"):
        StreamSpec.from_json({"kind": "extend", "ring": 8})
    with pytest.raises(TypeError, match="dict or JSON"):
        StreamSpec.from_json(["extend"])


def test_spec_default_for_overrides():
    s = StreamSpec.default_for("extend", capacity=32)
    assert s.capacity == 32 and s.ingest_every == 1


def test_stream_spec_on_plan_json_is_rejected():
    # streaming is deliberately NOT an ExecutionPlan field: a plan
    # decides how to *train*; the StreamSpec rides the entry points
    # (execute/serve_while_training/CLIs) beside its DataSource.
    with pytest.raises(ValueError, match="unknown"):
        ExecutionPlan.from_json(
            {"executor": "ssp", "rounds": 6, "staleness": 1,
             "stream": {"kind": "extend"}})


# ---------------------------------------------------------------------------
# empty-source bit-exactness on every executor × every app
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTORS)
@pytest.mark.parametrize("app", ("lasso", "lda", "mf"))
def test_empty_source_bit_identical_to_unstreamed(executor, app, mesh):
    if app == "lasso":
        eng, data, init, _ = _lasso_setup(mesh)
        spec = StreamSpec(kind="replace", ingest_every=4)
    elif app == "lda":
        eng, data, init, _ = _lda_setup(mesh)
        spec = StreamSpec(kind="extend", ingest_every=4)
    else:
        eng, data, init, _ = _mf_setup(mesh)
        spec = StreamSpec(kind="extend", ingest_every=4)
    plan = _plan(executor, 8)
    ref = eng.execute(init(), data, jax.random.key(1), plan)
    rep = eng.execute(init(), data, jax.random.key(1), plan,
                      stream=spec, source=EmptySource())
    _bit_identical(ref.state, rep.state)
    assert rep.stream is not None
    assert int(rep.stream["rows_in"]) == 0


def test_drift_source_changes_the_trajectory(mesh):
    # guard against a silently-ignored source: real deltas must move
    # the trained state
    eng, data, init, _ = _lasso_setup(mesh)
    spec = StreamSpec(kind="replace", ingest_every=2)
    plan = _plan("scan", 8)
    ref = eng.execute(init(), data, jax.random.key(1), plan)
    rep = eng.execute(init(), data, jax.random.key(1), plan, stream=spec,
                      source=LassoDriftSource(num_rows=48,
                                              num_features=24,
                                              rows_per_ingest=8, seed=3))
    assert int(rep.stream["rows_in"]) == 8 * 3      # t = 2, 4, 6
    assert not (np.asarray(rep.state["beta"])
                == np.asarray(ref.state["beta"])).all()


# ---------------------------------------------------------------------------
# the extend ring: fill, wraparound, oversize deltas, inert padding
# ---------------------------------------------------------------------------

def _row_delta(vals, M):
    """An MF delta whose A rows are the constants ``vals``."""
    k = len(vals)
    return {"data": {
        "A": np.tile(np.asarray(vals, np.float32)[:, None], (1, M)),
        "mask": np.ones((k, M), np.float32)}}


def test_extend_ring_fills_padding_then_wraps(mesh):
    N, M, FILL = 8, 6, 5
    r = np.random.default_rng(0)
    A = np.concatenate([r.normal(size=(FILL, M)).astype(np.float32),
                        np.zeros((N - FILL, M), np.float32)])
    mask = np.concatenate([np.ones((FILL, M), np.float32),
                           np.zeros((N - FILL, M), np.float32)])
    eng = mf.make_engine(mf.MFConfig(num_rows=N, num_cols=M, rank=2),
                         mesh)
    data = eng.shard_data({"A": jnp.asarray(A), "mask": jnp.asarray(mask)})
    src = ScheduledSource({0: _row_delta([100, 101], M),
                           1: _row_delta([102, 103, 104], M),
                           2: _row_delta(list(range(200, 210)), M)})
    ing = Ingestor(StreamSpec(kind="extend", ingest_every=1),
                   src).bind(eng, data)
    assert ing.capacity == N and ing.fill0 == FILL

    # boundary 0: two rows land in the padding slots 5, 6
    _, data = ing.step(eng, None, data, 0)
    np.testing.assert_array_equal(np.asarray(data["A"])[5], 100.0)
    np.testing.assert_array_equal(np.asarray(data["A"])[6], 101.0)
    assert (ing.cursor, ing.rows_in, ing.rows_dropped) == (2, 2, 0)

    # boundary 1: slot 7, then wrap to the oldest rows 0, 1
    _, data = ing.step(eng, None, data, 1)
    got = np.asarray(data["A"])[:, 0]
    np.testing.assert_array_equal(got[[7, 0, 1]], [102, 103, 104])
    assert (ing.cursor, ing.rows_in, ing.rows_dropped) == (5, 5, 0)

    # boundary 2: a delta larger than the whole ring keeps only its last
    # 8 rows (the earlier 2 would be overwritten before any round saw
    # them) — slot of sliced row i is (fill0 + cursor + dropped + i) % N
    _, data = ing.step(eng, None, data, 2)
    got = np.asarray(data["A"])[:, 0]
    for i in range(8):
        assert got[(FILL + 5 + 2 + i) % N] == 202 + i
    assert (ing.cursor, ing.rows_in, ing.rows_dropped) == (15, 13, 2)

    # the cursor payload round-trips; restore skips the valid() recount
    payload = ing.payload()
    assert sorted(payload) == ["cursor", "fill0", "rows_dropped",
                               "rows_in"]
    ing2 = Ingestor(StreamSpec(kind="extend", ingest_every=1),
                    EmptySource()).restore(payload).bind(eng, data)
    assert (ing2.cursor, ing2.fill0) == (15, FILL)


def test_extend_padding_rows_are_inert_until_a_delta_lands(mesh):
    """A capacity-padded MF problem (zero-mask rows absorbing future
    appends) must train exactly like the unpadded one: padded rows
    contribute nothing and their factors stay at zero."""
    N0, M = 4, 6
    r = np.random.default_rng(1)
    A, mask = mf.synthetic_ratings(r, N0, M, true_rank=2)
    small = mf.make_engine(mf.MFConfig(num_rows=N0, num_cols=M, rank=2),
                           mesh)
    sdata = small.shard_data({"A": jnp.asarray(A),
                              "mask": jnp.asarray(mask)})
    sstate = small.init_state(jax.random.key(0), A=jnp.asarray(A),
                              mask=jnp.asarray(mask))
    # snapshot before execute: plan.donate would delete these buffers
    s0 = {k: np.array(np.asarray(v)) for k, v in sstate.items()}
    plan = ExecutionPlan(executor="scan", rounds=4)
    sfin = small.execute(sstate, sdata, jax.random.key(1), plan).state

    pad = np.zeros((4, M), np.float32)
    big = mf.make_engine(mf.MFConfig(num_rows=N0 + 4, num_cols=M,
                                     rank=2), mesh)
    bdata = big.shard_data({
        "A": jnp.asarray(np.concatenate([A, pad])),
        "mask": jnp.asarray(np.concatenate([mask, pad]))})
    zW = np.zeros((4, 2), np.float32)
    bstate = {"W": jnp.asarray(np.concatenate([s0["W"], zW])),
              "H": jnp.asarray(s0["H"]),
              "R": jnp.asarray(np.concatenate([s0["R"], pad]))}
    bfin = big.execute(bstate, bdata, jax.random.key(1), plan).state
    np.testing.assert_allclose(np.asarray(bfin["H"]),
                               np.asarray(sfin["H"]), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(bfin["W"])[:N0],
                               np.asarray(sfin["W"]), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(bfin["W"])[N0:], 0.0)


def test_extend_streamed_execute_end_to_end(mesh):
    # the full path: execute() with an extend drift source on the
    # capacity ring — shapes never change, cursor lands on the report
    eng, data, init, _ = _mf_setup(mesh)
    spec = StreamSpec(kind="extend", ingest_every=2)
    rep = eng.execute(init(), data, jax.random.key(1), _plan("scan", 8),
                      stream=spec,
                      source=MFDriftSource(num_rows=12, num_cols=10,
                                           rows_per_ingest=3, seed=5))
    assert np.asarray(rep.state["W"]).shape == (12, 3)
    assert int(rep.stream["rows_in"]) == 3 * 3      # t = 2, 4, 6
    assert int(rep.stream["rows_dropped"]) == 0


def test_lda_ingest_keeps_collapsed_counts_exact(mesh):
    """After streamed ingest, the collapsed counts D/B/s must equal the
    counts materialized from scratch off (words, docs, z) — the exact
    invariant build_state establishes."""
    eng, data, init, cfg = _lda_setup(mesh)
    spec = StreamSpec(kind="extend", ingest_every=2)
    rep = eng.execute(init(), data, jax.random.key(1), _plan("scan", 4),
                      stream=spec,
                      source=LDADriftSource(num_tokens=24, vocab=20,
                                            num_topics=4,
                                            docs_per_worker=4,
                                            tokens_per_ingest=6, seed=7))
    assert int(rep.stream["rows_in"]) == 6          # t = 2 only
    st = rep.state
    z = np.asarray(st["z"])
    B = np.zeros_like(np.asarray(st["B"]))
    D = np.zeros_like(np.asarray(st["D"]))
    s = np.zeros_like(np.asarray(st["s"]))
    # data leaves were streamed — recount from the report's trajectory
    # inputs is impossible here, so recount from the final (words, z)
    # pair the engine actually holds: replay the data side
    data2, _ = replay_data(eng, data, spec,
                           LDADriftSource(num_tokens=24, vocab=20,
                                          num_topics=4, docs_per_worker=4,
                                          tokens_per_ingest=6, seed=7), 4)
    words = np.asarray(data2["words"])
    docs = np.asarray(data2["docs"])
    act = words >= 0
    np.add.at(B, (words[act], z[act]), 1)
    np.add.at(D, (docs[act], z[act]), 1)     # num_workers=1: global=local
    np.add.at(s, z[act], 1)
    np.testing.assert_array_equal(np.asarray(st["B"]), B)
    np.testing.assert_array_equal(np.asarray(st["D"]), D)
    np.testing.assert_array_equal(np.asarray(st["s"]), s)


# ---------------------------------------------------------------------------
# batching invariance: the trajectory sees the delta schedule, not how
# deltas were split
# ---------------------------------------------------------------------------

def _allclose_state(a_state, b_state, atol=1e-5):
    # the invariance is semantic, not bitwise: a split delta runs the
    # derived-state catch-up as two smaller matmuls, and XLA may block
    # a (6,J)@(J,) dot differently from two (3,J)@(J,) dots — same
    # math, last-ulp rounding differences
    assert set(a_state) == set(b_state)
    for k in a_state:
        np.testing.assert_allclose(np.asarray(a_state[k]),
                                   np.asarray(b_state[k]), rtol=1e-5,
                                   atol=atol, err_msg=k)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=99))
def test_trajectory_invariant_to_delta_batching(split, seed):
    mesh = single_device_mesh()
    eng, data, init, _ = _lasso_setup(mesh, seed=seed)
    r = np.random.default_rng(seed + 1)
    rows = np.sort(r.choice(48, size=6, replace=False))
    Xd = r.normal(size=(6, 24)).astype(np.float32)
    yd = r.normal(size=6).astype(np.float32)
    whole = {"rows": rows, "data": {"X": Xd, "y": yd}}
    parts = [{"rows": rows[:split],
              "data": {"X": Xd[:split], "y": yd[:split]}},
             {"rows": rows[split:],
              "data": {"X": Xd[split:], "y": yd[split:]}}]
    spec = StreamSpec(kind="replace", ingest_every=2)
    plan = ExecutionPlan(executor="scan", rounds=4)
    run = lambda src: eng.execute(init(), data, jax.random.key(1), plan,
                                  stream=spec, source=src)
    a = run(ScheduledSource({2: whole}))
    b = run(ScheduledSource({2: parts}))
    _allclose_state(a.state, b.state)
    assert all(int(a.stream[k]) == int(b.stream[k]) for k in a.stream)


def test_extend_split_delta_matches_whole_delta(mesh):
    # the ring cursor advances by the full delta size either way, so an
    # extend delta split in two lands on the same slots
    eng, data, init, _ = _mf_setup(mesh)
    d = _row_delta([300, 301, 302, 303], 10)
    halves = [{"data": {k: v[:2] for k, v in d["data"].items()}},
              {"data": {k: v[2:] for k, v in d["data"].items()}}]
    spec = StreamSpec(kind="extend", ingest_every=2)
    plan = ExecutionPlan(executor="scan", rounds=4)
    a = eng.execute(init(), data, jax.random.key(1), plan, stream=spec,
                    source=ScheduledSource({2: d}))
    b = eng.execute(init(), data, jax.random.key(1), plan, stream=spec,
                    source=ScheduledSource({2: halves}))
    _allclose_state(a.state, b.state)


# ---------------------------------------------------------------------------
# the serve loop streams at the same boundaries
# ---------------------------------------------------------------------------

def test_serve_while_training_streamed_matches_engine(mesh):
    eng, data, init, (X, y) = _lasso_setup(mesh)
    plan = _plan("ssp", 8)
    spec = StreamSpec(kind="replace", ingest_every=2)
    src = lambda: LassoDriftSource(num_rows=48, num_features=24,
                                   rows_per_ingest=4, seed=9)
    srep = serve_while_training(
        eng, init(), data, jax.random.key(1), plan, stream=spec,
        source=src(),
        requests=[(t, {"x": jnp.asarray(X[t])}) for t in (0, 4, 8)])
    ref = eng.execute(init(), data, jax.random.key(1), plan,
                      stream=spec, source=src())
    _bit_identical(srep.report.state, ref.state)
    assert srep.ingest is not None
    assert int(srep.ingest["rows_in"]) == int(ref.stream["rows_in"])
    assert len(srep.responses) == 3


def test_serve_while_training_rejects_misaligned_ingest(mesh):
    eng, data, init, _ = _lasso_setup(mesh)
    plan = _plan("ssp", 8)                          # chunk = window = 2
    with pytest.raises(ValueError, match="multiple"):
        serve_while_training(eng, init(), data, jax.random.key(1), plan,
                             stream=StreamSpec(kind="replace",
                                               ingest_every=3),
                             source=EmptySource())


# ---------------------------------------------------------------------------
# error paths: pairing, alignment, app support, delta validation
# ---------------------------------------------------------------------------

def test_execute_requires_stream_source_pair(mesh):
    eng, data, init, _ = _lasso_setup(mesh)
    plan = _plan("scan", 4)
    with pytest.raises(ValueError, match="come as a pair"):
        eng.execute(init(), data, jax.random.key(1), plan,
                    stream=StreamSpec(kind="replace"))
    with pytest.raises(ValueError, match="come as a pair"):
        eng.execute(init(), data, jax.random.key(1), plan,
                    source=EmptySource())
    with pytest.raises(ValueError, match="stream_state"):
        eng.execute(init(), data, jax.random.key(1), plan,
                    stream_state={"cursor": 0})


def test_execute_rejects_misaligned_ingest_cadence(mesh):
    eng, data, init, _ = _lasso_setup(mesh)
    plan = _plan("ssp", 8)                          # step length 2
    with pytest.raises(ValueError, match="ingest_every=3 must be a "
                                         "multiple"):
        eng.execute(init(), data, jax.random.key(1), plan,
                    stream=StreamSpec(kind="replace", ingest_every=3),
                    source=EmptySource())


def test_ingestor_type_and_lifecycle_errors(mesh):
    with pytest.raises(TypeError, match="StreamSpec"):
        Ingestor({"kind": "replace"}, EmptySource())
    with pytest.raises(TypeError, match="DataSource"):
        Ingestor(StreamSpec(kind="replace"), object())
    ing = Ingestor(StreamSpec(kind="replace"), EmptySource())
    with pytest.raises(RuntimeError, match="bind"):
        ing.step(None, None, {}, 0)
    with pytest.raises(ValueError, match="missing"):
        ing.restore({"cursor": 0})


def test_bind_rejects_apps_without_ingest_primitives():
    class NoIngest(StradsAppBase):
        pass

    class FakeEngine:
        app = NoIngest()
    with pytest.raises(NotImplementedError, match="ingest"):
        Ingestor(StreamSpec(kind="replace"),
                 EmptySource()).bind(FakeEngine(), {})


def test_bind_rejects_unsupported_kind_and_oversize_capacity(mesh):
    eng, data, init, _ = _lasso_setup(mesh)
    # lasso has no validity channel, so it declares replace-only
    with pytest.raises(ValueError, match="supports stream kinds"):
        Ingestor(StreamSpec(kind="extend"),
                 EmptySource()).bind(eng, data)
    meng, mdata, _, _ = _mf_setup(mesh)
    with pytest.raises(ValueError, match="exceeds"):
        Ingestor(StreamSpec(kind="extend", capacity=999),
                 EmptySource()).bind(meng, mdata)


def test_replace_delta_row_validation(mesh):
    eng, data, init, _ = _lasso_setup(mesh)         # 48 rows
    spec = StreamSpec(kind="replace", ingest_every=1)

    def bad(rows):
        k = len(rows)
        d = {"rows": np.asarray(rows),
             "data": {"X": np.zeros((k, 24), np.float32),
                      "y": np.zeros(k, np.float32)}}
        ing = Ingestor(spec, ScheduledSource({0: d})).bind(eng, data)
        ing.step(eng, None, data, 0)
    with pytest.raises(ValueError, match="unique"):
        bad([3, 3])
    with pytest.raises(ValueError, match="out of range"):
        bad([48])
    with pytest.raises(ValueError, match="out of range"):
        bad([-1])


def test_replay_data_verifies_cursor_against_checkpoint(mesh):
    eng, data, init, _ = _lasso_setup(mesh)
    spec = StreamSpec(kind="replace", ingest_every=2)
    src = lambda s: LassoDriftSource(num_rows=48, num_features=24,
                                     rows_per_ingest=4, seed=s)
    _, ing = replay_data(eng, data, spec, src(1), 6)
    # the right source verifies; a different seed (different stream)
    # would produce the same cursor counts here, so verify shape first
    replay_data(eng, data, spec, src(1), 6, stream_state=ing.payload())
    wrong = dict(ing.payload(), rows_in=np.int64(999))
    with pytest.raises(ValueError, match="rows_in"):
        replay_data(eng, data, spec, src(1), 6, stream_state=wrong)


# ---------------------------------------------------------------------------
# observability: ingest rides the Recorder
# ---------------------------------------------------------------------------

def test_ingest_events_ride_the_recorder(mesh):
    eng, data, init, _ = _lasso_setup(mesh)
    plan = ExecutionPlan(executor="scan", rounds=4,
                         telemetry=TelemetrySpec(kind="trace"))
    rep = eng.execute(init(), data, jax.random.key(1), plan,
                      stream=StreamSpec(kind="replace", ingest_every=2),
                      source=LassoDriftSource(num_rows=48,
                                              num_features=24,
                                              rows_per_ingest=4, seed=2))
    names = [e["name"] for e in rep.telemetry.events]
    assert "ingest" in names
    assert "ingest_rows" in names


# ---------------------------------------------------------------------------
# SyntheticLMSource ≡ repro.data.synthetic_batches (one derivation path)
# ---------------------------------------------------------------------------

def test_synthetic_lm_source_matches_pipeline():
    from repro.data.pipeline import synthetic_batches
    cfg = SyntheticLMConfig(vocab_size=50, seq_len=8, batch_size=2,
                            seed=3)
    src = SyntheticLMSource(cfg)
    assert src.peek(0) == 2
    delta = src.take(5)
    assert len(delta) == 1
    ref = make_batch(cfg, 5)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(delta[0]["data"][k]),
                                      np.asarray(ref[k]))
    it = synthetic_batches(cfg)
    for step in range(3):
        got = next(it)
        want = make_batch(cfg, step)
        for k in want:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(want[k]))
