"""`core/kvstore` edge cases: VarSpec role validation,
``specs_from_tree``/``store_from_tree``/``place_tree`` mismatch
handling, replicated↔sharded round-trips through
``nbytes_per_device``/``repartition``, and VarTable role derivation for
nested pytrees."""
import numpy as np
import pytest

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import single_device_mesh
from repro.core.kvstore import (VarSpec, VarTable, is_replicated,
                                specs_from_tree, store_from_tree)
from repro.part import contiguous_assignment


# ---------------------------------------------------------------------------
# VarSpec
# ---------------------------------------------------------------------------

def test_varspec_role_validated_at_construction():
    VarSpec((4,), jnp.float32, P(), role="model")
    VarSpec((4,), jnp.float32, P(), role="priority")
    with pytest.raises(ValueError, match="'model' or 'priority'|model"):
        VarSpec((4,), jnp.float32, P(), role="prio")
    with pytest.raises(ValueError, match="role"):
        VarSpec((4,), jnp.float32, P(), role="")


def test_varspec_nbytes_replicated_vs_sharded_roundtrip():
    mesh = single_device_mesh()          # 1-wide 'data' axis
    rep = VarSpec((8, 4), jnp.float32, P())
    shd = VarSpec((8, 4), jnp.float32, P("data"))
    assert rep.nbytes() == shd.nbytes() == 8 * 4 * 4
    # per-device bytes: replicated = full; sharded = full / mesh width
    U = mesh.shape["data"]
    assert rep.nbytes_per_device(mesh) == rep.nbytes()
    assert shd.nbytes_per_device(mesh) == shd.nbytes() // U
    assert is_replicated(rep.spec) and not is_replicated(shd.spec)


# ---------------------------------------------------------------------------
# specs_from_tree / store_from_tree / place_tree
# ---------------------------------------------------------------------------

def _nested_state():
    return {"model": {"w": jnp.zeros((4, 2)), "p": jnp.zeros((4,))},
            "r": jnp.zeros((6,))}


def _nested_specs():
    return {"model": {"w": P(), "p": P()}, "r": P("data")}


def test_specs_from_tree_nested_paths_and_roles():
    specs = specs_from_tree(_nested_state(), _nested_specs(),
                            roles={"model/p": "priority"})
    assert set(specs) == {"model/w", "model/p", "r"}
    assert specs["model/p"].role == "priority"
    assert specs["model/w"].role == "model"
    assert specs["r"].spec == P("data")


def test_specs_from_tree_rejects_mismatches():
    state = _nested_state()
    # leaf-count mismatch
    with pytest.raises(ValueError, match="leaves"):
        specs_from_tree(state, {"model": {"w": P()}, "r": P("data")})
    # unknown role path
    with pytest.raises(ValueError, match="unknown state leaves"):
        specs_from_tree(state, _nested_specs(), roles={"nope": "priority"})
    # an invalid role name surfaces the VarSpec validation
    with pytest.raises(ValueError, match="role"):
        specs_from_tree(state, _nested_specs(),
                        roles={"model/p": "hot"})


def test_place_tree_roundtrips_values_and_rejects_unknown_leaves():
    mesh = single_device_mesh()
    state = {"a": jnp.arange(4.0), "b": jnp.ones((2, 2))}
    store = store_from_tree(mesh, state, {"a": P(), "b": P("data")})
    placed = store.place_tree(state)
    for k in state:
        assert (np.asarray(placed[k]) == np.asarray(state[k])).all()
    # a tree with a leaf the store never declared cannot be placed
    with pytest.raises(KeyError):
        store.place_tree({"a": jnp.arange(4.0), "c": jnp.ones((2,))})


def test_store_accounting_follows_repartition():
    mesh = single_device_mesh()
    state = {"a": jnp.zeros((8, 4)), "b": jnp.zeros((8,))}
    store = store_from_tree(mesh, state, {"a": P(), "b": P("data")})
    total = store.total_bytes()
    assert total == 8 * 4 * 4 + 8 * 4
    before = store.bytes_per_device()
    asgn = contiguous_assignment(8, 1)
    # sharded → replicated round-trip through repartition: the spec is
    # re-derived and the accounting moves with it (on a 1-wide mesh the
    # byte numbers coincide; the spec change is what must stick)
    state2 = store.repartition(asgn, state, leaf_specs={"b": P()})
    assert store.specs["b"].spec == P()
    assert store.assignment is asgn
    assert store.bytes_per_device() == before     # 1-device: same bytes
    assert (np.asarray(state2["b"]) == np.asarray(state["b"])).all()
    # ... and back
    store.repartition(asgn, leaf_specs={"b": P("data")})
    assert store.specs["b"].spec == P("data")
    assert store.partition_specs()["b"] == P("data")


# ---------------------------------------------------------------------------
# VarTable role derivation (nested pytrees)
# ---------------------------------------------------------------------------

def test_vartable_derives_nested_commit_and_priority_sets():
    mesh = single_device_mesh()
    state = _nested_state()
    store = store_from_tree(mesh, state, _nested_specs(),
                            roles={"model/p": "priority"})
    table = VarTable(store)
    assert table.worker_resident == {"r"}
    assert table.priority_names == {"model/p"}

    # commit-through: a nested `local` whose path names the sharded leaf
    local = {"r": jnp.full((6,), 7.0), "z": jnp.ones((3,))}
    committed = table.commit_local(state, local, phase=0)
    assert (np.asarray(committed["r"]) == 7.0).all()
    assert (np.asarray(committed["model"]["w"]) == 0.0).all()
    deferred = table.defer_local(local, phase=0)
    assert set(deferred) == {"z"}
    rebuilt = table.rebuild_local(committed, deferred, phase=0)
    assert (np.asarray(rebuilt["r"]) == 7.0).all()
    assert (np.asarray(rebuilt["z"]) == 1.0).all()

    # in-flight exclusion zeroes only the nested priority leaf
    view = {"model": {"w": jnp.ones((4, 2)), "p": jnp.ones((4,))},
            "r": jnp.ones((6,))}
    marked = table.mark_scheduled(view, jnp.array([1, 3]))
    assert list(np.asarray(marked["model"]["p"])) == [1.0, 0.0, 1.0, 0.0]
    assert (np.asarray(marked["model"]["w"]) == 1.0).all()
    with pytest.raises(TypeError, match="integer"):
        table.mark_scheduled(view, jnp.array([0.5, 1.5]))


def test_vartable_rejects_structure_drift():
    mesh = single_device_mesh()
    state = _nested_state()
    store = store_from_tree(mesh, state, _nested_specs())
    table = VarTable(store)
    table.commit_local(state, {"r": jnp.zeros((6,))}, phase=0)
    with pytest.raises(ValueError, match="different"):
        table.commit_local(state, {"z": jnp.zeros((3,))}, phase=0)
    with pytest.raises(ValueError, match="defer_local"):
        table.rebuild_local(state, {}, phase=5)
